"""MoE tests: local sort+ragged_dot path vs a brute-force oracle, capacity
semantics, and the distributed scatter/decode paths vs the local oracle
(via an 8-device subprocess — shard_map + all_to_all + psum)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ParamFactory
from repro.models.ffn import (MoEConfig, _moe_local_math, _route, init_moe,
                              moe_forward)
from repro.sharding import ParallelContext


def _setup(seed=0, T=32, d=16, E=4, k=2, f=8):
    cfg = MoEConfig(d_model=d, d_ff=f, n_experts=E, top_k=k)
    pf = ParamFactory(jax.random.PRNGKey(seed), jnp.float32)
    params = init_moe(pf, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (T, d))
    return cfg, params, x


def _brute_force(params, cfg, x2d):
    """Explicit per-token loop over its top-k experts."""
    gates, idx, _ = _route(params["router"], x2d, cfg)
    T, d = x2d.shape
    out = np.zeros((T, d), np.float32)
    for t in range(T):
        for j in range(cfg.top_k):
            e = int(idx[t, j])
            h = jax.nn.silu(x2d[t] @ params["w_gate"][e]) * \
                (x2d[t] @ params["w_up"][e])
            out[t] += float(gates[t, j]) * np.asarray(h @ params["w_down"][e])
    return out


def test_local_path_matches_bruteforce():
    cfg, params, x = _setup()
    y, aux = _moe_local_math(x, params["router"], params["w_gate"],
                             params["w_up"], params["w_down"], cfg)
    ref = _brute_force(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-4, rtol=1e-3)


def test_router_topk_normalized():
    cfg, params, x = _setup()
    gates, idx, aux = _route(params["router"], x, cfg)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)),
                               np.ones(x.shape[0]), atol=1e-5)
    assert float(aux) >= 0.9   # E * sum f_e P_e ~ 1 for near-uniform routing


def test_moe_forward_with_shared_expert():
    cfg = MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=2,
                    n_shared_experts=1, shared_d_ff=8)
    pf = ParamFactory(jax.random.PRNGKey(0), jnp.float32)
    params = init_moe(pf, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, aux = moe_forward(params, cfg, x, ParallelContext())
    assert y.shape == x.shape and not bool(jnp.isnan(y).any())


def test_grad_flows_through_moe():
    cfg, params, x = _setup()

    def loss(params):
        y, aux = _moe_local_math(x, params["router"], params["w_gate"],
                                 params["w_up"], params["w_down"], cfg)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.abs(g[name]).sum()) > 0, name


_DISTRIBUTED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.common import ParamFactory
    from repro.models.ffn import MoEConfig, init_moe, moe_forward
    from repro.sharding import ParallelContext

    cfg = MoEConfig(d_model=16, d_ff=8, n_experts=8, top_k=2,
                    capacity_factor=8.0)   # high capacity => no drops
    pf = ParamFactory(jax.random.PRNGKey(0), jnp.float32)
    params = init_moe(pf, cfg)
    B, T, d = 4, 8, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, d))

    y_ref, aux_ref = moe_forward(params, cfg, x, ParallelContext())

    from repro.sharding import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    ctx = ParallelContext(mesh=mesh)
    y_scatter, aux_s = jax.jit(
        lambda p, x: moe_forward(p, cfg, x, ctx, decode=False))(params, x)
    np.testing.assert_allclose(np.asarray(y_scatter), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)
    y_decode, aux_d = jax.jit(
        lambda p, x: moe_forward(p, cfg, x, ctx, decode=True))(params, x)
    np.testing.assert_allclose(np.asarray(y_decode), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)

    # decode with expert_ffn sharded over data ("gather tokens, not
    # weights" — the kimi-k2 decode hillclimb layout) == same oracle
    from repro.sharding import rules_dict
    rules = rules_dict({"expert_embed": (), "expert_ffn": ("data",)})
    ctx_f = ParallelContext(mesh=mesh, rules=rules)
    y_fsh, _ = jax.jit(
        lambda p, x: moe_forward(p, cfg, x, ctx_f, decode=True))(params, x)
    np.testing.assert_allclose(np.asarray(y_fsh), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)

    # and the scatter path under the same override (falls back to
    # gathering f) == oracle
    y_ssh, _ = jax.jit(
        lambda p, x: moe_forward(p, cfg, x, ctx_f, decode=False))(params, x)
    np.testing.assert_allclose(np.asarray(y_ssh), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)
    print("DISTRIBUTED_MOE_OK")
""")


def test_distributed_paths_match_local_oracle():
    """scatter (all_to_all) and decode (psum) paths == single-device math."""
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", _DISTRIBUTED_SCRIPT],
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         env=env)
    assert "DISTRIBUTED_MOE_OK" in res.stdout, res.stderr[-2000:]


def test_capacity_drops_bounded():
    """With tiny capacity, output stays finite and drops are partial."""
    cfg = MoEConfig(d_model=8, d_ff=8, n_experts=2, top_k=2,
                    capacity_factor=0.25)
    pf = ParamFactory(jax.random.PRNGKey(0), jnp.float32)
    params = init_moe(pf, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 8))
    y, _ = moe_forward(params, cfg, x, ParallelContext())
    assert not bool(jnp.isnan(y).any())
