"""Paged flash-decode kernel tests: interpret-mode parity of the Pallas
GQA/MLA paged decode kernels (and the in-kernel single-token paged write)
against the XLA dense-gather path, active-prefix gather equivalence, and
engine-level greedy parity of the kernel path and of batched paged
prefill vs the serial chunk loop on a ragged Poisson stream."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.data.synthetic import make_lm_stream
from repro.kernels import ops as kops
from repro.models import transformer as tfm
from repro.models import attention as attn
from repro.models.attention import _paged_write, gather_blocks
from repro.serving import (ContinuousCascadeEngine, ModelRunner,
                           make_requests, poisson_arrivals)
from repro.serving.request import DONE
from repro.sharding import ParallelContext


@pytest.fixture(scope="module")
def runners():
    key = jax.random.PRNGKey(0)
    s_cfg = reduced(get_config("internlm2-1.8b"))
    l_cfg = s_cfg.replace(name="large", n_layers=3, d_ff=768)
    small = ModelRunner(s_cfg, tfm.init_params(s_cfg, key))
    large = ModelRunner(l_cfg, tfm.init_params(l_cfg,
                                               jax.random.fold_in(key, 1)))
    return small, large


def ragged_prompts(key, lens, vocab):
    base = make_lm_stream(key, len(lens), max(lens), vocab)
    return [base[i, :n].astype(np.int32) for i, n in enumerate(lens)]


# page table with disjoint nonzero blocks per row + one all-trash row;
# positions ragged, one mid-block
TABLES = np.asarray([[1, 2, 3, 0],
                     [4, 5, 0, 0],
                     [6, 0, 0, 0],
                     [0, 0, 0, 0]], np.int32)
POS = np.asarray([9, 6, 2, 3], np.int32)       # rows 0-2 mapped, row 3 trash


# ---------------------------------------------------------------------------
# Kernel-level parity vs the dense-gather reference
# ---------------------------------------------------------------------------

def test_gqa_kernel_parity_ragged():
    key = jax.random.PRNGKey(1)
    B, H, KV, hd, bs, N = 4, 4, 2, 16, 4, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (N, bs, KV, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (N, bs, KV, hd), jnp.float32)
    tables, pos = jnp.asarray(TABLES), jnp.asarray(POS)

    out = kops.paged_flash_decode_gqa(q, kp, vp, tables, pos)

    kk, vv = gather_blocks(kp, tables), gather_blocks(vp, tables)
    S = kk.shape[1]
    mask = jnp.arange(S)[None, :] <= pos[:, None]
    qg = q.reshape(B, 1, KV, H // KV, hd)
    s = jnp.einsum("btkgh,bskh->bkgts", qg, kk) / np.sqrt(hd)
    s = jnp.where(mask[:, None, None, None, :], s, -1e30)
    ref = jnp.einsum("bkgts,bskh->btkgh", jax.nn.softmax(s, axis=-1),
                     vv).reshape(B, 1, H, hd)
    # mapped rows: epsilon parity (fp32 online softmax vs XLA softmax)
    np.testing.assert_allclose(np.asarray(out[:3]), np.asarray(ref[:3]),
                               atol=1e-5)
    # all-trash row: every page early-masked -> exact zeros, no NaN
    np.testing.assert_array_equal(np.asarray(out[3]),
                                  np.zeros_like(np.asarray(out[3])))


def test_mla_kernel_parity_ragged():
    key = jax.random.PRNGKey(2)
    B, H, r, dr, bs, N = 4, 4, 8, 6, 4, 8
    ks = jax.random.split(key, 5)
    q_abs = jax.random.normal(ks[0], (B, 1, H, r), jnp.float32)
    q_rope = jax.random.normal(ks[1], (B, 1, H, dr), jnp.float32)
    ckv = jax.random.normal(ks[2], (N, bs, r), jnp.float32)
    kr = jax.random.normal(ks[3], (N, bs, dr), jnp.float32)
    w = jax.random.normal(ks[4], (r,), jnp.float32) * 0.1
    tables, pos = jnp.asarray(TABLES), jnp.asarray(POS)
    scale = 1.0 / np.sqrt(16 + dr)

    out = kops.paged_flash_decode_mla(q_abs, q_rope, ckv, kr, w, tables,
                                      pos, scale=scale)

    from repro.models.common import rms_norm
    ckv_all = gather_blocks(ckv, tables)
    kr_all = gather_blocks(kr, tables)
    ckv_n = rms_norm(ckv_all, w)
    S = ckv_all.shape[1]
    s = (jnp.einsum("bthr,bsr->bhts", q_abs, ckv_n)
         + jnp.einsum("bthk,bsk->bhts", q_rope, kr_all)) * scale
    mask = (jnp.arange(S)[None, :] <= pos[:, None])[:, None, None, :]
    s = jnp.where(mask, s, -1e30)
    ref = jnp.einsum("bhts,bsr->bthr", jax.nn.softmax(s, axis=-1), ckv_n)
    np.testing.assert_allclose(np.asarray(out[:3]), np.asarray(ref[:3]),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(out[3]),
                                  np.zeros_like(np.asarray(out[3])))


def test_paged_write_kernel_matches_xla():
    key = jax.random.PRNGKey(3)
    N, bs, KV, hd, B = 8, 4, 2, 16, 4
    leaf = jax.random.normal(key, (N, bs, KV, hd), jnp.float32)
    vals = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, hd))
    tables, pos = jnp.asarray(TABLES), jnp.asarray(POS)
    out = kops.paged_write_token(leaf, tables, pos, vals)
    ref = _paged_write(leaf, tables, pos[:, None], vals[:, None])
    # bit parity, including the trash-row write into block 0
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # MLA-shaped leaf (no head dims beyond one feature axis)
    leaf2 = jax.random.normal(key, (N, bs, 7), jnp.float32)
    vals2 = jax.random.normal(jax.random.fold_in(key, 2), (B, 7))
    np.testing.assert_array_equal(
        np.asarray(kops.paged_write_token(leaf2, tables, pos, vals2)),
        np.asarray(_paged_write(leaf2, tables, pos[:, None],
                                vals2[:, None])))


# ---------------------------------------------------------------------------
# Attention-level: kernel vs fallback, active-prefix gather equivalence
# ---------------------------------------------------------------------------

def _gqa_layer(key):
    cfg = reduced(get_config("internlm2-1.8b"))
    ac = tfm.attn_config(cfg)
    params = jax.tree.map(lambda a: a[0],
                          tfm.init_params(cfg, key)["blocks"]["dense"]["attn"])
    ks = jax.random.split(jax.random.fold_in(key, 7), 3)
    cache = {
        "k": jax.random.normal(ks[0], (8, 4, ac.n_kv_heads, ac.head_dim)) * .1,
        "v": jax.random.normal(ks[1], (8, 4, ac.n_kv_heads, ac.head_dim)) * .1}
    x = jax.random.normal(ks[2], (4, 1, cfg.d_model)) * 0.3
    return ac, params, cache, x


def test_gqa_decode_kernel_vs_fallback_layer():
    """One attention layer: same inputs through both paged decode
    implementations -> outputs match to epsilon on mapped rows and the
    written caches are BIT-identical (the write kernel scatters exactly
    what the XLA scatter does)."""
    ac, params, cache, x = _gqa_layer(jax.random.PRNGKey(4))
    ctx = ParallelContext()
    tables, pos = jnp.asarray(TABLES), jnp.asarray(POS)
    y_f, c_f = attn.gqa_decode(params, ac, x, pos, cache, ctx,
                               pages=tables, paged_kernel=False)
    y_k, c_k = attn.gqa_decode(params, ac, x, pos, cache, ctx,
                               pages=tables, paged_kernel=True)
    np.testing.assert_allclose(np.asarray(y_k[:3]), np.asarray(y_f[:3]),
                               atol=1e-5)
    assert np.isfinite(np.asarray(y_k)).all()
    for leaf in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(c_k[leaf]),
                                      np.asarray(c_f[leaf]))


def test_active_prefix_gather_equivalence():
    """Slicing the page table to the active block prefix (every mapped
    position still covered) must not change the fallback decode output
    or the cache writes at all — the tightened gather is exact."""
    ac, params, cache, x = _gqa_layer(jax.random.PRNGKey(5))
    ctx = ParallelContext()
    pos = jnp.asarray([9, 6, 2, 3], jnp.int32)      # max pos 9 -> 3 blocks
    full = jnp.asarray(TABLES)
    for kernel in (False, True):
        y_full, c_full = attn.gqa_decode(params, ac, x, pos, cache, ctx,
                                         pages=full, paged_kernel=kernel)
        y_cut, c_cut = attn.gqa_decode(params, ac, x, pos, cache, ctx,
                                       pages=full[:, :3],
                                       paged_kernel=kernel)
        np.testing.assert_array_equal(np.asarray(y_cut), np.asarray(y_full))
        for leaf in ("k", "v"):
            np.testing.assert_array_equal(np.asarray(c_cut[leaf]),
                                          np.asarray(c_full[leaf]))


# ---------------------------------------------------------------------------
# Engine-level: kernel path + batched prefill greedy parity
# ---------------------------------------------------------------------------

def _engine(small, large, **kw):
    return ContinuousCascadeEngine(small, large, n_slots=3, tau=-1e9,
                                   early_exit=False, backend="paged",
                                   block_size=4, prefill_chunk=4, **kw)


def test_engine_kernel_parity_ragged_poisson(runners):
    """Acceptance: the continuous+paged engine on the Pallas kernel path
    (interpret mode) reproduces the dense-gather path token for token on
    a ragged Poisson stream — and both match standalone generation."""
    small, large = runners
    key = jax.random.PRNGKey(11)
    lens = [5, 9, 4, 12, 7, 6, 10, 4]
    prompts = ragged_prompts(key, lens, small.cfg.vocab_size)
    arrivals = poisson_arrivals(len(prompts), rate=300.0, seed=3)

    res_fb = _engine(small, large, paged_kernel=False).run(
        make_requests(prompts, 5, arrivals), 5)
    res_k = _engine(small, large, paged_kernel=True).run(
        make_requests(prompts, 5, arrivals), 5)

    assert res_k.stats["paged_kernel"] and not res_fb.stats["paged_kernel"]
    np.testing.assert_array_equal(res_k.tokens, res_fb.tokens)
    np.testing.assert_allclose(res_k.confidence, res_fb.confidence,
                               rtol=1e-4)
    assert all(r.state == DONE for r in res_k.requests)
    for r in res_k.requests:
        t, _ = small.generate(r.prompt[None, :], r.prompt_len, 5)
        np.testing.assert_array_equal(r.tokens, t[0])


def test_batched_prefill_parity_and_dispatch_count(runners):
    """Batched paged prefill packs same-offset chunks of simultaneous
    arrivals into one dispatch: greedy outputs equal the serial chunk
    loop bit for bit, the per-row chunk count is unchanged, and the
    dispatch count strictly drops on a batched-arrival workload."""
    small, large = runners
    key = jax.random.PRNGKey(13)
    lens = [8, 8, 12, 6, 8, 10]
    prompts = ragged_prompts(key, lens, small.cfg.vocab_size)

    serial = _engine(small, large, batch_prefill=False).run(
        make_requests(prompts, 4), 4)
    batched = _engine(small, large, batch_prefill=True).run(
        make_requests(prompts, 4), 4)

    np.testing.assert_array_equal(batched.tokens, serial.tokens)
    np.testing.assert_allclose(batched.confidence, serial.confidence,
                               rtol=1e-5)
    assert batched.stats["prefill_chunks"] == serial.stats["prefill_chunks"]
    assert serial.stats["prefill_dispatches"] == \
        serial.stats["prefill_chunks"]
    assert (batched.stats["prefill_dispatches"]
            < serial.stats["prefill_dispatches"])
    for r in batched.requests:
        t, _ = small.generate(r.prompt[None, :], r.prompt_len, 4)
        np.testing.assert_array_equal(r.tokens[:r.max_new], t[0])


def test_mla_engine_kernel_parity():
    """MLA weight-absorbed kernel decode (compressed paged cache) agrees
    with the gather fallback inside the full engine."""
    key = jax.random.PRNGKey(17)
    cfg = reduced(get_config("deepseek-v2-236b"))
    cfg = cfg.replace(moe=None, family="dense", n_layers=2)
    small = ModelRunner(cfg, tfm.init_params(cfg, key))
    large = ModelRunner(cfg.replace(name="l"),
                        tfm.init_params(cfg, jax.random.fold_in(key, 1)))
    prompts = ragged_prompts(jax.random.fold_in(key, 2), [6, 9, 4, 7],
                             cfg.vocab_size)
    res_fb = _engine(small, large, paged_kernel=False).run(
        make_requests(prompts, 3), 3)
    res_k = _engine(small, large, paged_kernel=True).run(
        make_requests(prompts, 3), 3)
    np.testing.assert_array_equal(res_k.tokens, res_fb.tokens)
    np.testing.assert_allclose(res_k.confidence, res_fb.confidence,
                               rtol=1e-4)
