"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.kernels import ops
from repro.kernels.ref import (deferral_entropy_ref, flash_attention_ref,
                               gatekeeper_loss_ref)
from repro.kernels.gatekeeper_loss import gatekeeper_loss_tokens
from repro.kernels.deferral_entropy import deferral_entropy
from repro.kernels.flash_attention import flash_attention


# ---------------------------------------------------------------------------
# gatekeeper_loss kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,d,V,tb,vb,db", [
    (128, 32, 256, 64, 128, 32),
    (128, 64, 300, 128, 128, 16),      # non-multiple vocab (padding path)
    (256, 48, 512, 128, 512, 48),      # single vocab block
    (64, 128, 128, 64, 64, 64),
])
def test_gatekeeper_kernel_shapes(T, d, V, tb, vb, db):
    k = jax.random.PRNGKey(T + V)
    x = jax.random.normal(k, (T, d))
    table = jax.random.normal(jax.random.fold_in(k, 1), (V, d))
    tgt = jax.random.randint(k, (T,), 0, V)
    ce, kl, corr, ent = gatekeeper_loss_tokens(x, table, tgt, tb=tb, vb=vb,
                                               db=db, interpret=True)
    ref = gatekeeper_loss_ref(x, table, tgt, 0.5, jnp.ones((T,)))
    np.testing.assert_allclose(ce, ref["ce"], atol=2e-4, rtol=1e-4)
    np.testing.assert_allclose(kl, ref["kl"], atol=2e-4, rtol=1e-4)
    np.testing.assert_allclose(ent, ref["entropy"], atol=2e-4, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(corr), np.asarray(ref["correct"]))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gatekeeper_kernel_dtypes(dtype):
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (128, 32)).astype(dtype)
    table = jax.random.normal(jax.random.fold_in(k, 1), (200, 32)).astype(dtype)
    tgt = jax.random.randint(k, (128,), 0, 200)
    ce, kl, corr, ent = gatekeeper_loss_tokens(x, table, tgt, tb=64, vb=64,
                                               db=32, interpret=True)
    ref = gatekeeper_loss_ref(x.astype(jnp.float32),
                              table.astype(jnp.float32), tgt, 0.5,
                              jnp.ones((128,)))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(ce, ref["ce"], atol=tol, rtol=tol)


def test_gatekeeper_fused_wrapper_scalar():
    k = jax.random.PRNGKey(1)
    T, d, V = 100, 24, 333            # ragged T (token padding path)
    x = jax.random.normal(k, (T, d))
    table = jax.random.normal(jax.random.fold_in(k, 1), (V, d))
    tgt = jax.random.randint(k, (T,), 0, V)
    loss, aux = ops.gatekeeper_loss_fused(x, table, tgt, alpha=0.25,
                                          tb=64, vb=128, db=24, interpret=True)
    ref = gatekeeper_loss_ref(x, table, tgt, 0.25, jnp.ones((T,)))
    np.testing.assert_allclose(float(loss), float(ref["loss"]), rtol=1e-4)


# ---------------------------------------------------------------------------
# deferral_entropy kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,V,tb,vb", [
    (128, 512, 64, 128), (64, 1000, 64, 256), (128, 50, 128, 64),
])
def test_deferral_entropy_shapes(T, V, tb, vb):
    k = jax.random.PRNGKey(T * V)
    logits = jax.random.normal(k, (T, V)) * 4
    ne, mp, am = deferral_entropy(logits, tb=tb, vb=vb, interpret=True)
    rne, rmp, ram = deferral_entropy_ref(logits)
    np.testing.assert_allclose(ne, rne, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(mp, rmp, atol=1e-5, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(am), np.asarray(ram))


@settings(deadline=None, max_examples=15)
@given(st.integers(0, 9999), st.integers(2, 600))
def test_property_deferral_entropy(seed, V):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (64, V)) * 3
    ne, mp, am = deferral_entropy(logits, tb=64, vb=128, interpret=True)
    # neg entropy in [-log V, 0]; max prob in (0, 1]
    assert float(ne.max()) <= 1e-5
    assert float(ne.min()) >= -np.log(V) - 1e-4
    assert 0 < float(mp.min()) and float(mp.max()) <= 1 + 1e-6


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,S,H,KV,hd,causal,win", [
    (2, 64, 64, 4, 2, 32, True, 0),
    (1, 96, 96, 4, 4, 64, True, 32),       # sliding window
    (2, 128, 128, 8, 2, 64, False, 0),     # bidirectional (encoder)
    (1, 70, 70, 2, 1, 16, True, 0),        # ragged (padding path), MQA
])
def test_flash_attention_shapes(B, T, S, H, KV, hd, causal, win):
    ks = jax.random.split(jax.random.PRNGKey(B * T + H), 3)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    o = flash_attention(q, k, v, causal=causal, window=win, qb=32, kb=32,
                        interpret=True)
    r = flash_attention_ref(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5,
                               rtol=1e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 64, 2, 32)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 64, 2, 32)).astype(dtype)
    o = flash_attention(q, k, v, causal=True, qb=32, kb=32, interpret=True)
    r = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(r),
                               atol=tol, rtol=tol)


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 9999), st.sampled_from([16, 32, 64]),
       st.booleans())
def test_property_flash_attention(seed, hd, causal):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, hd))
    k = jax.random.normal(ks[1], (1, 64, 2, hd))
    v = jax.random.normal(ks[2], (1, 64, 2, hd))
    o = flash_attention(q, k, v, causal=causal, qb=32, kb=32, interpret=True)
    r = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=3e-5,
                               rtol=2e-4)


# ---------------------------------------------------------------------------
# XLA-level chunked (online-softmax) attention — the flash dataflow used by
# the qwen prefill hillclimb — must match dense _attend exactly.
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=10)
@given(st.integers(0, 9999), st.sampled_from([128, 256]),
       st.sampled_from(["causal", "sliding", "cache"]))
def test_property_chunked_attend_matches_dense(seed, chunk, mode):
    from repro.models.attention import _attend
    from repro.models.common import make_causal_mask, make_sliding_mask
    from repro.sharding import ParallelContext
    ctx = ParallelContext(mesh=None)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, Tq, H, KV, hd = 2, 512, 8, 4, 32
    Tk = 1024 if mode == "cache" else Tq
    q = jax.random.normal(ks[0], (B, Tq, H, hd))
    k = jax.random.normal(ks[1], (B, Tk, KV, hd))
    v = jax.random.normal(ks[2], (B, Tk, KV, hd))
    if mode == "causal":
        mask = make_causal_mask(Tq, Tk, 0)
    elif mode == "sliding":
        mask = make_sliding_mask(Tq, Tk, 0, 128)
    else:
        mask = make_causal_mask(Tq, Tk, 100)
    ref = _attend(q, k, v, mask, 0.125, ctx)
    got = _attend(q, k, v, mask, 0.125, ctx, chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=3e-5, rtol=2e-4)


# ---------------------------------------------------------------------------
# WKV (RWKV6 chunked recurrence) Pallas kernel vs the naive scan oracle
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=8)
@given(st.integers(0, 9999), st.sampled_from([16, 32]),
       st.sampled_from([32, 64]), st.sampled_from([64, 96]))
def test_property_wkv_kernel_matches_scan(seed, dim, chunk, T):
    from repro.kernels.wkv_scan import wkv_scan
    from repro.models.ssm import linear_attention_scan
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    B, H, K, V = 2, 2, dim, dim
    q = jax.random.normal(ks[0], (B, T, H, K)) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, K)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, V)) * 0.5
    logw = -jax.random.uniform(ks[3], (B, T, H, K), minval=0.05, maxval=1.0)
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    s0 = jax.random.normal(ks[5], (B, H, K, V)) * 0.2
    y_ref, s_ref = linear_attention_scan(q, k, v, logw, s0, mode="rwkv", u=u)
    y, s = wkv_scan(q, k, v, logw, u, s0, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=5e-4, rtol=5e-4)


def test_mla_chunked_matches_dense():
    """Chunked MLA (concat nope||rope trick) == the dense two-term score."""
    import dataclasses
    from repro.models.attention import AttnConfig, init_mla, mla_forward
    from repro.models.common import ParamFactory
    from repro.sharding import ParallelContext
    cfg = AttnConfig(d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                     q_lora=24, kv_lora=32, rope_dim=8, v_head_dim=16)
    pf = ParamFactory(jax.random.PRNGKey(0), jnp.float32)
    params = init_mla(pf, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 512, 64))
    pos = jnp.arange(512)[None, :]
    ctx = ParallelContext()
    y_ref, _ = mla_forward(params, cfg, x, pos, ctx)
    y_chk, _ = mla_forward(params, dataclasses.replace(cfg, attn_chunk=128),
                           x, pos, ctx)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               atol=3e-5, rtol=3e-5)


@settings(deadline=None, max_examples=6)
@given(st.integers(0, 9999), st.sampled_from([16, 32]))
def test_property_ssd_kernel_matches_scan(seed, dim):
    """mode="mamba" (inclusive, scalar decay) of the same kernel."""
    from repro.kernels.wkv_scan import wkv_scan
    from repro.models.ssm import linear_attention_scan
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    B, T, H, K = 2, 64, 2, dim
    q = jax.random.normal(ks[0], (B, T, H, K)) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, K)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, K)) * 0.5
    logw_s = -jax.random.uniform(ks[3], (B, T, H, 1), minval=0.05,
                                 maxval=1.0)
    s0 = jax.random.normal(ks[4], (B, H, K, K)) * 0.2
    y_ref, s_ref = linear_attention_scan(q, k, v, logw_s, s0, mode="mamba")
    y, s = wkv_scan(q, k, v, jnp.broadcast_to(logw_s, (B, T, H, K)),
                    jnp.zeros((H, K)), s0, chunk=32, interpret=True,
                    mode="mamba")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=5e-4, rtol=5e-4)
