"""Cascade orchestration tests (eq. 6) + calibration + baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import (PromptingBaseline, compute_static_partition,
                                  static_partition_loss)
from repro.core.cascade import Cascade
from repro.core.calibration import (expected_compute_cost,
                                    threshold_for_accuracy)
from repro.core.deferral import (selective_predict,
                                 sequence_negative_entropy)


def _mk_cascade(seed=0, n_classes=5, d=8):
    k = jax.random.PRNGKey(seed)
    ws = jax.random.normal(k, (d, n_classes)) * 0.3          # weak
    wl = jax.random.normal(jax.random.fold_in(k, 1), (d, n_classes))
    return Cascade(
        small_apply=lambda p, x: x @ p, large_apply=lambda p, x: x @ p,
        small_params=ws, large_params=wl, signal="max_softmax", tau=0.5)


def test_dense_sparse_equivalent():
    c = _mk_cascade()
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 8))
    dense = c.predict_dense(x)
    sparse = c.predict_sparse(x)
    np.testing.assert_array_equal(dense.predictions, sparse.predictions)
    np.testing.assert_array_equal(dense.deferred, sparse.deferred)
    assert dense.compute_cost == pytest.approx(sparse.compute_cost)


def test_tau_extremes():
    c = _mk_cascade()
    x = jax.random.normal(jax.random.PRNGKey(3), (32, 8))
    c.tau = -1e9
    assert c.predict_dense(x).deferral_ratio == 0.0
    c.tau = 1e9
    assert c.predict_dense(x).deferral_ratio == 1.0


def test_calibrate_ratio():
    c = _mk_cascade()
    x = jax.random.normal(jax.random.PRNGKey(4), (500, 8))
    c.calibrate_tau(x, deferral_ratio=0.25)
    r = c.predict_dense(x).deferral_ratio
    assert abs(r - 0.25) < 0.05


def test_threshold_for_accuracy_monotone():
    rng = np.random.default_rng(0)
    n = 1000
    sc = (rng.random(n) < 0.6).astype(float)
    lc = np.maximum(sc, (rng.random(n) < 0.9).astype(float))
    conf = sc + rng.random(n) * 0.1
    tau_low = threshold_for_accuracy(conf, sc, lc, 0.7)
    tau_high = threshold_for_accuracy(conf, sc, lc, 0.85)
    assert tau_low is not None and tau_high is not None
    assert tau_high >= tau_low
    assert threshold_for_accuracy(conf, sc, lc, 0.999) is None


def test_compute_cost_formula():
    assert expected_compute_cost(0.0, 0.2) == pytest.approx(0.2)
    assert expected_compute_cost(1.0, 0.2) == pytest.approx(1.2)


def test_selective_predict_tokens():
    small = jnp.zeros((4, 6), jnp.int32)
    large = jnp.ones((4, 6), jnp.int32)
    conf = jnp.array([0.9, 0.1, 0.9, 0.1])
    out = selective_predict(small, large, conf, 0.5)
    np.testing.assert_array_equal(np.asarray(out[0]), np.zeros(6))
    np.testing.assert_array_equal(np.asarray(out[1]), np.ones(6))


def test_sequence_neg_entropy_mask():
    k = jax.random.PRNGKey(0)
    logits = jax.random.normal(k, (3, 10, 7))
    mask = jnp.zeros((3, 10)).at[:, :4].set(1.0)
    g1 = sequence_negative_entropy(logits, mask)
    g2 = sequence_negative_entropy(logits[:, :4])
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5)


def test_static_partition_baseline():
    k = jax.random.PRNGKey(1)
    logits = jax.random.normal(k, (32, 5))
    targets = jax.random.randint(k, (32,), 0, 5)
    ref_logits = jax.random.normal(jax.random.fold_in(k, 1), (32, 5))
    easy = compute_static_partition(ref_logits, targets)
    loss, aux = static_partition_loss(logits, targets, easy, alpha=0.5)
    assert np.isfinite(float(loss))


def test_prompting_baseline_prepends():
    pb = PromptingBaseline("answer_n")
    toks = jnp.arange(10)[None, :]
    out = pb.modify_inputs(toks)
    assert out.shape == toks.shape
    assert int(out[0, 0]) == 2          # ANSWER_N token
    logits = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    conf = pb.confidence_from_logits(logits)
    assert conf.shape == (4,)
