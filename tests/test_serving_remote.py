"""Distributed M_L tier: wire-format goldens, socket RPC server/client
contract, and the fault-injection suite — replica death mid-batch
(re-dispatch), slow replica (timeout + retry), connection refused,
corrupt payloads (rid echoed), cancellation on engine shutdown, and
bit-exact greedy parity sync vs socket vs 2-replica pool on a ragged
Poisson workload."""
import json
import socket
import threading
import time
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import transformer as tfm
from repro.serving import (ContinuousCascadeEngine, ModelRunner, Request,
                           make_requests, poisson_arrivals)
from repro.serving.large_backend import BatchPolicy, LargeResult, _Pending
from repro.serving.remote import (MLServer, ReplicaPool,
                                  RemoteBackendError, SocketBackend, wire)
from repro.serving.request import DONE

GOLDEN = Path(__file__).parent / "golden" / "wire_v1.json"


class FakeRunner:
    """Deterministic stand-in for a ModelRunner: token i of row r is
    prompt[r][0] + i. Lets protocol/fault tests run at socket speed;
    parity tests use the real models (see `runners`)."""

    def __init__(self, delay: float = 0.0):
        self.delay = delay

    def generate(self, prompts, plen, max_new):
        if self.delay:
            time.sleep(self.delay)
        out = (prompts[:, :1]
               + np.arange(max_new, dtype=np.int32)[None, :]).astype(np.int32)
        return out, None


def fake_server(**kw) -> MLServer:
    kw.setdefault("max_new", 4)
    kw.setdefault("large_batch", 2)
    kw.setdefault("max_wait", 0.01)
    return MLServer(FakeRunner(kw.pop("gen_delay", 0.0)), **kw).start()


def reqs_for(prompts, max_new=4):
    return [Request(rid=i, prompt=np.asarray(p, np.int32), max_new=max_new)
            for i, p in enumerate(prompts)]


def expected_tokens(prompt, max_new=4):
    return int(prompt[0]) + np.arange(max_new, dtype=np.int32)


@pytest.fixture(scope="module")
def runners():
    key = jax.random.PRNGKey(0)
    s_cfg = reduced(get_config("internlm2-1.8b"))
    l_cfg = s_cfg.replace(name="large", n_layers=3, d_ff=768)
    small = ModelRunner(s_cfg, tfm.init_params(s_cfg, key))
    large = ModelRunner(l_cfg, tfm.init_params(l_cfg,
                                               jax.random.fold_in(key, 1)))
    return small, large


def ragged_prompts(key, lens, vocab):
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                          (n,), 0, vocab), np.int32)
            for i, n in enumerate(lens)]


# ---------------------------------------------------------------------------
# Wire format: goldens + framing limits
# ---------------------------------------------------------------------------

def test_golden_wire_format_pinned():
    """The serialized request/result payloads (and their exact frame
    bytes — canonical JSON makes them stable) must match the committed
    fixture: a change here breaks rolling server/client upgrades, and
    the escape hatch is bumping SCHEMA_VERSION + adding wire_v2.json."""
    fix = json.loads(GOLDEN.read_text())
    assert fix["schema"] == wire.SCHEMA_VERSION, \
        "schema bumped: pin a new golden fixture for the new version"
    req = wire.encode_request(fix["request"]["rid"],
                              np.asarray(fix["request"]["prompt"], np.int32))
    assert req == fix["request"]
    assert wire.frame_bytes(req).hex() == fix["request_frame_hex"]
    res = LargeResult(rid=fix["result"]["rid"],
                      tokens=np.asarray(fix["result"]["tokens"], np.int32),
                      batch_id=fix["result"]["batch_id"],
                      n_real=fix["result"]["n_real"],
                      pad_to=fix["result"]["pad_to"],
                      reason=fix["result"]["reason"],
                      prompt_len=fix["result"]["prompt_len"])
    assert wire.encode_result(res) == fix["result"]
    assert wire.frame_bytes(fix["result"]).hex() == fix["result_frame_hex"]
    assert wire.frame_bytes(
        wire.envelope("submit", reqs=[req])).hex() \
        == fix["submit_envelope_frame_hex"]
    assert wire.frame_bytes(
        wire.envelope("results", results=[fix["result"]], pending=0)).hex() \
        == fix["results_envelope_frame_hex"]
    # and the pinned bytes decode back to the same payloads
    rid, prompt = wire.decode_request(fix["request"])
    assert rid == fix["request"]["rid"]
    np.testing.assert_array_equal(prompt, fix["request"]["prompt"])
    back = wire.decode_result(fix["result"])
    np.testing.assert_array_equal(back.tokens, fix["result"]["tokens"])


def test_frame_roundtrip_and_limits():
    a, b = socket.socketpair()
    try:
        wire.send_frame(a, wire.envelope("ping", n=1))
        msg = wire.recv_frame(b)
        wire.check_schema(msg)
        assert msg["kind"] == "ping" and msg["n"] == 1
        # oversize length prefix rejected before allocation
        a.sendall((wire.MAX_FRAME + 1).to_bytes(4, "big"))
        with pytest.raises(wire.WireError, match="MAX_FRAME"):
            wire.recv_frame(b)
    finally:
        a.close()
        b.close()
    # truncated frame: peer closes mid-body
    a, b = socket.socketpair()
    try:
        a.sendall((100).to_bytes(4, "big") + b"only-a-few-bytes")
        a.close()
        with pytest.raises(wire.WireError, match="truncated"):
            wire.recv_frame(b)
    finally:
        b.close()
    # schema mismatch rejected loudly
    with pytest.raises(wire.WireError, match="schema mismatch"):
        wire.check_schema({"schema": wire.SCHEMA_VERSION + 1, "kind": "x"})


def test_decode_request_echoes_rid():
    with pytest.raises(wire.WireError, match="rid must be"):
        wire.decode_request({"rid": -1, "prompt": [1]})
    with pytest.raises(wire.WireError, match="prompt must be") as ei:
        wire.decode_request({"rid": 42, "prompt": []})
    assert ei.value.rid == 42
    with pytest.raises(wire.WireError) as ei:
        wire.decode_request({"rid": 7, "prompt": [1, "x"]})
    assert ei.value.rid == 7


# ---------------------------------------------------------------------------
# BatchPolicy: cancellation (server-side shutdown path)
# ---------------------------------------------------------------------------

def test_batch_policy_cancel():
    pol = BatchPolicy(large_batch=4, max_wait=None)
    for i in range(5):
        pol.add(_Pending(i, np.full(8 if i < 3 else 6, i, np.int32), 0.0))
    removed = pol.cancel([1, 3, 99])
    assert sorted(removed) == [1, 3]
    assert pol.n_pending == 3
    out = pol.take(now=0.0, drain=True)
    assert sorted(p.rid for g, _, _ in out for p in g) == [0, 2, 4]
    # cancelling everything leaves no empty groups behind
    pol.add(_Pending(9, np.full(8, 9, np.int32), 0.0))
    assert pol.cancel([9]) == [9]
    assert pol.n_pending == 0 and pol.next_deadline() is None


# ---------------------------------------------------------------------------
# Server/client contract (fake runner: protocol speed)
# ---------------------------------------------------------------------------

def test_socket_backend_submit_poll_drain():
    srv = fake_server()
    try:
        be = SocketBackend(srv.address, request_timeout=5.0)
        reqs = reqs_for([np.full(5, 10 + i, np.int32) for i in range(5)])
        be.submit(reqs[:3])
        be.submit(reqs[3:])
        out = be.drain()
        assert be.n_pending == 0
        assert sorted(r.rid for r in out) == [0, 1, 2, 3, 4]
        for r in out:
            np.testing.assert_array_equal(
                r.tokens, expected_tokens(reqs[r.rid].prompt))
        # batch metadata survives the wire: 2 full batches + 1 drain
        assert len(be.batch_log) == 3
        assert sorted(b["reason"] for b in be.batch_log) \
            == ["drain", "full", "full"]
        be.close()
    finally:
        srv.stop()


def test_server_session_reset_between_runs():
    """Consecutive engine runs reuse rid 0..N; a new client session must
    reset server state so run 2 isn't served run 1's stale results."""
    srv = fake_server()
    try:
        p1 = [np.full(5, 10 + i, np.int32) for i in range(3)]
        be1 = SocketBackend(srv.address, request_timeout=5.0)
        be1.submit(reqs_for(p1))
        out1 = be1.drain()
        be1.close()
        # same rids, DIFFERENT prompts: stale delivery would be wrong
        p2 = [np.full(5, 50 + i, np.int32) for i in range(3)]
        be2 = SocketBackend(srv.address, request_timeout=5.0)
        be2.submit(reqs_for(p2))
        out2 = be2.drain()
        be2.close()
        assert sorted(r.rid for r in out1) == [0, 1, 2]
        assert sorted(r.rid for r in out2) == [0, 1, 2]
        for r in out2:
            np.testing.assert_array_equal(r.tokens,
                                          expected_tokens(p2[r.rid]))
    finally:
        srv.stop()


def test_connection_refused_is_loud_and_fast():
    """No server listening: the backend must raise a clear ConnectionError
    (naming the address and the server entrypoint) quickly, not hang."""
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()                      # port now refuses connections
    t0 = time.perf_counter()
    with pytest.raises(ConnectionError, match="ml_server"):
        SocketBackend(("127.0.0.1", port), connect_timeout=0.2,
                      retries=1, backoff=0.01)
    assert time.perf_counter() - t0 < 5.0


def test_corrupt_payload_rejected_with_rid_echoed():
    """A well-framed but invalid request must be rejected with the
    offending rid echoed — and the server must keep serving."""
    srv = fake_server()
    try:
        s = socket.create_connection(srv.address, timeout=2.0)
        s.settimeout(2.0)
        wire.send_frame(s, wire.envelope("hello", session="bad-client"))
        assert wire.recv_frame(s)["kind"] == "ok"
        wire.send_frame(s, wire.envelope(
            "submit", reqs=[{"rid": 42, "prompt": "not-a-token-list"}]))
        reply = wire.recv_frame(s)
        assert reply["kind"] == "error" and reply["rid"] == 42
        assert "42" in reply["error"]
        # connection survives a payload error: the next RPC still works
        wire.send_frame(s, wire.envelope("health"))
        assert wire.recv_frame(s)["kind"] == "ok"
        s.close()

        # undecodable frame (truncated mid-body): connection dropped,
        # server survives, a fresh client is served normally
        s2 = socket.create_connection(srv.address, timeout=2.0)
        s2.sendall((1000).to_bytes(4, "big") + b"garbage")
        s2.close()
        be = SocketBackend(srv.address, request_timeout=5.0)
        be.submit(reqs_for([np.full(5, 10, np.int32)]))
        assert [r.rid for r in be.drain()] == [0]
        be.close()
    finally:
        srv.stop()


def test_slow_replica_timeout_then_retry_succeeds():
    """Fault injection: the server delays its next responses past the
    client's request timeout; the RPC retries (counter increments), the
    retried submit dedupes server-side, and every result arrives exactly
    once."""
    from repro.serving.obs import MetricsRegistry
    srv = fake_server()
    try:
        reg = MetricsRegistry()
        be = SocketBackend(srv.address, request_timeout=0.15,
                           retries=4, backoff=0.01, registry=reg)
        srv.fault_delay_next = 1
        srv.fault_delay_s = 0.5       # > request_timeout: forces a retry
        be.submit(reqs_for([np.full(5, 10 + i, np.int32)
                            for i in range(3)]))
        out = be.drain()
        assert sorted(r.rid for r in out) == [0, 1, 2]   # exactly once
        assert be.n_pending == 0
        scrape = reg.render()
        assert "serving_ml_rpc_retries_total" in scrape
        retries = [ln for ln in scrape.splitlines()
                   if ln.startswith("serving_ml_rpc_retries_total")]
        assert retries and float(retries[0].split()[-1]) >= 1
        be.close()
    finally:
        srv.stop()


def test_cancel_on_close_withdraws_inflight():
    """Engine shutdown mid-run: close() cancels the backend's in-flight
    rids server-side (pending drops to zero) and the server goes on to
    serve the next client."""
    srv = fake_server(large_batch=64, max_wait=None)   # nothing flushes
    try:
        be = SocketBackend(srv.address, request_timeout=5.0)
        be.submit(reqs_for([np.full(5, 10 + i, np.int32)
                            for i in range(4)]))
        deadline = time.perf_counter() + 2.0
        while srv.n_pending < 4 and time.perf_counter() < deadline:
            time.sleep(0.01)
        assert srv.n_pending == 4
        be.close()                    # cancels rids 0..3
        deadline = time.perf_counter() + 2.0
        while srv.n_pending and time.perf_counter() < deadline:
            time.sleep(0.01)
        assert srv.n_pending == 0
        be2 = SocketBackend(srv.address, request_timeout=5.0)
        be2.submit(reqs_for([np.full(5, 30, np.int32)]))
        assert [r.rid for r in be2.drain()] == [0]
        be2.close()
    finally:
        srv.stop()


# ---------------------------------------------------------------------------
# Replica pool: ejection + re-dispatch (fault injection)
# ---------------------------------------------------------------------------

def test_pool_kill_replica_mid_batch_redispatches():
    """Kill the replica holding in-flight work mid-batch: the pool must
    eject it, re-dispatch the orphans to the survivor, and complete the
    drain with every rid exactly once — zero dropped deferrals."""
    from repro.serving.obs import MetricsRegistry
    slow = fake_server(large_batch=8, max_wait=None, gen_delay=30.0)
    healthy = fake_server()
    reg = MetricsRegistry()
    pool = ReplicaPool([slow.address, healthy.address],
                       request_timeout=1.0, retries=1, backoff=0.01,
                       health_interval=0.05, max_new=4, registry=reg)
    try:
        prompts = [np.full(5, 10 + i, np.int32) for i in range(5)]
        pool.submit(reqs_for(prompts))    # least-loaded tie -> slow (idx 0)
        deadline = time.perf_counter() + 2.0
        while slow.n_pending < 5 and time.perf_counter() < deadline:
            time.sleep(0.01)
        assert slow.n_pending == 5
        slow.kill()                       # abrupt: connections reset
        out = pool.drain()
        assert sorted(r.rid for r in out) == [0, 1, 2, 3, 4]
        assert len(out) == len({r.rid for r in out})     # no duplicates
        for r in out:
            np.testing.assert_array_equal(r.tokens,
                                          expected_tokens(prompts[r.rid]))
        assert pool.n_alive == 1 and pool.n_pending == 0
        scrape = reg.render()
        eject = [ln for ln in scrape.splitlines()
                 if ln.startswith("serving_ml_replica_ejections_total")]
        assert eject and float(eject[0].split()[-1]) == 1
        redis = [ln for ln in scrape.splitlines()
                 if ln.startswith("serving_ml_redispatched_requests_total")]
        assert redis and float(redis[0].split()[-1]) == 5
    finally:
        pool.close()
        healthy.stop()
        slow.stop()


def test_pool_batch_aware_routing_fills_batches():
    """With `large_batch` known, streamed single-request submits stick
    to one replica until its group fills, then move on: every server
    batch flushes `reason="full"` (never waits out max_wait) and both
    replicas get work. Least-loaded spreading would leave every group
    partial — the 2-replica deferral-wait tail would be WORSE than 1."""
    a = fake_server(large_batch=2, max_wait=None, gen_delay=0.25)
    b = fake_server(large_batch=2, max_wait=None, gen_delay=0.25)
    pool = ReplicaPool([a.address, b.address], request_timeout=5.0,
                       health_interval=10.0, max_new=4, large_batch=2)
    try:
        prompts = [np.full(5, 10 + i, np.int32) for i in range(4)]
        for r in reqs_for(prompts):       # streamed, like the engine
            pool.submit([r])
        got = pool.drain()
        assert sorted(r.rid for r in got) == [0, 1, 2, 3]
        for srv in (a, b):                # work landed on BOTH replicas
            batches = srv.batch_log
            assert len(batches) == 1
            assert batches[0]["n_real"] == 2
            assert batches[0]["reason"] == "full"
    finally:
        pool.close()
        a.stop()
        b.stop()


def test_pool_all_replicas_dead_raises():
    srv = fake_server(large_batch=8, max_wait=None, gen_delay=30.0)
    pool = ReplicaPool([srv.address], request_timeout=0.5, retries=0,
                       backoff=0.01, health_interval=0.02, max_new=4)
    try:
        pool.submit(reqs_for([np.full(5, 10, np.int32)]))
        srv.kill()
        with pytest.raises(RemoteBackendError, match="dead"):
            for _ in range(200):          # bounded, must raise not hang
                pool.poll(timeout=0.05)
                time.sleep(0.01)
    finally:
        pool.close()
        srv.stop()


def test_pool_health_check_ejects_silently_dead_replica():
    """A replica that dies while holding NO work is ejected by the
    periodic health probe; the pool keeps serving on the survivor."""
    a = fake_server()
    b = fake_server()
    pool = ReplicaPool([a.address, b.address], request_timeout=1.0,
                       retries=1, backoff=0.01, health_interval=0.05,
                       max_new=4)
    try:
        a.kill()
        time.sleep(0.1)                   # > health_interval
        # an idle poll runs the periodic probe: the dead replica is
        # ejected BEFORE any submit could trip over it
        pool.poll()
        assert pool.n_alive == 1
        prompts = [np.full(5, 10 + i, np.int32) for i in range(4)]
        pool.submit(reqs_for(prompts))
        out = pool.drain()
        assert sorted(r.rid for r in out) == [0, 1, 2, 3]
        assert pool.n_alive == 1
    finally:
        pool.close()
        b.stop()
        a.stop()


# ---------------------------------------------------------------------------
# Engine integration: parity + drain-through-death (real models)
# ---------------------------------------------------------------------------

def _remote_factory(kind, addresses):
    def factory(runner=None, max_new=0, large_batch=None, max_wait=None,
                stub_latency=0.0, registry=None):
        if kind == "socket":
            return SocketBackend(addresses[0], request_timeout=30.0,
                                 registry=registry)
        return ReplicaPool(addresses, request_timeout=30.0,
                           health_interval=0.1, max_new=max_new,
                           large_batch=large_batch, registry=registry)
    return factory


def test_engine_parity_sync_socket_pool(runners):
    """Acceptance: bit-exact greedy outputs across sync (in-process
    reference), socket (one remote replica), and a 2-replica pool, on a
    ragged Poisson workload."""
    small, large = runners
    key = jax.random.PRNGKey(5)
    lens = [6, 10] * 6
    prompts = ragged_prompts(key, lens, small.cfg.vocab_size)
    arrivals = poisson_arrivals(len(prompts), rate=400.0, seed=1)
    for plen in (6, 10):              # pre-warm every M_L jit shape
        large.generate(np.zeros((4, plen), np.int32), plen, 4)
        large.generate(np.zeros((1, plen), np.int32), plen, 4)
        large.generate(np.zeros((2, plen), np.int32), plen, 4)
        large.generate(np.zeros((3, plen), np.int32), plen, 4)

    servers = [MLServer(large, max_new=4, large_batch=4,
                        max_wait=0.02).start() for _ in range(2)]
    try:
        backends = {
            "sync": "sync",
            "socket": _remote_factory("socket", [servers[0].address]),
            "pool": _remote_factory("pool",
                                    [s.address for s in servers]),
        }
        outs = {}
        for name, backend in backends.items():
            eng = ContinuousCascadeEngine(
                small, large, n_slots=4, tau=1e9, min_tokens=2,
                early_exit=True, large_batch=4, large_backend=backend,
                large_max_wait=0.02)
            res = eng.run(make_requests(prompts, 4, arrivals), 4)
            assert all(r.state == DONE for r in res.requests)
            assert res.deferred.all()
            assert res.stats["ml_backend"] == name
            outs[name] = res
        np.testing.assert_array_equal(outs["sync"].tokens,
                                      outs["socket"].tokens)
        np.testing.assert_array_equal(outs["sync"].tokens,
                                      outs["pool"].tokens)
        np.testing.assert_array_equal(outs["sync"].deferred,
                                      outs["pool"].deferred)
    finally:
        for s in servers:
            s.stop()


def test_engine_drain_survives_replica_death(runners):
    """A replica dies while the engine drains: the pool re-dispatches
    its in-flight deferrals and the run completes with every request
    DONE and tokens matching the single-replica reference."""
    small, large = runners
    key = jax.random.PRNGKey(7)
    prompts = ragged_prompts(key, [6] * 8, small.cfg.vocab_size)
    large.generate(np.zeros((4, 6), np.int32), 6, 4)   # pre-warm
    for b in (1, 2, 3):
        large.generate(np.zeros((b, 6), np.int32), 6, 4)

    # doomed hoards work (big batch, huge injected latency per batch);
    # survivor is responsive
    doomed = MLServer(FakeRunner(delay=30.0), max_new=4, large_batch=8,
                      max_wait=None).start()
    survivor = MLServer(large, max_new=4, large_batch=4,
                        max_wait=0.02).start()

    killer_done = threading.Event()

    def kill_when_loaded():
        deadline = time.perf_counter() + 30.0
        while time.perf_counter() < deadline:
            if doomed.n_pending > 0:
                doomed.kill()
                break
            time.sleep(0.005)
        killer_done.set()

    killer = threading.Thread(target=kill_when_loaded, daemon=True)
    killer.start()
    try:
        eng = ContinuousCascadeEngine(
            small, large, n_slots=4, tau=1e9, min_tokens=2,
            early_exit=True, large_batch=8,
            large_backend=_remote_factory(
                "pool", [doomed.address, survivor.address]),
            large_max_wait=None)
        res = eng.run(make_requests(prompts, 4), 4)
        killer_done.wait(timeout=30.0)
        assert all(r.state == DONE for r in res.requests)
        assert res.deferred.all()
        # parity with a direct M_L regeneration of the same prompts
        want, _ = large.generate(np.stack(prompts), 6, 4)
        np.testing.assert_array_equal(res.tokens, want)
    finally:
        survivor.stop()
        doomed.stop()
