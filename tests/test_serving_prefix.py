"""Prefix-sharing / copy-on-write paged-cache tests: hash-chain registry,
ref-count conservation under churn (hypothesis), CoW cloning, the
paged-write aliasing guard (the hazard this machinery exists to prevent),
release-while-shared and cached-block resurrection, reservation
accounting at the CoW worst case, and bit-exact greedy parity of
shared-prefix serving vs unshared runs on both paged decode paths."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_shim import given, settings, st
from repro.configs import get_config, reduced
from repro.data.synthetic import make_lm_stream
from repro.models import transformer as tfm
from repro.models.attention import _paged_write
from repro.serving import (ContinuousCascadeEngine, ModelRunner,
                           PagedCachePool, make_requests)
from repro.serving.paged_pool import prefix_block_keys
from repro.serving.request import DONE, Request
from repro.serving.telemetry import ServingTelemetry


@pytest.fixture(scope="module")
def tiny_cfg():
    return reduced(get_config("internlm2-1.8b"))


@pytest.fixture(scope="module")
def runners():
    key = jax.random.PRNGKey(0)
    s_cfg = reduced(get_config("internlm2-1.8b"))
    l_cfg = s_cfg.replace(name="large", n_layers=3, d_ff=768)
    small = ModelRunner(s_cfg, tfm.init_params(s_cfg, key))
    large = ModelRunner(l_cfg, tfm.init_params(l_cfg,
                                               jax.random.fold_in(key, 1)))
    return small, large


def shared_prefix_prompts(key, n, prefix_len, suffix_len, vocab):
    """`n` prompts sharing one `prefix_len`-token prefix with distinct
    `suffix_len`-token suffixes."""
    base = make_lm_stream(key, n + 1, prefix_len + suffix_len, vocab)
    prefix = np.asarray(base[0, :prefix_len], np.int32)
    return [np.concatenate([prefix, base[i + 1, prefix_len:]]
                           ).astype(np.int32) for i in range(n)]


# ---------------------------------------------------------------------------
# Hash-chain keys
# ---------------------------------------------------------------------------

def test_prefix_block_keys_chain_property():
    a = np.arange(16, dtype=np.int32)
    b = a.copy()
    ka, kb = prefix_block_keys(a, 4), prefix_block_keys(b, 4)
    assert ka == kb and len(ka) == 4
    # diverging block m invalidates keys m.. (chain, not per-block hash)
    c = a.copy()
    c[5] += 1
    kc = prefix_block_keys(c, 4)
    assert kc[0] == ka[0] and all(kc[m] != ka[m] for m in (1, 2, 3))
    # equal blocks at different depths must NOT collide (prefix identity)
    d = np.concatenate([a[4:8], a[4:8]]).astype(np.int32)
    kd = prefix_block_keys(d, 4)
    assert kd[0] != kd[1]
    # partial tail blocks are never keyed
    assert len(prefix_block_keys(a[:15], 4)) == 3
    assert prefix_block_keys(a[:3], 4) == []


# ---------------------------------------------------------------------------
# Pool: share / CoW / release-while-shared / resurrection
# ---------------------------------------------------------------------------

def test_share_cow_release_lifecycle(tiny_cfg):
    pool = PagedCachePool(tiny_cfg, n_slots=3, n_blocks=12, block_size=4,
                          max_len=24)
    toks = np.arange(16, dtype=np.int32)
    s0 = pool.alloc()
    pool.reserve(s0, 19)
    pool.ensure_mapped(s0, 16)
    assert pool.register_prefix(s0, toks) == 4
    pool.check_invariants()

    # sharing maps the registered blocks by refcount, no fresh allocation
    free_before = pool.n_free_blocks
    s1 = pool.alloc()
    pool.reserve(s1, 19)
    assert pool.share_prefix(s1, toks) == 16
    assert pool.n_free_blocks == free_before
    assert (pool.tables[s1, :4] == pool.tables[s0, :4]).all()
    assert all(pool.ref[pool.tables[s1, m]] == 2 for m in range(4))
    pool.check_invariants()

    # the shared span is read-only: a write into it must CoW-clone first
    assert pool.ensure_writable(s1, 15, 16) == 1
    assert pool.tables[s1, 3] != pool.tables[s0, 3]
    assert pool.ref[pool.tables[s1, 3]] == 1
    assert pool.cow_clones == 1
    pool.check_write_disjoint([(s0, 16, 19), (s1, 15, 19)])
    pool.check_invariants()

    # release-while-shared: the donor's still-shared blocks survive
    pool.release(s0)
    pool.check_invariants()
    assert all(pool.ref[pool.tables[s1, m]] == 1 for m in range(4))

    # releasing the last holder caches registered blocks: a later
    # same-prefix request resurrects them even with no donor resident
    pool.release(s1)
    pool.check_invariants()
    s2 = pool.alloc()
    pool.reserve(s2, 19)
    assert pool.share_prefix(s2, toks) == 16
    pool.check_invariants()
    pool.release(s2)
    pool.check_invariants()


def test_shared_blocks_not_double_freed(tiny_cfg):
    """Releasing both holders of a shared block must return it to the
    free list exactly once (refcount, not ownership)."""
    pool = PagedCachePool(tiny_cfg, n_slots=2, n_blocks=8, block_size=4,
                          max_len=16)
    toks = np.arange(8, dtype=np.int32)
    s0 = pool.alloc()
    pool.reserve(s0, 11)
    pool.ensure_mapped(s0, 8)
    pool.register_prefix(s0, toks)
    s1 = pool.alloc()
    pool.reserve(s1, 11)
    pool.share_prefix(s1, toks)
    pool.release(s1)
    pool.check_invariants()
    pool.release(s0)
    pool.check_invariants()
    assert pool.n_free_blocks == 8


def test_partial_share_returns_full_reservation(tiny_cfg):
    """A partially-shared prompt can never CoW (prefill restarts at a
    block boundary), so sharing must hand ALL aliased blocks' owed
    share back — no phantom slack eating admission headroom."""
    pool = PagedCachePool(tiny_cfg, n_slots=3, n_blocks=12, block_size=4,
                          max_len=24)
    toks = np.arange(16, dtype=np.int32)
    s0 = pool.alloc()
    pool.reserve(s0, 19)
    pool.ensure_mapped(s0, 16)
    pool.register_prefix(s0, toks)
    # 12-of-16-token overlap: 3 of 4 prompt blocks match, share partial
    other = np.concatenate([toks[:12], toks[:4] + 100]).astype(np.int32)
    s1 = pool.alloc()
    pool.reserve(s1, 19)                      # 5 blocks
    reserved_before = pool._reserved_total
    assert pool.share_prefix(s1, other) == 12
    # needs exactly blocks 3 (tail of prompt) + 4 (decode) fresh: the
    # 3 aliased blocks' reservation came back in full
    assert reserved_before - pool._reserved_total == 3
    pool.ensure_mapped(s1, 19)
    pool.check_invariants()


def test_cow_reservation_covers_fully_shared_prompt(tiny_cfg):
    """A fully-shared prompt whose tail block must be CoW-cloned cannot
    run out of blocks: share_prefix keeps one owed block of slack, so
    the clone allocates within the reservation even at zero headroom."""
    # budget exactly two requests' worst case: 4 prompt blocks + 1 decode
    pool = PagedCachePool(tiny_cfg, n_slots=2, n_blocks=10, block_size=4,
                          max_len=20)
    toks = np.arange(16, dtype=np.int32)
    s0 = pool.alloc()
    pool.reserve(s0, 19)                      # 5 blocks
    pool.ensure_mapped(s0, 16)
    pool.register_prefix(s0, toks)
    s1 = pool.alloc()
    assert pool.can_reserve(19)
    pool.reserve(s1, 19)
    assert pool.share_prefix(s1, toks) == 16  # all 4 prompt blocks aliased
    # free headroom is now exactly the two slots' unmapped needs; the
    # CoW clone of the recompute block must still succeed
    assert pool.ensure_writable(s1, 15, 16) == 1
    pool.ensure_mapped(s1, 17)                # first decode block
    pool.ensure_mapped(s0, 17)
    pool.check_invariants()


# ---------------------------------------------------------------------------
# The write-aliasing hazard (why CoW exists) + the dispatch guard
# ---------------------------------------------------------------------------

def test_paged_write_aliasing_guard():
    """Two rows whose tables alias one physical block at their write
    positions corrupt each other under the XLA paged scatter — exactly
    the hazard shared blocks introduce. The pool must (a) detect such a
    dispatch via check_write_disjoint and (b) never produce one, because
    ensure_writable CoW-clones the shared block first."""
    # demonstrate the raw hazard: both rows write "their" position of
    # the SAME physical block 3; row 1's value lands in row 0's view
    leaf = jnp.zeros((5, 4, 2), jnp.float32)
    pages = jnp.asarray([[3], [3]], jnp.int32)
    tpos = jnp.asarray([[1], [2]], jnp.int32)
    vals = jnp.asarray([[[1.0, 1.0]], [[2.0, 2.0]]], jnp.float32)
    out = _paged_write(leaf, pages, tpos, vals)
    # row 0's block now ALSO contains row 1's token — shared-state leak
    assert np.asarray(out)[3, 2, 0] == 2.0 and np.asarray(out)[3, 1, 0] == 1.0

    cfg = reduced(get_config("internlm2-1.8b"))
    pool = PagedCachePool(cfg, n_slots=2, n_blocks=8, block_size=4,
                          max_len=16)
    toks = np.arange(8, dtype=np.int32)
    s0 = pool.alloc()
    pool.reserve(s0, 11)
    pool.ensure_mapped(s0, 8)
    pool.register_prefix(s0, toks)
    s1 = pool.alloc()
    pool.reserve(s1, 11)
    pool.share_prefix(s1, toks)
    # both rows "writing" inside the shared span in one dispatch = alias
    with pytest.raises(RuntimeError, match="aliasing"):
        pool.check_write_disjoint([(s0, 4, 8), (s1, 4, 8)])
    # the engine's guard path: make each row's span private first
    pool.ensure_writable(s0, 4, 8)
    pool.ensure_writable(s1, 4, 8)
    pool.check_write_disjoint([(s0, 4, 8), (s1, 4, 8)])
    pool.check_invariants()


def test_cow_clone_preserves_contents(tiny_cfg):
    """cow_clone must copy the donor block's device contents bit-exactly
    into the private clone (reads of the shared prefix stay identical)."""
    pool = PagedCachePool(tiny_cfg, n_slots=2, n_blocks=8, block_size=4,
                          max_len=16, dtype=jnp.float32)
    toks = np.arange(8, dtype=np.int32)
    s0 = pool.alloc()
    pool.reserve(s0, 11)
    pool.ensure_mapped(s0, 8)
    # fill the mapped blocks with recognizable values
    pool.cache = jax.tree.map(
        lambda a: jnp.arange(a.size, dtype=a.dtype).reshape(a.shape),
        pool.cache)
    pool.register_prefix(s0, toks)
    s1 = pool.alloc()
    pool.reserve(s1, 11)
    pool.share_prefix(s1, toks)
    old = int(pool.tables[s1, 1])
    new = pool.cow_clone(s1, 1)
    assert new != old

    def check(leaf, ax):
        l = np.asarray(leaf)
        if ax == 0:
            np.testing.assert_array_equal(l[new], l[old])
        else:
            np.testing.assert_array_equal(l[:, new], l[:, old])
    jax.tree.map(check, pool.cache, pool.block_axes)
    pool.check_invariants()


# ---------------------------------------------------------------------------
# Reservation accounting regressions (heap free lists, over-map)
# ---------------------------------------------------------------------------

def test_overmap_beyond_reservation_raises(tiny_cfg):
    """Regression: ensure_mapped beyond a slot's own reservation used to
    silently pop blocks other slots' reservations were counting on. It
    must now raise when the over-map would break free >= reserved, and
    leave the victim's reservation servable."""
    pool = PagedCachePool(tiny_cfg, n_slots=2, n_blocks=4, block_size=4,
                          max_len=16)
    a = pool.alloc()
    pool.reserve(a, 8)
    pool.ensure_mapped(a, 8)                  # A's reservation fully mapped
    b = pool.alloc()
    pool.reserve(b, 8)                        # free=2 == reserved=2
    with pytest.raises(RuntimeError, match="beyond its reservation"):
        pool.ensure_mapped(a, 12)
    pool.check_invariants()
    pool.ensure_mapped(b, 8)                  # the victim still maps fine
    pool.check_invariants()

    # with real headroom the over-map (padded-chunk slack) is allowed
    pool2 = PagedCachePool(tiny_cfg, n_slots=2, n_blocks=6, block_size=4,
                           max_len=16)
    a2 = pool2.alloc()
    pool2.reserve(a2, 8)
    pool2.ensure_mapped(a2, 8)
    b2 = pool2.alloc()
    pool2.reserve(b2, 8)
    pool2.ensure_mapped(a2, 12)               # headroom: 3 free > 2 reserved
    pool2.check_invariants()
    pool2.ensure_mapped(b2, 8)
    pool2.check_invariants()


def test_free_lists_stay_lowest_id_first(tiny_cfg):
    """The heapq free lists must preserve deterministic lowest-id-first
    allocation across out-of-order releases (the old list.sort
    behavior), and prefer evicting unregistered blocks over cached
    prefixes."""
    pool = PagedCachePool(tiny_cfg, n_slots=3, n_blocks=9, block_size=4,
                          max_len=12)
    slots = [pool.alloc() for _ in range(3)]
    assert slots == [0, 1, 2]
    for s in slots:
        pool.reserve(s, 11)
        pool.ensure_mapped(s, 11)             # 3 blocks each, ids in order
    assert pool.tables[0, :3].tolist() == [1, 2, 3]
    # release out of order; realloc must hand back lowest ids first
    pool.release(slots[2])
    pool.release(slots[0])
    assert pool.alloc() == 0
    pool.reserve(0, 11)
    pool.ensure_mapped(0, 11)
    assert pool.tables[0, :3].tolist() == [1, 2, 3]
    pool.check_invariants()

    # cached (registered) free blocks are evicted only after plain ones
    toks = np.arange(8, dtype=np.int32)
    pool.release(0)
    pool.release(1)
    s = pool.alloc()
    pool.reserve(s, 8)
    pool.ensure_mapped(s, 8)                  # blocks 1, 2
    pool.register_prefix(s, toks)
    pool.release(s)                           # 1, 2 cached; rest plain
    t = pool.alloc()
    pool.reserve(t, 12)
    pool.ensure_mapped(t, 12)
    assert pool.tables[t, :3].tolist() == [3, 4, 5]   # skipped cached 1, 2
    s2 = pool.alloc()
    pool.reserve(s2, 8)
    assert pool.share_prefix(s2, toks) == 8           # cache still intact
    assert pool.tables[s2, :2].tolist() == [1, 2]
    pool.check_invariants()


# ---------------------------------------------------------------------------
# Ref-count conservation under churn (property test)
# ---------------------------------------------------------------------------

_CHURN_CFG = None


def _churn_cfg():
    global _CHURN_CFG
    if _CHURN_CFG is None:
        _CHURN_CFG = reduced(get_config("internlm2-1.8b"))
    return _CHURN_CFG


@given(st.lists(st.tuples(st.integers(0, 4),     # op
                          st.integers(0, 5),     # slot / prompt selector
                          st.integers(1, 24)),   # length / position
                min_size=1, max_size=60),
       st.integers(0, 3))
@settings(max_examples=25, deadline=None)
def test_refcount_invariants_under_churn(ops, pick):
    """Random admit/share/map/write/release churn: refcount conservation
    (sum of table mappings == ref[]; ref-0 set == free set; their union
    partitions {1..n_blocks}), registry consistency, and reservation
    bounds must hold after every single operation. Writes follow the
    engine's discipline — only at or beyond the first unshared token —
    which is what the one-block CoW reservation slack covers."""
    cfg = _churn_cfg()
    pool = PagedCachePool(cfg, n_slots=4, n_blocks=14, block_size=4,
                          max_len=28)
    base = np.arange(64, dtype=np.int32)
    # four prompts with heavy prefix overlap so share/CoW paths trigger
    prompts = [base[:16],
               base[:16].copy(),
               np.concatenate([base[:12], base[40:44]]).astype(np.int32),
               np.concatenate([base[:8], base[48:56]]).astype(np.int32)]
    live = {}                                  # slot -> (prompt, total, start)
    for op, sel, ln in ops:
        if op == 0 and pool.n_free:            # admit
            prompt = prompts[(sel + pick) % len(prompts)]
            total = prompt.shape[0] + (ln % 8)
            if not pool.can_reserve(total):
                continue
            slot = pool.alloc()
            pool.reserve(slot, total)
            shared = pool.share_prefix(slot, prompt)
            assert shared % pool.block_size == 0
            assert shared <= prompt.shape[0]
            pool.ensure_mapped(slot, prompt.shape[0])
            live[slot] = (prompt, total, min(shared, prompt.shape[0] - 1))
        elif op == 1 and live:                 # map further (decode)
            slot = sorted(live)[sel % len(live)]
            prompt, total, _ = live[slot]
            pool.ensure_mapped(slot, min(prompt.shape[0] + ln, total))
        elif op == 2 and live:                 # write at/after the frontier
            slot = sorted(live)[sel % len(live)]
            prompt, total, start = live[slot]
            lo = start + ln % max(total - start, 1)
            pool.ensure_writable(slot, lo, min(lo + 4, total))
        elif op == 3 and live:                 # publish prefix
            slot = sorted(live)[sel % len(live)]
            pool.register_prefix(slot, live[slot][0])
        elif op == 4 and live:                 # retire
            slot = sorted(live)[sel % len(live)]
            pool.release(slot)
            del live[slot]
        pool.check_invariants()
        # every live slot must still be able to map its full reservation
        assert pool.n_free_blocks >= pool._reserved_total
    for slot in sorted(live):
        pool.release(slot)
        pool.check_invariants()
    assert pool.n_free_blocks == pool.n_blocks


# ---------------------------------------------------------------------------
# Engine: shared-prefix greedy parity (the acceptance pin)
# ---------------------------------------------------------------------------

def _paged_engine(small, large, **kw):
    kw.setdefault("n_slots", 2)
    return ContinuousCascadeEngine(small, large, tau=-1e9,
                                   early_exit=False, backend="paged",
                                   block_size=4, prefill_chunk=4, **kw)


@pytest.mark.parametrize("kernel", [False, True],
                         ids=["xla-fallback", "pallas-kernel"])
def test_shared_prefix_parity_bit_exact(runners, kernel):
    """Acceptance: greedy outputs of a shared-prefix run are bit-exact
    vs the unshared run of the identical request stream — on the XLA
    gather fallback AND the interpret-mode paged kernels — and sharing
    actually engaged (prefill-token count strictly drops)."""
    small, large = runners
    prompts = shared_prefix_prompts(jax.random.PRNGKey(5), 4,
                                    prefix_len=12, suffix_len=4,
                                    vocab=small.cfg.vocab_size)
    # single slot: requests run back-to-back, so every later request
    # deterministically shares the first one's cached prefix blocks
    shared = _paged_engine(small, large, n_slots=1, paged_kernel=kernel,
                           prefix_sharing=True).run(
        make_requests(prompts, 5), 5)
    plain = _paged_engine(small, large, n_slots=1, paged_kernel=kernel,
                          prefix_sharing=False).run(
        make_requests(prompts, 5), 5)

    np.testing.assert_array_equal(shared.tokens, plain.tokens)
    np.testing.assert_allclose(shared.confidence, plain.confidence,
                               rtol=1e-5)
    assert shared.stats["shared_tokens"] == 3 * 12
    assert shared.stats["prefill_tokens"] < plain.stats["prefill_tokens"]
    assert plain.stats["shared_tokens"] == 0
    assert all(r.state == DONE for r in shared.requests)
    for r in shared.requests:
        t, c = small.generate(r.prompt[None, :], r.prompt_len, 5)
        np.testing.assert_array_equal(r.tokens, t[0])
        np.testing.assert_allclose(r.confidence, c[0], rtol=1e-5)


@pytest.mark.parametrize("kernel", [False, True],
                         ids=["xla-fallback", "pallas-kernel"])
def test_fully_shared_prompt_cow_parity(runners, kernel):
    """Identical prompts (length a multiple of block_size): the whole
    prompt matches the registry, so the final token is recomputed into
    a CoW-cloned tail block when two sharers are resident. Two slots +
    four requests make wave 2 share wave 1's registered blocks
    concurrently — the clone is deterministic — and every request's
    greedy tokens must equal its standalone generation."""
    small, large = runners
    base = make_lm_stream(jax.random.PRNGKey(9), 1, 16,
                          small.cfg.vocab_size)
    prompts = [np.asarray(base[0], np.int32) for _ in range(4)]
    res = _paged_engine(small, large, n_slots=2, paged_kernel=kernel,
                        prefix_sharing=True).run(
        make_requests(prompts, 4), 4)
    assert res.stats["shared_tokens"] > 0
    assert res.stats["cow_clones"] >= 1       # wave-2 concurrent sharers
    t, _ = small.generate(prompts[0][None, :], 16, 4)
    for r in res.requests:
        np.testing.assert_array_equal(r.tokens, t[0])


def test_shared_prefix_parity_mla(runners):
    """Prefix sharing + CoW must also hold for the MLA compressed-kv
    paged cache (ckv/kr leaves clone together)."""
    key = jax.random.PRNGKey(21)
    cfg = reduced(get_config("deepseek-v2-236b"))
    cfg = cfg.replace(moe=None, family="dense", n_layers=2)
    small = ModelRunner(cfg, tfm.init_params(cfg, key))
    large = ModelRunner(cfg.replace(name="l"),
                        tfm.init_params(cfg, jax.random.fold_in(key, 1)))
    prompts = shared_prefix_prompts(jax.random.fold_in(key, 2), 3,
                                    prefix_len=8, suffix_len=4,
                                    vocab=cfg.vocab_size)
    shared = _paged_engine(small, large, n_slots=1,
                           prefix_sharing=True).run(
        make_requests(prompts, 3), 3)
    plain = _paged_engine(small, large, n_slots=1,
                          prefix_sharing=False).run(
        make_requests(prompts, 3), 3)
    np.testing.assert_array_equal(shared.tokens, plain.tokens)
    assert shared.stats["shared_tokens"] > 0


def test_sharing_disabled_row_matches_pre_sharing_behavior(runners):
    """prefix_sharing=False keeps the pool on the old one-owner-per-
    block path: no shared blocks, no CoW, zero registry traffic."""
    small, large = runners
    prompts = shared_prefix_prompts(jax.random.PRNGKey(6), 3,
                                    prefix_len=12, suffix_len=4,
                                    vocab=small.cfg.vocab_size)
    res = _paged_engine(small, large, prefix_sharing=False).run(
        make_requests(prompts, 4), 4)
    assert res.stats["shared_tokens"] == 0
    assert res.stats["shared_blocks"] == 0
    assert res.stats["cow_clones"] == 0
    assert not res.stats["prefix_sharing"]


# ---------------------------------------------------------------------------
# Telemetry satellites
# ---------------------------------------------------------------------------

def test_telemetry_context_manager_closes_on_error(tmp_path):
    path = str(tmp_path / "audit.jsonl")
    with pytest.raises(ValueError, match="boom"):
        with ServingTelemetry(path) as tel:
            tel.event("admit", rid=0)
            raise ValueError("boom")
    assert tel._fh is None                     # handle released
    assert "admit" in open(path).read()        # buffered event flushed


def test_summary_counts_real_token_lengths():
    """out_tokens must be the tokens actually delivered, not the sum of
    per-request budgets: a clamped / heterogeneous-budget run reports
    the throughput of what it really produced."""
    reqs = []
    for rid, (budget, real) in enumerate([(8, 8), (8, 3), (8, 0)]):
        r = Request(rid=rid, prompt=np.zeros(4, np.int32), max_new=budget)
        r.tokens = np.zeros(real, np.int32) if real else None
        r.t_admit = r.t_retire = r.t_done = 1.0
        reqs.append(r)
    tel = ServingTelemetry()
    s = tel.summary(reqs, makespan=1.0)
    assert s["throughput_tok_s"] == pytest.approx(11.0)   # 8 + 3 + 0
