"""Tests for deferral metrics: s_o, s_d, AUROC, ideal curve (paper §4.1,
App. A.2/B.3)."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.metrics import (auroc, deferral_performance,
                                distributional_overlap, ideal_deferral_curve,
                                pearson_correlation, random_deferral_curve,
                                realized_deferral_curve, summarize_deferral)


def test_ideal_curve_piecewise():
    """eq. (11): linear to the knee at r = 1 - p_s, then flat at p_l."""
    r = np.linspace(0, 1, 101)
    c = ideal_deferral_curve(r, 0.6, 0.9)
    assert c[0] == pytest.approx(0.6)
    knee = 1 - 0.6
    assert c[r <= knee][-1] == pytest.approx(0.9, abs=0.02)
    assert np.all(c[r > knee] == pytest.approx(0.9))
    assert np.all(np.diff(c) >= -1e-12)


def test_ideal_dominates_random():
    r = np.linspace(0, 1, 101)
    assert np.all(ideal_deferral_curve(r, 0.5, 0.9)
                  >= random_deferral_curve(r, 0.5, 0.9) - 1e-12)


def test_sd_oracle_is_one():
    """A confidence that exactly ranks M_S mistakes lowest achieves s_d≈1."""
    rng = np.random.default_rng(0)
    n = 2000
    sc = (rng.random(n) < 0.7).astype(float)
    lc = (rng.random(n) < 0.95).astype(float)
    conf = sc + rng.random(n) * 0.01        # oracle ordering
    res = deferral_performance(conf, sc, lc)
    assert res["s_d"] > 0.97


def test_sd_random_is_zero():
    rng = np.random.default_rng(1)
    n = 4000
    sc = (rng.random(n) < 0.7).astype(float)
    lc = (rng.random(n) < 0.95).astype(float)
    conf = rng.random(n)                     # independent of correctness
    res = deferral_performance(conf, sc, lc)
    assert abs(res["s_d"]) < 0.1


def test_sd_anti_oracle_negative():
    rng = np.random.default_rng(2)
    n = 2000
    sc = (rng.random(n) < 0.7).astype(float)
    lc = np.ones(n)
    conf = -sc + rng.random(n) * 0.01        # defer the CORRECT ones first
    res = deferral_performance(conf, sc, lc)
    assert res["s_d"] < -0.5


def test_realized_curve_endpoints():
    rng = np.random.default_rng(3)
    n = 500
    sc = (rng.random(n) < 0.6).astype(float)
    lc = (rng.random(n) < 0.9).astype(float)
    conf = rng.random(n)
    r, acc = realized_deferral_curve(conf, sc, lc)
    assert acc[0] == pytest.approx(sc.mean())
    assert acc[-1] == pytest.approx(lc.mean())


def test_auroc_perfect_and_random():
    pos = np.linspace(0.6, 1.0, 100)
    neg = np.linspace(0.0, 0.4, 100)
    assert auroc(pos, neg) == pytest.approx(1.0)
    assert auroc(neg, pos) == pytest.approx(0.0)
    rng = np.random.default_rng(4)
    a = rng.random(3000)
    b = rng.random(3000)
    assert auroc(a, b) == pytest.approx(0.5, abs=0.03)


def test_auroc_matches_bruteforce():
    rng = np.random.default_rng(5)
    pos = rng.normal(1, 1, 80)
    neg = rng.normal(0, 1, 60)
    brute = np.mean([(p > n) + 0.5 * (p == n) for p in pos for n in neg])
    assert auroc(pos, neg) == pytest.approx(brute, abs=1e-9)


def test_overlap_bounds_and_separation():
    rng = np.random.default_rng(6)
    same_a = rng.normal(0, 1, 2000)
    same_b = rng.normal(0, 1, 2000)
    far_b = rng.normal(10, 1, 2000)
    s_same = distributional_overlap(same_a, same_b)
    s_far = distributional_overlap(same_a, far_b)
    assert 0.8 < s_same <= 1.05
    assert s_far < 0.02


def test_pearson():
    x = np.arange(100, dtype=float)
    assert pearson_correlation(x, 2 * x + 1) == pytest.approx(1.0)
    assert pearson_correlation(x, -x) == pytest.approx(-1.0)


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 9999), st.floats(0.2, 0.9), st.floats(0.0, 0.3))
def test_property_realized_below_empirical_oracle(seed, ps, gap):
    """True invariant: for every deferral count k, the realized joint
    accuracy (defer the k LEAST confident) cannot exceed the empirical
    oracle (defer the k examples with the largest lc - sc gain). The
    analytic eq.-(11) ideal is NOT a finite-n upper bound when lc
    correlates with the signal, so we check against the oracle instead."""
    rng = np.random.default_rng(seed)
    n = 800
    pl_ = min(ps + gap, 1.0)
    sc = (rng.random(n) < ps).astype(float)
    lc = np.maximum(sc, (rng.random(n) < pl_).astype(float))
    conf = sc * rng.random(n) + rng.random(n) * 0.5   # partially informative

    order = np.argsort(conf)                   # realized: least confident first
    gain = lc - sc
    real_acc = sc.sum() + np.concatenate([[0.0], np.cumsum(gain[order])])
    orac_acc = sc.sum() + np.concatenate([[0.0], np.cumsum(np.sort(gain)[::-1])])
    assert np.all(real_acc <= orac_acc + 1e-9)

    # s_d itself stays finite/sane whenever there is useful headroom
    res = deferral_performance(conf, sc, lc)
    if np.isfinite(res["s_d"]) and res["p_l"] - res["p_s"] > 0.1:
        assert -1.0 <= res["s_d"] <= 1.5


def test_summarize_keys():
    rng = np.random.default_rng(7)
    res = summarize_deferral(rng.random(300),
                             (rng.random(300) < 0.6).astype(float),
                             (rng.random(300) < 0.9).astype(float))
    for k in ("s_d", "s_o", "auroc", "acc_small", "acc_large"):
        assert k in res
