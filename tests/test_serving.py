"""Serving-engine tests: generation, calibration, deferral routing."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.synthetic import make_lm_stream
from repro.models import transformer as tfm
from repro.serving.engine import CascadeEngine, ModelRunner


@pytest.fixture(scope="module")
def runners():
    key = jax.random.PRNGKey(0)
    s_cfg = reduced(get_config("internlm2-1.8b"))
    l_cfg = s_cfg.replace(name="large", n_layers=3, d_ff=768)
    small = ModelRunner(s_cfg, tfm.init_params(s_cfg, key))
    large = ModelRunner(l_cfg, tfm.init_params(l_cfg,
                                               jax.random.fold_in(key, 1)))
    prompts = make_lm_stream(jax.random.fold_in(key, 2), 16, 8,
                             s_cfg.vocab_size)
    return small, large, prompts


def test_generate_shapes(runners):
    small, _, prompts = runners
    toks, conf = small.generate(prompts, 8, 4)
    assert toks.shape == (16, 4)
    assert conf.shape == (16,)
    assert np.isfinite(conf).all()
    assert (conf <= 1e-6).all()        # neg entropy <= 0


def test_generate_deterministic(runners):
    small, _, prompts = runners
    t1, c1 = small.generate(prompts, 8, 4)
    t2, c2 = small.generate(prompts, 8, 4)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_allclose(c1, c2, rtol=1e-6)


def test_cascade_deferral_ratio_calibrated(runners):
    small, large, prompts = runners
    engine = CascadeEngine(small, large)
    engine.calibrate(prompts, 8, 4, deferral_ratio=0.5)
    res = engine.serve(prompts, 8, 4)
    assert 0.2 <= res.deferral_ratio <= 0.8
    assert res.tokens.shape == (16, 4)
    # deferred rows replaced by large-model generations; kept rows untouched
    kept = ~res.deferred
    np.testing.assert_array_equal(res.tokens[kept], res.small_tokens[kept])
    assert res.compute_cost == pytest.approx(0.2 + res.deferral_ratio)


def test_full_and_no_deferral(runners):
    small, large, prompts = runners
    engine = CascadeEngine(small, large, tau=-1e9)
    res = engine.serve(prompts, 8, 4)
    assert res.deferral_ratio == 0.0
    engine.tau = 1e9
    res = engine.serve(prompts, 8, 4)
    assert res.deferral_ratio == 1.0
