"""Tests for the logical-axis sharding rules (divisibility + uniqueness)."""
import subprocess
import sys
import os
import textwrap



_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import sys
    sys.path.insert(0, "src")
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.sharding import logical_to_spec, AbstractParam, tree_shardings
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()                      # (16,16) data,model

    # divisible dims shard
    s = logical_to_spec(("vocab", "embed"), (163840, 7168), mesh)
    assert s == P("model", "data"), s
    # non-divisible dims replicate (GSPMD rejects uneven explicit shardings)
    s = logical_to_spec(("heads", None), (40, 128), mesh)
    assert s == P(None, None), s
    # kv_heads=8 < 16 replicates
    s = logical_to_spec((None, None, "kv_heads", None), (1, 5, 8, 128), mesh)
    assert s[2] is None, s
    # a mesh axis is used at most once per spec: batch=1 frees `data`
    # for the cache_seq dim
    s = logical_to_spec(("batch", "cache_seq", "kv_heads", None),
                        (1, 524288, 8, 128), mesh)
    assert s == P(None, "data", None, None), s
    # batch=128 takes data; cache_seq then replicates
    s = logical_to_spec(("batch", "cache_seq", "kv_heads", None),
                        (128, 32768, 8, 128), mesh)
    assert s == P("data", None, None, None), s

    # multi-pod: batch takes (pod, data)
    mesh2 = make_production_mesh(multi_pod=True)
    s = logical_to_spec(("batch", None), (256, 7), mesh2)
    assert s == P(("pod", "data"), None), s

    # tree_shardings works on AbstractParam trees
    tree = {"w": AbstractParam((512, 256), "float32", ("embed", "ffn"))}
    sh = tree_shardings(tree, mesh)
    assert sh["w"].spec == P("data", "model"), sh
    print("SHARDING_OK")
""")


def test_sharding_rules_on_production_mesh():
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         env=env)
    assert "SHARDING_OK" in res.stdout, res.stderr[-2000:]


def test_param_count():
    from repro.sharding import AbstractParam, param_count
    tree = {"a": AbstractParam((3, 4), "float32", (None, None)),
            "b": AbstractParam((5,), "float32", (None,))}
    assert param_count(tree) == 17


_FLASH_DECODE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.attention import (AttnConfig, init_gqa, init_gqa_cache,
                                        gqa_decode)
    from repro.models.common import ParamFactory
    from repro.sharding import ParallelContext, rules_dict

    cfg = AttnConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=16)
    pf = ParamFactory(jax.random.PRNGKey(0), jnp.float32)
    params = init_gqa(pf, cfg)
    B, S = 4, 64
    cache = {k: jax.random.normal(jax.random.PRNGKey(7), v.shape)
             for k, v in init_gqa_cache(cfg, B, S, jnp.float32).items()}
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, 32))
    pos = jnp.int32(40)
    y_ref, c_ref = gqa_decode(params, cfg, x, pos, cache, ParallelContext())

    from repro.sharding import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = rules_dict({"cache_seq": ("data", "model")})
    ctx = ParallelContext(mesh=mesh, rules=rules)
    y_sh, c_sh = jax.jit(lambda p, x, c: gqa_decode(p, cfg, x, pos, c, ctx))(
        params, x, cache)
    np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(c_sh["k"]), np.asarray(c_ref["k"]),
                               atol=1e-6)
    print("FLASH_DECODE_OK")
""")


def test_flash_decode_seq_sharded_cache_matches_dense():
    """Distributed flash-decode (partial max/lse/pv + psum over the
    seq-sharded KV cache) == single-device decode attention."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", _FLASH_DECODE_SCRIPT],
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         env=env)
    assert "FLASH_DECODE_OK" in res.stdout, res.stderr[-2000:]
