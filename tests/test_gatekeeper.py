"""Unit + property tests for the Gatekeeper loss (paper eqs. 1-5)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.core.gatekeeper import (GatekeeperConfig, cross_entropy,
                                   gatekeeper_loss, kl_to_uniform,
                                   predictive_entropy, standard_ce_loss)


def _logits_labels(seed, n=64, c=10):
    k = jax.random.PRNGKey(seed)
    return (jax.random.normal(k, (n, c)) * 2,
            jax.random.randint(jax.random.fold_in(k, 1), (n,), 0, c))


def test_kl_to_uniform_zero_for_uniform():
    logits = jnp.zeros((8, 12))
    assert float(jnp.abs(kl_to_uniform(logits)).max()) < 1e-6


def test_kl_to_uniform_positive():
    logits, _ = _logits_labels(0)
    assert float(kl_to_uniform(logits).min()) >= -1e-6


def test_ce_matches_nll():
    logits, labels = _logits_labels(1)
    ce = cross_entropy(logits, labels)
    ref = -jax.nn.log_softmax(logits)[jnp.arange(64), labels]
    np.testing.assert_allclose(np.asarray(ce), np.asarray(ref), rtol=1e-6)


def test_loss_decomposition():
    """alpha interpolates between the two branches (eq. 1)."""
    logits, labels = _logits_labels(2)
    losses = {}
    for alpha in (0.1, 0.5, 0.9):
        loss, aux = gatekeeper_loss(logits, labels,
                                    GatekeeperConfig(alpha=alpha))
        losses[alpha] = (float(loss), float(aux["l_corr"]),
                         float(aux["l_incorr"]))
    for alpha, (l, lc, li) in losses.items():
        assert abs(l - (alpha * lc + (1 - alpha) * li)) < 1e-5
    # branch terms are alpha-independent
    assert abs(losses[0.1][1] - losses[0.9][1]) < 1e-6
    assert abs(losses[0.1][2] - losses[0.9][2]) < 1e-6


def test_all_correct_reduces_to_ce_branch():
    """If every prediction is correct, loss = alpha * mean CE."""
    logits = jnp.eye(8) * 10.0
    labels = jnp.arange(8)
    loss, aux = gatekeeper_loss(logits, labels, GatekeeperConfig(alpha=0.7))
    assert float(aux["frac_correct"]) == 1.0
    assert float(aux["l_incorr"]) == 0.0
    ce = cross_entropy(logits, labels).mean()
    np.testing.assert_allclose(float(loss), 0.7 * float(ce), rtol=1e-5)


def test_all_incorrect_reduces_to_kl_branch():
    logits = jnp.eye(8) * 10.0
    labels = (jnp.arange(8) + 1) % 8
    loss, aux = gatekeeper_loss(logits, labels, GatekeeperConfig(alpha=0.7))
    assert float(aux["frac_correct"]) == 0.0
    assert float(aux["l_corr"]) == 0.0
    kl = kl_to_uniform(logits).mean()
    np.testing.assert_allclose(float(loss), 0.3 * float(kl), rtol=1e-5)


def test_gradient_pushes_incorrect_to_uniform():
    """One gradient step on an incorrect example raises its entropy."""
    logits = jnp.array([[4.0, 0.0, 0.0]])
    labels = jnp.array([1])           # predicted 0, incorrect

    def loss_fn(l):
        return gatekeeper_loss(l, labels, GatekeeperConfig(alpha=0.5))[0]

    g = jax.grad(loss_fn)(logits)
    new_logits = logits - 0.5 * g
    assert float(predictive_entropy(new_logits)[0]) > \
        float(predictive_entropy(logits)[0])


def test_gradient_sharpens_correct():
    logits = jnp.array([[1.0, 0.5, 0.0]])
    labels = jnp.array([0])           # predicted 0, correct

    def loss_fn(l):
        return gatekeeper_loss(l, labels, GatekeeperConfig(alpha=0.5))[0]

    g = jax.grad(loss_fn)(logits)
    new_logits = logits - 0.5 * g
    assert float(predictive_entropy(new_logits)[0]) < \
        float(predictive_entropy(logits)[0])


def test_token_level_shape():
    k = jax.random.PRNGKey(0)
    logits = jax.random.normal(k, (4, 9, 17))
    targets = jax.random.randint(k, (4, 9), 0, 17)
    loss, aux = gatekeeper_loss(logits, targets, GatekeeperConfig(alpha=0.4))
    assert np.isfinite(float(loss))


def test_pad_mask_excluded():
    k = jax.random.PRNGKey(0)
    logits = jax.random.normal(k, (4, 9, 17))
    targets = jax.random.randint(k, (4, 9), 1, 17)
    targets = targets.at[:, -3:].set(0)   # pad id 0
    cfg = GatekeeperConfig(alpha=0.5, mask_pad=0)
    loss_pad, _ = gatekeeper_loss(logits, targets, cfg)
    # corrupting pad-position logits must not change the loss
    logits2 = logits.at[:, -3:, :].set(99.0)
    loss_pad2, _ = gatekeeper_loss(logits2, targets, cfg)
    np.testing.assert_allclose(float(loss_pad), float(loss_pad2), rtol=1e-6)


def test_soft_targets():
    k = jax.random.PRNGKey(3)
    logits = jax.random.normal(k, (16, 6))
    teacher = jax.nn.softmax(jax.random.normal(jax.random.fold_in(k, 1),
                                               (16, 6)) * 2)
    cfg = GatekeeperConfig(alpha=0.5, soft_targets=True)
    loss, aux = gatekeeper_loss(logits, teacher, cfg)
    assert np.isfinite(float(loss))


@settings(deadline=None, max_examples=30)
@given(st.integers(0, 10000), st.floats(0.05, 0.95),
       st.integers(2, 32), st.integers(1, 64))
def test_property_loss_finite_nonneg(seed, alpha, c, n):
    k = jax.random.PRNGKey(seed)
    logits = jax.random.normal(k, (n, c)) * 3
    labels = jax.random.randint(jax.random.fold_in(k, 1), (n,), 0, c)
    loss, aux = gatekeeper_loss(logits, labels, GatekeeperConfig(alpha=alpha))
    assert np.isfinite(float(loss))
    assert float(loss) >= -1e-6
    assert float(aux["l_incorr"]) >= -1e-6     # KL >= 0
    # entropy bounded by log C
    assert float(aux["mean_entropy"]) <= np.log(c) + 1e-4


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 10000))
def test_property_ce_loss_accuracy_consistent(seed):
    k = jax.random.PRNGKey(seed)
    logits = jax.random.normal(k, (32, 7))
    labels = jnp.argmax(logits, -1)
    _, aux = standard_ce_loss(logits, labels)
    assert float(aux["accuracy"]) == 1.0
