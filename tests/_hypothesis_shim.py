"""Optional-`hypothesis` shim for the property-test modules.

The tier-1 environment does not ship `hypothesis`; importing it at module
scope used to abort collection of four test modules (and, with `-x`, the
whole suite). Importing from this shim instead keeps every plain pytest
test runnable and turns only the `@given`-decorated property tests into
skips when `hypothesis` is absent.

Usage (in a test module):

    from _hypothesis_shim import HAVE_HYPOTHESIS, given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                            # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for `hypothesis.strategies`: any attribute access or
        call returns itself, so strategy expressions evaluated at
        decoration time (`st.integers(1, 8).filter(...)`) don't blow up."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (optional extra)")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco
