"""Paged KV-cache serving tests: block alloc/free + reservation
invariants, page-table gather vs dense reads, slot-vs-paged greedy
parity, ragged mixed-length admission, chunked prefill, and the
memory-budget regime the slot backend cannot fit."""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.synthetic import make_lm_stream
from repro.models import transformer as tfm
from repro.models.attention import gather_blocks
from repro.serving import (CascadeEngine, ContinuousCascadeEngine,
                           ModelRunner, PagedCachePool, SlotCachePool,
                           make_requests, poisson_arrivals)
from repro.serving.paged_pool import gather_pages
from repro.serving.request import DONE


@pytest.fixture(scope="module")
def runners():
    key = jax.random.PRNGKey(0)
    s_cfg = reduced(get_config("internlm2-1.8b"))
    l_cfg = s_cfg.replace(name="large", n_layers=3, d_ff=768)
    small = ModelRunner(s_cfg, tfm.init_params(s_cfg, key))
    large = ModelRunner(l_cfg, tfm.init_params(l_cfg,
                                               jax.random.fold_in(key, 1)))
    prompts = make_lm_stream(jax.random.fold_in(key, 2), 16, 8,
                             s_cfg.vocab_size)
    return small, large, prompts


@pytest.fixture(scope="module")
def tiny_cfg():
    return reduced(get_config("internlm2-1.8b"))


def ragged_prompts(key, lens, vocab):
    base = make_lm_stream(key, len(lens), max(lens), vocab)
    return [base[i, :n].astype(np.int32) for i, n in enumerate(lens)]


# ---------------------------------------------------------------------------
# Pool: block alloc/free + reservation invariants
# ---------------------------------------------------------------------------

def test_paged_pool_alloc_free_invariants(tiny_cfg):
    pool = PagedCachePool(tiny_cfg, n_slots=3, n_blocks=8, block_size=4,
                          max_len=20)
    pool.check_invariants()
    assert pool.blocks_for(1) == 1 and pool.blocks_for(4) == 1
    assert pool.blocks_for(5) == 2 and pool.blocks_for(0) == 0

    # admit two requests: reserve worst case, map prompts lazily
    a = pool.alloc()
    pool.reserve(a, 11)                    # 3 blocks owed
    pool.ensure_mapped(a, 6)               # 2 mapped, 1 still owed
    b = pool.alloc()
    pool.reserve(b, 8)                     # 2 blocks owed
    pool.check_invariants()
    assert pool.n_mapped[a] == 2 and pool.n_mapped[b] == 0
    assert pool.n_free_blocks == 6
    # trash block 0 never handed out; mapped ids unique and nonzero
    assert (pool.tables[a, :2] > 0).all()

    # remaining capacity: 6 free - 3 outstanding reserved = 3 blocks
    assert pool.can_reserve(12) and not pool.can_reserve(13)

    # mapping inside the reservation can never fail, even when free
    # would appear exhausted to a naive allocator
    pool.ensure_mapped(a, 11)
    pool.ensure_mapped(b, 8)
    pool.check_invariants()
    assert pool.n_mapped[a] == 3 and pool.n_mapped[b] == 2

    # release returns blocks AND zeroes the table row (stale decode
    # writes from the dead tenant must land in the trash block)
    pool.release(a)
    assert (pool.tables[a] == 0).all()
    assert pool.n_free_blocks == 6
    pool.check_invariants()

    # slot ids recycle lowest-first with generation counters
    c = pool.alloc()
    assert c == a and pool.generations[a] == 2
    with pytest.raises(RuntimeError):
        pool.release(2)                    # slot that is not in use
    pool.release(b)
    pool.release(c)
    pool.check_invariants()
    assert pool.n_free == 3 and pool.n_free_blocks == 8


def test_paged_pool_rejects_unpageable_families(tiny_cfg):
    rwkv = reduced(get_config("rwkv6-3b"))
    with pytest.raises(NotImplementedError):
        PagedCachePool(rwkv, 2, 8, 4, 16)
    windowed = tiny_cfg.replace(sliding_window=8)
    with pytest.raises(NotImplementedError):
        PagedCachePool(windowed, 2, 8, 4, 16)


# ---------------------------------------------------------------------------
# Page-table gather == dense read
# ---------------------------------------------------------------------------

def test_gather_blocks_matches_manual():
    leaf = jnp.arange(5 * 4 * 3, dtype=jnp.float32).reshape(5, 4, 3)
    pages = jnp.asarray([[2, 1], [3, 0]], jnp.int32)
    out = np.asarray(gather_blocks(leaf, pages))
    leaf_np = np.asarray(leaf)
    np.testing.assert_array_equal(out[0],
                                  np.concatenate([leaf_np[2], leaf_np[1]]))
    np.testing.assert_array_equal(out[1],
                                  np.concatenate([leaf_np[3], leaf_np[0]]))


def test_page_table_gather_equals_dense_slot_read(tiny_cfg):
    """Write the same prefilled rows into a dense slot pool and (block by
    block) into a paged pool; the page-table gather must reproduce the
    dense per-slot view exactly."""
    bs, max_len = 4, 12
    paged = PagedCachePool(tiny_cfg, n_slots=2, n_blocks=6, block_size=bs,
                           max_len=max_len)
    dense = SlotCachePool(tiny_cfg, n_slots=2, max_len=max_len,
                          dtype=jnp.float32)
    for slot in range(2):
        assert paged.alloc() == slot
        paged.reserve(slot, max_len)
        paged.ensure_mapped(slot, max_len)

    row = tfm.init_cache(tiny_cfg, 2, max_len, dtype=jnp.float32)
    row = jax.tree.map(
        lambda a: jnp.arange(a.size, dtype=jnp.float32).reshape(a.shape), row)
    dense.write_rows(row, [0, 1])
    # scatter the same rows block-wise into the paged leaves
    def scatter(paged_leaf, row_leaf, ax):
        assert ax in (0, 1)
        for slot in range(2):
            for m in range(max_len // bs):
                blk = int(paged.tables[slot, m])
                sl_p = (slice(None),) * ax + (blk,)
                sl_r = (slice(None),) * ax + (slot,
                                              slice(m * bs, (m + 1) * bs))
                paged_leaf = paged_leaf.at[sl_p].set(row_leaf[sl_r])
        return paged_leaf
    paged.cache = jax.tree.map(scatter, paged.cache, row, paged.block_axes)

    view = gather_pages(paged.cache, jnp.asarray(paged.tables),
                        paged.block_axes)
    for g, d, ax in zip(jax.tree.leaves(view), jax.tree.leaves(dense.cache),
                        jax.tree.leaves(dense.batch_axes)):
        d_np = np.moveaxis(np.asarray(d), ax, 0) if ax else np.asarray(d)
        g_np = np.moveaxis(np.asarray(g), ax, 0) if ax else np.asarray(g)
        # gathered view is max_blocks*bs long; valid prefix must match
        np.testing.assert_array_equal(g_np[:, :max_len] if ax == 0
                                      else g_np[:, :, :max_len],
                                      d_np if ax == 0 else d_np)


# ---------------------------------------------------------------------------
# Engine parity: slot vs paged
# ---------------------------------------------------------------------------

def test_uniform_parity_slot_vs_paged(runners):
    """Acceptance: on a uniform workload the paged backend reproduces the
    slot backend (and hence the static cascade) token for token under
    greedy decoding, including deferral routing."""
    small, large, prompts = runners
    static = CascadeEngine(small, large)
    tau = static.calibrate(prompts, 8, 4, deferral_ratio=0.5)
    sres = static.serve(prompts, 8, 4)

    slot = ContinuousCascadeEngine(small, large, n_slots=8, tau=tau,
                                   early_exit=False, backend="slot")
    slot_res = slot.run(make_requests(prompts, 4), 4)
    paged = ContinuousCascadeEngine(small, large, n_slots=8, tau=tau,
                                    early_exit=False, backend="paged",
                                    block_size=4)
    paged_res = paged.run(make_requests(prompts, 4), 4)

    np.testing.assert_array_equal(paged_res.tokens, slot_res.tokens)
    np.testing.assert_array_equal(paged_res.tokens, sres.tokens)
    np.testing.assert_array_equal(paged_res.deferred, sres.deferred)
    np.testing.assert_allclose(paged_res.confidence, slot_res.confidence,
                               rtol=1e-6)
    assert paged_res.stats["backend"] == "paged"
    assert paged_res.stats["peak_blocks"] <= paged_res.stats["n_blocks"]


def test_ragged_parity_vs_single_run(runners):
    """Mixed-length admission on BOTH backends: every request's greedy
    output must equal a standalone single-request generation."""
    small, large, _ = runners
    key = jax.random.PRNGKey(7)
    lens = [5, 9, 4, 12, 7, 6, 10, 4]
    prompts = ragged_prompts(key, lens, small.cfg.vocab_size)
    for backend, kw in (("slot", {}),
                        ("paged", dict(block_size=4, prefill_chunk=4))):
        eng = ContinuousCascadeEngine(small, large, n_slots=3, tau=-1e9,
                                      early_exit=False, backend=backend,
                                      **kw)
        res = eng.run(make_requests(prompts, 5), 5)
        assert all(r.state == DONE for r in res.requests)
        for r in res.requests:
            t, c = small.generate(r.prompt[None, :], r.prompt_len, 5)
            np.testing.assert_array_equal(r.tokens, t[0])
            np.testing.assert_allclose(r.confidence, c[0], rtol=1e-5)


def test_chunked_prefill_does_not_perturb_residents(runners, tmp_path):
    """A long prompt prefilled in chunks while two residents decode must
    leave the residents' tokens AND confidences bit-identical to their
    standalone runs — and the audit log must show the chunked prefill
    actually interleaved with resident decoding."""
    small, large, _ = runners
    key = jax.random.PRNGKey(11)
    prompts = ragged_prompts(key, [6, 6, 14], small.cfg.vocab_size)
    reqs = make_requests(prompts, 10)
    reqs[0].max_new = 4          # retires early, freeing a slot for rid 2
    audit = str(tmp_path / "audit.jsonl")
    eng = ContinuousCascadeEngine(small, large, n_slots=2, tau=-1e9,
                                  early_exit=False, backend="paged",
                                  block_size=4, prefill_chunk=3)
    res = eng.run(reqs, 10, audit_path=audit)
    for r in res.requests:
        t, c = small.generate(r.prompt[None, :], r.prompt_len, r.max_new)
        np.testing.assert_array_equal(r.tokens[:r.max_new], t[0])
        np.testing.assert_allclose(r.confidence, c[0], rtol=1e-5)
    assert res.stats["prefill_chunks"] >= math.ceil(14 / 3) + 2

    events = [json.loads(l) for l in open(audit)]
    kinds = [(e["event"], e.get("rid")) for e in events]
    # rid 2 was admitted only after rid 0 retired, and its chunked
    # prefill finished BEFORE resident rid 1 retired -> interleaved
    assert kinds.index(("retire", 0)) < kinds.index(("prefill_done", 2))
    assert kinds.index(("prefill_done", 2)) < kinds.index(("retire", 1))


def test_paged_serves_budget_slot_cannot_fit(runners):
    """Acceptance: a ragged mixed-length Poisson workload served by the
    paged backend inside a block budget strictly smaller than the slot
    pool's worst-case footprint — with MORE concurrent requests than a
    dense pool of the same byte budget could even hold rows for."""
    small, large, _ = runners
    key = jax.random.PRNGKey(13)
    lens = [4, 4, 4, 4, 4, 4, 4, 4, 10, 4, 4, 4]      # mostly short
    prompts = ragged_prompts(key, lens, small.cfg.vocab_size)
    max_new, bs, n_blocks, n_slots = 4, 4, 12, 6
    max_len = max(lens) + max_new                       # 14

    eng = ContinuousCascadeEngine(small, large, n_slots=n_slots, tau=-1e9,
                                  early_exit=False, backend="paged",
                                  block_size=bs, n_blocks=n_blocks,
                                  prefill_chunk=4)
    arrivals = poisson_arrivals(len(prompts), rate=500.0, seed=13)
    res = eng.run(make_requests(prompts, max_new, arrivals), max_new)
    assert all(r.state == DONE for r in res.requests)
    for r in res.requests:
        t, _ = small.generate(r.prompt[None, :], r.prompt_len, max_new)
        np.testing.assert_array_equal(r.tokens, t[0])

    # paged physical budget (12 blocks of 4 = 48 logical tokens) is far
    # below the slot pool's worst case (6 slots x 14 = 84)
    slot_pool = SlotCachePool(small.cfg, n_slots, max_len)
    assert res.stats["cache_bytes"] < slot_pool.footprint_bytes()
    # a dense pool squeezed into the same token budget affords only
    # 48 // 14 = 3 worst-case rows; the paged run actually sustained more
    dense_affordable = (n_blocks * bs) // max_len
    assert res.stats["peak_active"] > dense_affordable
    assert res.stats["peak_blocks"] <= n_blocks


def test_oversized_request_rejected(runners):
    small, large, _ = runners
    prompts = ragged_prompts(jax.random.PRNGKey(17), [16], 64)
    eng = ContinuousCascadeEngine(small, large, n_slots=2, backend="paged",
                                  block_size=4, n_blocks=2)
    with pytest.raises(ValueError, match="largest request"):
        eng.run(make_requests(prompts, 4), 4)


def test_mla_paged_parity():
    """Paged gather/scatter must also hold for the MLA compressed-kv
    cache (ckv + rope-key leaves page independently of head count)."""
    key = jax.random.PRNGKey(3)
    cfg = reduced(get_config("deepseek-v2-236b"))
    cfg = cfg.replace(moe=None, family="dense", n_layers=2)
    small = ModelRunner(cfg, tfm.init_params(cfg, key))
    large = ModelRunner(cfg.replace(name="l"), tfm.init_params(
        cfg, jax.random.fold_in(key, 1)))
    prompts = make_lm_stream(jax.random.fold_in(key, 2), 4, 8,
                             cfg.vocab_size)
    static = CascadeEngine(small, large, tau=-1e9)
    sres = static.serve(prompts, 8, 3)
    cont = ContinuousCascadeEngine(small, large, n_slots=2, tau=-1e9,
                                   early_exit=False, backend="paged",
                                   block_size=4, prefill_chunk=3)
    cres = cont.run(make_requests(prompts, 3), 3)
    np.testing.assert_array_equal(cres.tokens, sres.tokens)


# ---------------------------------------------------------------------------
# run() signature: prompt_len removed
# ---------------------------------------------------------------------------

def test_run_prompt_len_removed(runners):
    small, large, prompts = runners
    eng = ContinuousCascadeEngine(small, large, n_slots=2)
    reqs = make_requests(prompts[:2], 4)
    with pytest.raises(TypeError, match="prompt_len"):
        eng.run(reqs, 8, 4)                 # old positional call shape
    with pytest.raises(TypeError, match="prompt_len"):
        eng.run(reqs, prompt_len=8)
    with pytest.raises(ValueError, match="prompt_len"):
        eng.serve(prompts[:2], 99, 4)       # mismatched width
