"""Launch-layer tests: fused chunked loss, roofline parsing, specs, and a
dry-run lowering smoke (subprocess with 512 host devices, shallow configs)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.core.gatekeeper import GatekeeperConfig, gatekeeper_loss
from repro.launch import roofline as rf
from repro.launch.steps import chunked_gatekeeper_loss, fused_confidence


def test_chunked_loss_matches_reference():
    k = jax.random.PRNGKey(0)
    B, S, d, V = 3, 7, 16, 64
    x = jax.random.normal(k, (B, S, d))
    table = jax.random.normal(jax.random.fold_in(k, 1), (V, d))
    tgt = jax.random.randint(k, (B, S), 0, V)
    gk = GatekeeperConfig(alpha=0.3)
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    l_ref, _ = gatekeeper_loss(logits, tgt, gk)
    l_chk, _ = chunked_gatekeeper_loss(x, table, tgt, gk, n_chunks=4)
    assert abs(float(l_ref - l_chk)) < 1e-5
    g_ref = jax.grad(lambda x: gatekeeper_loss(
        jnp.einsum("bsd,vd->bsv", x, table), tgt, gk)[0])(x)
    g_chk = jax.grad(lambda x: chunked_gatekeeper_loss(
        x, table, tgt, gk, n_chunks=4)[0])(x)
    np.testing.assert_allclose(np.asarray(g_ref), np.asarray(g_chk),
                               atol=1e-6)


def test_fused_confidence_matches():
    k = jax.random.PRNGKey(1)
    x = jax.random.normal(k, (5, 16))
    table = jax.random.normal(jax.random.fold_in(k, 1), (48, 16))
    ne, mp, am = fused_confidence(x, table, n_chunks=4)
    logits = jnp.einsum("td,vd->tv", x, table).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, -1)
    np.testing.assert_allclose(np.asarray(ne),
                               np.asarray((jnp.exp(logp) * logp).sum(-1)),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(am),
                                  np.asarray(logits.argmax(-1)))


def test_collective_bytes_parser():
    hlo = """
  %all-reduce.1 = f32[16,512]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[16,16]<=[256], use_global_device_ids=true
  %all-gather.2 = bf16[32,128]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %notacollective = f32[8]{0} add(%a, %b)
"""
    out = rf.collective_bytes(hlo)
    ar = 2 * 16 * 512 * 4 * 15 / 16
    ag = 32 * 128 * 2 * 3 / 4
    assert out["all-reduce"] == pytest.approx(ar)
    assert out["all-gather"] == pytest.approx(ag)
    assert out["count"] == 2


def test_analytic_model_flops_sane():
    cfg = get_config("internlm2-1.8b").replace(param_dtype="bfloat16")
    n = rf.active_matmul_params(cfg)
    assert 1.5e9 < n < 2.2e9             # ~1.8B params
    f_train = rf.analytic_model_flops(cfg, SHAPES["train_4k"])
    assert f_train > 6 * n * 4096 * 256  # at least 6ND
    f_dec = rf.analytic_model_flops(cfg, SHAPES["decode_32k"])
    assert f_dec < f_train


def test_moe_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    total_active = rf.active_matmul_params(cfg)
    # Kimi K2: ~1T total, ~32B active -> active matmul params well under 60B
    assert total_active < 6e10, total_active


_DRYRUN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import sys
    sys.path.insert(0, "src")
    from repro.launch.dryrun import lower_combo
    from repro.configs import get_config
    import repro.configs as C

    # shallow variants of three families through the REAL dry-run path
    import repro.launch.dryrun as dr
    for arch in ["internlm2-1.8b", "kimi-k2-1t-a32b"]:
        shape = "train_4k"
        res = dr.lower_combo(arch, shape, multi_pod=False, verbose=False,
                             skip_extrapolation=True)
        assert res["t_compile_s"] >= 0
    print("DRYRUN_SMOKE_OK")
""")


@pytest.mark.slow
def test_dryrun_lowering_smoke():
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", _DRYRUN_SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         env=env)
    assert "DRYRUN_SMOKE_OK" in res.stdout, res.stderr[-3000:]


def test_microbatched_train_step_matches_full_batch():
    """Grad accumulation (microbatches=4) == one full-batch step: same
    loss and same updated params (valid_mask is all-ones, so per-
    microbatch means average exactly to the full-batch mean)."""
    from repro.configs import ModelConfig
    from repro.launch.steps import make_train_step
    from repro.models import transformer as tfm
    from repro.sharding import ParallelContext
    from repro.training import optim

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64,
                      vocab_size=64, tie_embeddings=True,
                      param_dtype="float32", compute_dtype="float32")
    ctx = ParallelContext()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.adamw_init(params)
    k = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(k, (8, 12), 0, 64),
             "targets": jax.random.randint(jax.random.fold_in(k, 1),
                                           (8, 12), 0, 64)}
    step1 = make_train_step(cfg, ctx, microbatches=1)
    step4 = make_train_step(cfg, ctx, microbatches=4)
    p1, o1, m1 = jax.jit(step1)(params, opt, batch)
    p4, o4, m4 = jax.jit(step4)(params, opt, batch)
    assert abs(float(m1["loss"] - m4["loss"])) < 1e-5
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5), p1, p4)


_PERF_VARIANT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import sys
    sys.path.insert(0, "src")
    from repro.launch.dryrun import lower_combo

    # the three §Perf winning configurations, shallow-depth, through the
    # REAL lowering path — locks in the rule-override plumbing
    kw = dict(multi_pod=False, verbose=False, skip_extrapolation=True)
    r = lower_combo("kimi-k2-1t-a32b", "decode_32k",
                    rule_overrides={"expert_embed": (),
                                    "expert_ffn": ("data",),
                                    "cache_seq": ("data", "model"),
                                    "unembed_d": ("data",)},
                    cfg_overrides={"n_layers": 3}, **kw)
    assert r["collectives"]["all-gather"] < 5e9, r["collectives"]
    r = lower_combo("qwen1.5-32b", "prefill_32k",
                    rule_overrides={"seq": ("model",)},
                    cfg_overrides={"attn_chunk": 1024, "n_layers": 2,
                                   "scan_layers": False}, **kw)
    assert r["t_compile_s"] >= 0
    r = lower_combo("llama3-405b", "train_4k", remat="full",
                    rule_overrides=None,
                    opt_rule_overrides={"embed": ("data", "model")},
                    cfg_overrides={"n_layers": 2, "scan_layers": False,
                                   "microbatches": 4}, **kw)
    assert r["t_compile_s"] >= 0
    print("PERF_VARIANTS_OK")
""")


def test_perf_variant_configs_lower():
    """The §Perf winning rule/config combinations keep lowering+compiling
    (shallow depths): gather-tokens MoE decode, chunked+seq-parallel
    prefill, remat+microbatch+ZeRO-1 train."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", _PERF_VARIANT_SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         env=env)
    assert "PERF_VARIANTS_OK" in res.stdout, res.stderr[-3000:]
