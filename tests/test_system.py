"""End-to-end behaviour tests for the paper's system.

The headline claim (paper Figs. 4-6): Gatekeeper fine-tuning at low alpha
improves deferral performance s_d and correct/incorrect separation (AUROC up,
s_o down) relative to the untuned baseline, at some cost in raw accuracy.
We verify this end-to-end at CPU scale on the synthetic classification task.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core.cascade import Cascade
from repro.core.gatekeeper import GatekeeperConfig
from repro.core.metrics import summarize_deferral
from repro.data.pipeline import BatchIterator
from repro.data.synthetic import make_classification
from repro.models.classifier import (MLPClassifierConfig, classifier_forward,
                                     init_classifier)
from repro.training import optim
from repro.training.loop import evaluate_classifier, make_train_step, train


@pytest.fixture(scope="module")
def cascade_setup():
    """M_S: small MLP trained to interpolation on few samples (overconfident
    on test errors); M_L: larger MLP + more data (learns the parity tier).
    Stage-2 Gatekeeper uses a held-out calibration split (see
    bench_fig4_classification.py docstring for the rationale)."""
    key = jax.random.PRNGKey(0)
    train_small = make_classification(key, 2000, n_classes=8, hard_frac=0.45)
    train_large = make_classification(jax.random.fold_in(key, 5), 12000,
                                      n_classes=8, hard_frac=0.45)
    cal_data = make_classification(jax.random.fold_in(key, 7), 3000,
                                   n_classes=8, hard_frac=0.45)
    test_data = make_classification(jax.random.fold_in(key, 1), 3000,
                                    n_classes=8, hard_frac=0.45)
    d_in = train_small.x.shape[1]
    s_cfg = MLPClassifierConfig(d_in=d_in, n_classes=8, hidden=(64, 64))
    l_cfg = MLPClassifierConfig(d_in=d_in, n_classes=8, hidden=(256, 256))

    def make(cfg, data, seed, steps):
        params = init_classifier(cfg, jax.random.PRNGKey(seed))
        apply_fn = lambda p, b: classifier_forward(p, cfg, b["inputs"])
        it = BatchIterator({"inputs": data.x, "targets": data.y},
                           256, key=jax.random.PRNGKey(seed))
        step = make_train_step(apply_fn,
                               optim.AdamWConfig(lr=3e-3, total_steps=steps),
                               loss_kind="ce")
        return train(params, step, it.forever(), steps, log_every=1000).params

    small = make(s_cfg, train_small, 1, 1500)
    large = make(l_cfg, train_large, 2, 2500)
    return dict(train=train_small, cal=cal_data, test=test_data,
                s_cfg=s_cfg, l_cfg=l_cfg, small=small, large=large)


def _deferral_metrics(setup, small_params):
    s_cfg, l_cfg = setup["s_cfg"], setup["l_cfg"]
    test = setup["test"]
    sp, sconf, scorr = evaluate_classifier(
        lambda p, x: classifier_forward(p, s_cfg, x), small_params,
        test.x, test.y)
    lp, _, lcorr = evaluate_classifier(
        lambda p, x: classifier_forward(p, l_cfg, x), setup["large"],
        test.x, test.y)
    return summarize_deferral(sconf, scorr, lcorr)


def test_capacity_gap_exists(cascade_setup):
    """Setup sanity: M_L is genuinely stronger than M_S (paper assumption)."""
    base = _deferral_metrics(cascade_setup, cascade_setup["small"])
    assert base["acc_large"] > base["acc_small"] + 0.1


def test_gatekeeper_improves_deferral(cascade_setup):
    """Gatekeeper (alpha=0.2) improves s_d and AUROC, reduces s_o vs the
    untuned baseline — the paper's central claim."""
    setup = cascade_setup
    base = _deferral_metrics(setup, setup["small"])

    s_cfg = setup["s_cfg"]
    apply_fn = lambda p, b: classifier_forward(p, s_cfg, b["inputs"])
    it = BatchIterator({"inputs": setup["cal"].x,
                        "targets": setup["cal"].y}, 256,
                       key=jax.random.PRNGKey(7))
    step = make_train_step(apply_fn,
                           optim.AdamWConfig(lr=5e-3, total_steps=1500),
                           loss_kind="gatekeeper",
                           gk_cfg=GatekeeperConfig(alpha=0.1))
    tuned = train(setup["small"], step, it.forever(), 1500,
                  log_every=10000).params
    gk = _deferral_metrics(setup, tuned)

    assert gk["s_d"] > base["s_d"], (gk["s_d"], base["s_d"])
    assert gk["auroc"] > base["auroc"]
    assert gk["s_o"] < base["s_o"]


def test_cascade_end_to_end_cost_accuracy(cascade_setup):
    """At a 30% deferral budget the cascade beats M_S alone on accuracy and
    costs less than always calling M_L."""
    setup = cascade_setup
    s_cfg, l_cfg = setup["s_cfg"], setup["l_cfg"]
    test = setup["test"]
    c = Cascade(
        small_apply=lambda p, x: classifier_forward(p, s_cfg, x),
        large_apply=lambda p, x: classifier_forward(p, l_cfg, x),
        small_params=setup["small"], large_params=setup["large"],
        signal="max_softmax", cost_small=0.2)
    c.calibrate_tau(jnp.asarray(test.x[:1000]), deferral_ratio=0.3)
    res = c.predict_sparse(jnp.asarray(test.x[1000:]))
    y = test.y[1000:]
    acc_joint = (res.predictions == y).mean()
    acc_small = (res.small_predictions == y).mean()
    assert acc_joint > acc_small
    assert res.compute_cost < 1.0
