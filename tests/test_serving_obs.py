"""Observability-layer tests: metrics registry (Prometheus rendering,
pull-mode gauges, fixed-bucket histograms), the /metrics HTTP endpoint,
Chrome-trace export + schema/nesting validation (golden test against a
real engine run, cross-checked against the JSONL audit log), bounded
telemetry retention + streaming audit flush, device-time attribution,
and the parity contract: greedy outputs are bit-exact with the full
observability surface on vs off, on both KV backends."""
import json
import pathlib
import sys
import urllib.error
import urllib.request

import numpy as np
import pytest

import jax

from repro.configs import get_config, reduced
from repro.data.synthetic import make_lm_stream
from repro.models import transformer as tfm
from repro.serving import (ContinuousCascadeEngine, ModelRunner,
                           make_requests)
from repro.serving.obs import (DeviceTimer, MetricsRegistry, MetricsServer,
                               ObsConfig, Observability, ProfilerWindow,
                               Tracer, validate_chrome_trace)
from repro.serving.request import Request
from repro.serving.telemetry import ServingTelemetry


@pytest.fixture(scope="module")
def runners():
    key = jax.random.PRNGKey(0)
    s_cfg = reduced(get_config("internlm2-1.8b"))
    l_cfg = s_cfg.replace(name="large", n_layers=3, d_ff=768)
    small = ModelRunner(s_cfg, tfm.init_params(s_cfg, key))
    large = ModelRunner(l_cfg, tfm.init_params(l_cfg,
                                               jax.random.fold_in(key, 1)))
    prompts = make_lm_stream(jax.random.fold_in(key, 2), 6, 10,
                             s_cfg.vocab_size)
    return small, large, prompts


@pytest.fixture(scope="module")
def tau_mixed(runners):
    """A threshold that defers roughly half the fixture prompts, so the
    traced run exercises both the keep and the defer/M_L path."""
    small, _, prompts = runners
    _, conf = small.generate(prompts, 10, 6)
    return float(np.median(conf))


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_counter_labels_and_render():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests", labels=("outcome",))
    c.labels(outcome="ok").inc()
    c.labels(outcome="ok").inc(2)
    c.labels(outcome="err").inc()
    out = reg.render()
    assert "# HELP req_total requests" in out
    assert "# TYPE req_total counter" in out
    assert 'req_total{outcome="ok"} 3.0' in out
    assert 'req_total{outcome="err"} 1.0' in out
    assert out.endswith("\n")


def test_gauge_push_and_pull():
    reg = MetricsRegistry()
    g = reg.gauge("depth", "queue depth")
    g.set(4)
    assert g.value == 4.0
    state = {"n": 0}
    reg.gauge("live", "pull-mode", fn=lambda: state["n"])
    state["n"] = 7     # mutated after registration: read at render time
    assert "live 7.0" in reg.render()
    state["n"] = 9
    assert "live 9.0" in reg.render()


def test_histogram_cumulative_buckets_and_inf():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v)
    out = reg.render()
    assert 'lat_bucket{le="0.1"} 1' in out
    assert 'lat_bucket{le="1.0"} 3' in out
    assert 'lat_bucket{le="+Inf"} 4' in out
    assert "lat_count 4" in out
    sum_line = next(l for l in out.splitlines()
                    if l.startswith("lat_sum"))
    assert float(sum_line.split()[1]) == pytest.approx(6.05)


def test_registry_get_or_create_and_collision():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "x")
    assert reg.counter("x_total") is a        # get-or-create
    with pytest.raises(ValueError):
        reg.gauge("x_total")                  # type collision
    with pytest.raises(ValueError):
        reg.counter("x_total", labels=("k",))  # label collision
    with pytest.raises(ValueError):
        a.labels(wrong="v")                   # unknown label name


def test_label_value_escaping():
    reg = MetricsRegistry()
    c = reg.counter("esc_total", "", labels=("v",))
    c.labels(v='a"b\\c\nd').inc()
    assert r'esc_total{v="a\"b\\c\nd"} 1.0' in reg.render()


# ---------------------------------------------------------------------------
# /metrics endpoint
# ---------------------------------------------------------------------------

def test_metrics_http_endpoint():
    reg = MetricsRegistry()
    reg.counter("scraped_total", "scrapes").inc(3)
    srv = MetricsServer(reg, port=0).start()
    try:
        with urllib.request.urlopen(srv.url, timeout=5) as resp:
            body = resp.read().decode()
            assert resp.status == 200
            assert resp.headers["Content-Type"].startswith(
                "text/plain; version=0.0.4")
        assert "scraped_total 3.0" in body
        # pull-mode gauges are live per scrape, not a snapshot
        reg.counter("scraped_total").inc()
        with urllib.request.urlopen(srv.url, timeout=5) as resp:
            assert "scraped_total 4.0" in resp.read().decode()
        bad = urllib.request.Request(
            f"http://{srv.host}:{srv.port}/nope")
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(bad, timeout=5)
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# Tracer + validator
# ---------------------------------------------------------------------------

def test_tracer_export_schema(tmp_path):
    tr = Tracer()
    tr.name_process(1, "engine")
    tr.name_thread(1, 0, "loop")
    tr.complete("outer", "t", 0.0, 1.0, tid=0)
    tr.complete("inner", "t", 0.2, 0.3, tid=0)
    tr.instant("mark", "t", 0.5, tid=0)
    path = tmp_path / "t.json"
    tr.export(str(path))
    obj = json.loads(path.read_text())
    spans = validate_chrome_trace(obj)
    assert [s["name"] for s in spans] == ["outer", "inner"]
    meta = [e for e in obj["traceEvents"] if e["ph"] == "M"]
    assert {m["name"] for m in meta} == {"process_name", "thread_name"}
    assert obj["displayTimeUnit"] == "ms"


def test_validator_rejects_partial_overlap():
    tr = Tracer()
    tr.complete("a", "t", 0.0, 1.0, tid=0)
    tr.complete("b", "t", 0.5, 1.0, tid=0)    # overlaps, not nested
    with pytest.raises(AssertionError, match="overlaps"):
        validate_chrome_trace(tr.export_obj())
    # same spans on DIFFERENT tracks are fine
    tr2 = Tracer()
    tr2.complete("a", "t", 0.0, 1.0, tid=0)
    tr2.complete("b", "t", 0.5, 1.0, tid=1)
    assert len(validate_chrome_trace(tr2.export_obj())) == 2


def test_validator_rejects_malformed_events():
    with pytest.raises(AssertionError):
        validate_chrome_trace({"notTraceEvents": []})
    with pytest.raises(AssertionError):
        validate_chrome_trace({"traceEvents": [
            {"name": "x", "ph": "X", "pid": 1, "tid": 0, "ts": 1.0}]})


# ---------------------------------------------------------------------------
# Device timer / profiler window
# ---------------------------------------------------------------------------

def test_device_timer_split():
    import time
    x = jax.numpy.ones((64, 64))
    off = DeviceTimer(enabled=False)
    t0 = time.perf_counter()
    y = x @ x
    host, dev = off.split(t0, y)
    assert host >= 0 and dev == 0.0
    on = DeviceTimer(enabled=True)
    t0 = time.perf_counter()
    y = x @ x
    host, dev = on.split(t0, y)
    assert host >= 0 and dev >= 0.0         # blocked until ready


def test_profiler_window_state_machine(monkeypatch):
    calls = []
    monkeypatch.setattr(jax.profiler, "start_trace",
                        lambda d: calls.append(("start", d)))
    monkeypatch.setattr(jax.profiler, "stop_trace",
                        lambda: calls.append(("stop",)))
    w = ProfilerWindow("/tmp/prof", n_iters=3)
    for _ in range(6):
        w.tick()
    w.close()
    w.close()                               # idempotent
    assert calls == [("start", "/tmp/prof"), ("stop",)]
    # disabled window never touches the profiler
    calls.clear()
    w2 = ProfilerWindow(None)
    w2.tick()
    w2.close()
    assert calls == []


# ---------------------------------------------------------------------------
# Telemetry retention / audit flush / summary keys
# ---------------------------------------------------------------------------

def test_event_retention_modes(tmp_path):
    tel = ServingTelemetry(max_events=3)
    for i in range(5):
        tel.event("step", i=i)
    assert tel.n_events == 5
    assert [e["i"] for e in tel.events] == [2, 3, 4]    # ring of last 3
    tel0 = ServingTelemetry(max_events=0)
    tel0.event("step")
    assert tel0.n_events == 1 and len(tel0.events) == 0
    # the audit log streams every event regardless of retention
    path = tmp_path / "audit.jsonl"
    tel_a = ServingTelemetry(str(path), max_events=0)
    for i in range(4):
        tel_a.event("step", i=i)
    tel_a.close()
    assert [json.loads(l)["i"] for l in path.read_text().splitlines()] \
        == [0, 1, 2, 3]


def test_audit_flush_every(tmp_path):
    path = tmp_path / "audit.jsonl"
    tel = ServingTelemetry(str(path), flush_every=2)
    tel.event("a")
    tel.event("b")          # hits the flush cadence
    tel.event("c")          # buffered
    flushed = path.read_text().splitlines()
    assert len(flushed) >= 2
    tel.close()
    assert len(path.read_text().splitlines()) == 3


def test_summary_queueing_and_phase_keys():
    def req(rid, arrival, admit, done):
        r = Request(rid=rid, prompt=np.zeros(4, np.int32), max_new=3,
                    arrival_time=arrival)
        r.t_admit, r.t_done = admit, done
        r.tokens = np.zeros(3, np.int32)
        r.n_small_steps = 3
        return r
    tel = ServingTelemetry()
    tel.phase_add("decode", 1.5)
    tel.phase_add("decode", 0.5, device_s=0.25)
    tel.phase_add("prefill", 0.75)
    reqs = [req(0, 0.0, 0.1, 1.0), req(1, 0.0, 0.3, 1.2)]
    s = tel.summary(reqs, makespan=2.0)
    assert s["queueing_p95_s"] == pytest.approx(0.29, abs=1e-6)
    assert s["phase_decode_s"] == pytest.approx(2.0)
    assert s["phase_prefill_s"] == pytest.approx(0.75)
    assert "device_timing" not in s          # mode was off
    tel.obs.device_timer.enabled = True
    s2 = tel.summary(reqs, makespan=2.0)
    assert s2["device_timing"] is True
    assert s2["phase_decode_device_s"] == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# Engine integration: golden trace, audit cross-check, parity, metrics
# ---------------------------------------------------------------------------

def _order_preserved(audit_ts, span_ts):
    """Every strict ordering in the audit log must be preserved by the
    trace span edges (ties in either are allowed — retirements within
    one sync share a timestamp)."""
    rids = list(audit_ts)
    for i, a in enumerate(rids):
        for b in rids[i + 1:]:
            if audit_ts[a] < audit_ts[b]:
                assert span_ts[a] <= span_ts[b] + 1e-6, (a, b)


@pytest.mark.parametrize("backend", ["slot", "paged"])
def test_trace_golden_and_obs_parity(runners, tau_mixed, tmp_path,
                                     backend):
    small, large, prompts = runners
    eng = ContinuousCascadeEngine(
        small, large, n_slots=3, tau=tau_mixed, min_tokens=2,
        early_exit=True, large_backend="thread", large_batch=2,
        large_max_wait=0.01, steps_per_sync=1, backend=backend,
        block_size=4, prefill_chunk=4)
    arrivals = np.linspace(0.0, 0.05, len(prompts))
    trace_path = tmp_path / f"trace_{backend}.json"
    metrics_path = tmp_path / f"metrics_{backend}.prom"
    audit_path = tmp_path / f"audit_{backend}.jsonl"
    cfg = ObsConfig(trace_path=str(trace_path),
                    metrics_path=str(metrics_path),
                    device_timing=True)
    res_on = eng.run(make_requests(prompts, 6, arrivals), 6,
                     audit_path=str(audit_path), obs=cfg)

    # -- golden: schema-valid, properly nested Chrome trace -------------
    obj = json.loads(trace_path.read_text())
    spans = validate_chrome_trace(obj)
    names = {s["name"] for s in spans}
    assert {"iteration", "decode", "prefill", "queued"} <= names
    by_req = {}
    for s in spans:
        if s["pid"] == 2:
            by_req.setdefault(s["tid"], {})[s["name"]] = s
    assert len(by_req) == len(prompts)       # one track per request
    for rid, sp in by_req.items():
        q, p, d = sp["queued"], sp["prefill"], sp["decode"]
        # lifecycle spans abut: queued -> prefill -> decode
        assert q["ts"] + q["dur"] == pytest.approx(p["ts"], abs=1.0)
        assert p["ts"] + p["dur"] == pytest.approx(d["ts"], abs=1.0)
        # per-token confidence record on the decode span
        conf = d["args"]["conf"]
        assert len(conf) == d["args"]["n_tokens"]
        req = res_on.requests[rid]
        assert len(conf) == req.n_small_steps
        assert np.mean(conf) == pytest.approx(req.confidence, abs=1e-4)
        if req.deferred:
            assert "ml_wait" in sp

    # -- audit-log cross-check: span edges preserve event order ---------
    audit = [json.loads(l) for l in audit_path.read_text().splitlines()]
    admit_ts = {r: e["t"] for e in audit if e["event"] == "admit"
                for r in e["rids"]}
    retire_ts = {e["rid"]: e["t"] for e in audit
                 if e["event"] == "retire"}
    assert set(admit_ts) == set(by_req)
    _order_preserved(admit_ts,
                     {r: sp["queued"]["ts"] + sp["queued"]["dur"]
                      for r, sp in by_req.items()})
    _order_preserved(retire_ts,
                     {r: sp["decode"]["ts"] + sp["decode"]["dur"]
                      for r, sp in by_req.items()})

    # -- metrics dump ---------------------------------------------------
    prom = metrics_path.read_text()
    for want in ("serving_tokens_total", "serving_requests_total",
                 "serving_decode_step_seconds_bucket",
                 "serving_phase_seconds_total",
                 "serving_ml_queue_depth", "serving_active_slots"):
        assert want in prom, want
    if backend == "paged":
        assert 'serving_pool_blocks{kind="total"}' in prom
    n_small = sum(len(r.tokens) for r in res_on.requests
                  if not r.deferred)
    assert f'serving_tokens_total{{model="small"}} {float(n_small)!r}' \
        in prom

    # -- device timing surfaced in the summary --------------------------
    assert res_on.stats["device_timing"] is True
    assert res_on.stats["phase_decode_device_s"] >= 0.0
    assert res_on.stats["queueing_p95_s"] >= 0.0

    # -- parity: bit-exact greedy outputs with observability off --------
    res_off = eng.run(make_requests(prompts, 6, arrivals), 6)
    assert np.array_equal(res_on.tokens, res_off.tokens)
    np.testing.assert_allclose(res_on.confidence, res_off.confidence,
                               rtol=0, atol=0)
    assert np.array_equal(res_on.deferred, res_off.deferred)


def test_caller_owned_observability_not_finished(runners, tau_mixed,
                                                 tmp_path):
    """A prebuilt Observability is fed but never exported by the engine:
    the caller decides when to finish (serve.py keeps /metrics open)."""
    small, large, prompts = runners
    eng = ContinuousCascadeEngine(small, large, n_slots=3, tau=tau_mixed,
                                  early_exit=False, steps_per_sync=1)
    trace_path = tmp_path / "t.json"
    obs = Observability(ObsConfig(trace_path=str(trace_path)))
    eng.run(make_requests(prompts, 4), 4, obs=obs)
    assert not trace_path.exists()          # engine did not finish it
    assert obs.registry.get("serving_tokens_total") is not None
    obs.finish()
    validate_chrome_trace(json.loads(trace_path.read_text()))


def test_bench_serving_obs_row_and_trace(runners, tmp_path, monkeypatch):
    """`bench_serving --trace-out` emits a valid Chrome trace and the
    gated continuous+obs row + queueing/phase keys in the bench record
    (acceptance criterion for the CI observability gate)."""
    root = pathlib.Path(__file__).resolve().parents[1]
    if str(root) not in sys.path:
        sys.path.insert(0, str(root))
    import benchmarks.bench_serving as bs
    monkeypatch.setattr(bs, "save_result", lambda *a, **k: None)
    monkeypatch.setattr(bs, "emit_csv_row", lambda *a, **k: None)
    trace_path = tmp_path / "bench_trace.json"
    payload = bs.run(n_requests=4, max_new=4, slots=2,
                     ragged_min=8, ragged_max=8,
                     obs_cfg=ObsConfig(trace_path=str(trace_path)))
    validate_chrome_trace(json.loads(trace_path.read_text()))
    engines = [r["engine"] for r in payload["rows"]]
    assert "continuous+obs" in engines and "continuous" in engines
    assert payload["obs_overhead"] is not None
    rec = bs.bench_record(payload)
    row = next(r for r in rec["rows"] if r["engine"] == "continuous")
    assert row["queueing_p95_s"] is not None
    assert "decode" in row["phase_breakdown_s"]
