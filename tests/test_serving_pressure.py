"""Memory-pressure serving tests: KV-cache oversubscription with the
preempt / defer-on-OOM / shed pressure policies, host swap tier,
admission overload control (bounded queue + deadlines), loud release
semantics, and property-based churn over the paged pool.

The headline guarantees pinned here:

  * an oversubscribed engine under a block budget far below worst-case
    reservation demand completes 100% of requests with greedy tokens
    BIT-EXACT against an unconstrained run (preemption is invisible to
    the output);
  * defer-on-OOM escalates victims up the cascade ladder with
    ``deferred_reason == "oom"``;
  * shed / queue-bound / deadline paths land requests in the
    REJECTED / EXPIRED terminal states with empty outputs, exactly once;
  * a preempted request re-enters the arrival queue ahead of
    never-admitted arrivals (age priority — repeated preemption cannot
    starve it behind fresh traffic).
"""
import numpy as np
import pytest

import jax

from _hypothesis_shim import given, settings, st
from repro.configs import get_config, reduced
from repro.data.synthetic import make_lm_stream
from repro.models import transformer as tfm
from repro.serving import (BlockPressure, CascadeSpec,
                           ContinuousCascadeEngine, EngineConfig,
                           MLBackendConfig, ModelRunner, PagedCachePool,
                           PagedConfig, PressureConfig, SlotScheduler,
                           make_requests)
from repro.serving.request import (DONE, EXPIRED, REJECTED, ArrivalQueue,
                                   Request)

MAX_NEW = 10
BS = 4
SLOTS = 4
TIGHT = 16        # demand of a full slot set is 8 blocks/req = 2x this
GENEROUS = 64     # no pressure possible


@pytest.fixture(scope="module")
def runners():
    key = jax.random.PRNGKey(0)
    s_cfg = reduced(get_config("internlm2-1.8b"))
    l_cfg = s_cfg.replace(name="large", n_layers=3, d_ff=768)
    small = ModelRunner(s_cfg, tfm.init_params(s_cfg, key))
    large = ModelRunner(l_cfg, tfm.init_params(l_cfg,
                                               jax.random.fold_in(key, 1)))
    rng = np.random.default_rng(7)
    lens = rng.integers(6, 21, size=10)
    base = make_lm_stream(jax.random.fold_in(key, 2), 10, 20,
                          s_cfg.vocab_size)
    prompts = [np.asarray(base[i, :n]).astype(np.int32)
               for i, n in enumerate(lens)]
    return small, large, prompts


@pytest.fixture(scope="module")
def tiny_cfg():
    return reduced(get_config("internlm2-1.8b"))


def make_engine(small, large, *, n_blocks, pressure=None, slots=SLOTS,
                max_queue=None, deadline_s=None):
    return ContinuousCascadeEngine(
        CascadeSpec.two_tier(small, large, tau=-1e9),
        EngineConfig(n_slots=slots, early_exit=False, steps_per_sync=4,
                     backend="paged", max_queue=max_queue,
                     deadline_s=deadline_s,
                     ml=MLBackendConfig(kind="sync", large_batch=slots),
                     paged=PagedConfig(block_size=BS, n_blocks=n_blocks,
                                       prefill_chunk=4,
                                       pressure=pressure)))


@pytest.fixture(scope="module")
def unconstrained(runners):
    """Reference run with a generous budget: no pressure, no shedding."""
    small, large, prompts = runners
    eng = make_engine(small, large, n_blocks=GENEROUS)
    return eng.run(make_requests(prompts, MAX_NEW), MAX_NEW)


def assert_terminal_exactly_once(res, n):
    """Every request reaches exactly one terminal state; DONE requests
    carry a full generation, shed requests an empty one."""
    assert len(res.requests) == n
    assert len({r.rid for r in res.requests}) == n
    s = res.stats
    assert s["n_completed"] + s["n_rejected"] + s["n_expired"] == n
    for r in res.requests:
        if r.state == DONE:
            assert r.tokens is not None and len(r.tokens) == r.max_new
        else:
            assert r.shed and len(r.tokens) == 0


# ---------------------------------------------------------------------------
# Tentpole: oversubscription + preempt policy, bit-exact under pressure
# ---------------------------------------------------------------------------

def test_oversubscribed_preempt_completes_bit_exact(runners, unconstrained):
    """2x+ reservation demand on a tight budget: the preempt policy must
    complete every request with tokens identical to the unconstrained
    run — save/restore of the decode-written KV region plus bit-exact
    prompt re-prefill make preemption invisible to greedy outputs."""
    small, large, prompts = runners
    eng = make_engine(
        small, large, n_blocks=TIGHT,
        pressure=PressureConfig(oversubscribe=4.0, policy="preempt",
                                max_preemptions=50, swap_blocks=8))
    res = eng.run(make_requests(prompts, MAX_NEW), MAX_NEW)
    assert res.stats["n_preemptions"] > 0
    assert res.stats["n_completed"] == len(prompts)
    assert_terminal_exactly_once(res, len(prompts))
    assert all(r.state == DONE and r.tier == 0 for r in res.requests)
    np.testing.assert_array_equal(res.tokens, unconstrained.tokens)


def test_preemption_bound_escalates_to_oom_deferral(runners, unconstrained):
    """max_preemptions=1 on a thrashing workload: victims past the bound
    escalate up the ladder (deferred_reason == "oom") instead of cycling
    forever; everything still completes, and requests that never left
    tier 0 stay bit-exact."""
    small, large, prompts = runners
    eng = make_engine(
        small, large, n_blocks=TIGHT,
        pressure=PressureConfig(oversubscribe=4.0, policy="preempt",
                                max_preemptions=1))
    res = eng.run(make_requests(prompts, MAX_NEW), MAX_NEW)
    assert res.stats["n_completed"] == len(prompts)
    assert all(r.n_preempted <= 1 for r in res.requests)
    oom = [r for r in res.requests if r.deferred_reason == "oom"]
    assert res.stats["oom_deferrals"] == len(oom) > 0
    assert all(r.deferred and r.state == DONE for r in oom)
    for i, r in enumerate(res.requests):
        if not r.deferred:
            np.testing.assert_array_equal(r.tokens,
                                          unconstrained.requests[i].tokens)


def test_defer_on_oom_policy(runners, unconstrained):
    """The defer policy never resumes a victim: every eviction goes up
    the ladder immediately, tagged as an OOM deferral."""
    small, large, prompts = runners
    eng = make_engine(
        small, large, n_blocks=TIGHT,
        pressure=PressureConfig(oversubscribe=4.0, policy="defer"))
    res = eng.run(make_requests(prompts, MAX_NEW), MAX_NEW)
    assert res.stats["n_completed"] == len(prompts)
    assert res.stats["n_preemptions"] == 0
    assert res.stats["oom_deferrals"] > 0
    for i, r in enumerate(res.requests):
        assert r.state == DONE
        if not r.deferred:
            np.testing.assert_array_equal(r.tokens,
                                          unconstrained.requests[i].tokens)


def test_shed_policy_rejects_deterministically(runners, unconstrained):
    """The shed policy trades completion for latency: pressure victims
    land in REJECTED with empty outputs; survivors are untouched
    (bit-exact vs the unconstrained run)."""
    small, large, prompts = runners
    eng = make_engine(
        small, large, n_blocks=TIGHT,
        pressure=PressureConfig(oversubscribe=4.0, policy="shed"))
    res = eng.run(make_requests(prompts, MAX_NEW), MAX_NEW)
    s = res.stats
    assert s["n_rejected"] > 0
    assert s["n_completed"] + s["n_rejected"] == len(prompts)
    assert s["shed_ratio"] == pytest.approx(s["n_rejected"] / len(prompts))
    assert_terminal_exactly_once(res, len(prompts))
    for i, r in enumerate(res.requests):
        if r.state == REJECTED:
            assert r.shed and len(r.tokens) == 0
        else:
            np.testing.assert_array_equal(r.tokens,
                                          unconstrained.requests[i].tokens)


def test_hostile_trace_no_starvation(runners):
    """Hostile trace: uniform prompts cross block boundaries in lockstep,
    so pressure recurs every few steps. The age-priority requeue +
    preemption bound must still drive every request to completion with
    its per-request preemption count within the bound."""
    small, large, _ = runners
    vocab = small.cfg.vocab_size
    prompts = [np.full(12, (i * 17) % vocab, dtype=np.int32)
               for i in range(8)]
    eng = make_engine(
        small, large, n_blocks=12,
        pressure=PressureConfig(oversubscribe=4.0, policy="preempt",
                                max_preemptions=3))
    res = eng.run(make_requests(prompts, MAX_NEW), MAX_NEW)
    assert res.stats["n_completed"] == len(prompts)
    assert res.stats["n_preemptions"] > 0
    assert all(r.n_preempted <= 3 for r in res.requests)


# ---------------------------------------------------------------------------
# Admission overload control: bounded queue + deadlines
# ---------------------------------------------------------------------------

def test_queue_bound_sheds_newest(runners):
    """max_queue trims the ready set to the OLDEST entries; the shed
    requests end REJECTED with empty outputs and the survivors drain
    normally."""
    small, large, prompts = runners
    eng = make_engine(small, large, n_blocks=GENEROUS, max_queue=2)
    res = eng.run(make_requests(prompts, MAX_NEW), MAX_NEW)
    s = res.stats
    assert s["n_completed"] == 2 and s["n_rejected"] == len(prompts) - 2
    assert_terminal_exactly_once(res, len(prompts))
    assert sorted(r.rid for r in res.requests if r.state == DONE) == [0, 1]
    assert all(r.state == REJECTED
               for r in res.requests if r.rid >= 2)


def test_deadline_expires_queued_requests(runners):
    """A deadline far shorter than the service time expires requests
    stuck behind a single slot; requests already admitted are finished,
    never killed in flight."""
    small, large, prompts = runners
    eng = make_engine(small, large, n_blocks=GENEROUS, slots=1,
                      deadline_s=0.01)
    res = eng.run(make_requests(prompts[:6], MAX_NEW), MAX_NEW)
    s = res.stats
    assert s["n_expired"] >= 1 and s["n_completed"] >= 1
    assert s["n_completed"] + s["n_expired"] == 6
    assert_terminal_exactly_once(res, 6)
    done = [r for r in res.requests if r.state == DONE]
    assert all(r.state == EXPIRED for r in res.requests if r not in done)


def test_requeue_age_priority_unit():
    """A preempted request re-enters keyed on its ORIGINAL arrival time:
    it pops before every never-admitted arrival still waiting."""
    mk = lambda rid, t: Request(rid=rid, prompt=np.arange(4, dtype=np.int32),
                                max_new=4, arrival_time=t)
    old, mid, new = mk(0, 0.0), mk(1, 1.0), mk(2, 2.0)
    q = ArrivalQueue([mid, new])
    q.release(5.0)
    q.requeue(old)                      # preempted at t=4, arrived at t=0
    assert [q.pop_ready().rid for _ in range(3)] == [0, 1, 2]

    # overflow shedding keeps the OLDEST max_queue entries
    q = ArrivalQueue([mk(i, float(i)) for i in range(5)], max_queue=2)
    q.release(10.0)
    shed = q.shed_overflow()
    assert [r.rid for r in shed] == [2, 3, 4]
    assert q.pop_ready().rid == 0


# ---------------------------------------------------------------------------
# Pool: oversubscription accounting, loud release, swap tier, snapshots
# ---------------------------------------------------------------------------

def test_pool_oversubscription_accounting(tiny_cfg):
    pool = PagedCachePool(tiny_cfg, n_slots=3, n_blocks=8, block_size=4,
                          max_len=40, oversubscribe=2.0)
    assert pool.virtual_blocks == 16
    a, b, c = pool.alloc(), pool.alloc(), pool.alloc()
    for s in (a, b, c):
        pool.reserve(s, 20)             # 5 blocks each: 15 <= 16 virtual
    pool.check_invariants()
    # physical exhaustion raises BlockPressure instead of the
    # reservation-invariant RuntimeError
    pool.ensure_mapped(a, 20)
    pool.ensure_mapped(b, 12)           # 8 physical blocks now mapped
    with pytest.raises(BlockPressure):
        pool.ensure_mapped(c, 4)
    pool.check_invariants()             # failed map left the books sound
    assert pool.n_mapped[c] == 0
    # relief: release a victim, the retry succeeds
    pool.release(a)
    pool.ensure_mapped(c, 4)
    pool.check_invariants()

    # a non-oversubscribed pool can never reach BlockPressure: the same
    # over-demand is refused at reservation time
    flat = PagedCachePool(tiny_cfg, n_slots=3, n_blocks=8, block_size=4,
                          max_len=40)
    s0 = flat.alloc()
    flat.reserve(s0, 20)
    assert not flat.can_reserve(20)     # 10 > 8 physical


def test_pool_release_is_loudly_idempotent(tiny_cfg):
    pool = PagedCachePool(tiny_cfg, n_slots=2, n_blocks=8, block_size=4,
                          max_len=16)
    a = pool.alloc()
    gen = pool.generations[a]
    pool.reserve(a, 8)
    pool.ensure_mapped(a, 8)
    pool.release(a, expected_generation=gen)
    with pytest.raises(RuntimeError, match="double release"):
        pool.release(a)
    # stale release: slot re-allocated to a new tenant since the caller
    # captured its generation
    b = pool.alloc()
    assert b == a
    with pytest.raises(RuntimeError, match="stale release"):
        pool.release(b, expected_generation=gen)
    pool.release(b, expected_generation=pool.generations[b])
    pool.check_invariants()


def test_pool_save_restore_span_round_trip(tiny_cfg):
    pool = PagedCachePool(tiny_cfg, n_slots=1, n_blocks=4, block_size=4,
                          max_len=16)
    a = pool.alloc()
    pool.reserve(a, 8)
    pool.ensure_mapped(a, 8)
    saved = pool.save_block_span(a, 0, 8)
    assert len(saved) == 2
    # clobber the mapped blocks, then restore the snapshot verbatim
    blks = [int(pool.tables[a, m]) for m in range(2)]

    def zero(leaf, ax):
        for blk in blks:
            leaf = (leaf.at[blk].set(0) if ax == 0
                    else leaf.at[:, blk].set(0))
        return leaf
    pool.cache = jax.tree.map(zero, pool.cache, pool.block_axes)
    pool.restore_block_span(a, 0, 8, saved)
    again = pool.save_block_span(a, 0, 8)
    for s0, s1 in zip(saved, again):
        jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y),
                     s0, s1)


def test_pool_swap_tier_round_trip(tiny_cfg):
    """Cold registered prefix blocks spill to host RAM on eviction and
    come back bit-identical on the next same-prefix share."""
    pool = PagedCachePool(tiny_cfg, n_slots=2, n_blocks=4, block_size=4,
                          max_len=16, swap_blocks=4)
    toks = np.arange(8, dtype=np.int32)
    a = pool.alloc()
    pool.reserve(a, 8)
    pool.ensure_mapped(a, 8)
    pool.register_prefix(a, toks)
    before = pool.save_block_span(a, 0, 8)
    pool.release(a)                     # zero-ref registered -> cached
    pool.check_invariants()

    b = pool.alloc()                    # evict the cached blocks: they
    pool.reserve(b, 16)                 # swap out instead of vanishing
    pool.ensure_mapped(b, 16)
    assert pool.swap_outs == 2 and pool.n_swapped_blocks == 2
    pool.check_invariants()
    pool.release(b)

    c = pool.alloc()
    pool.reserve(c, 8)
    assert pool.share_prefix(c, toks) == 8
    assert pool.swap_ins == 2 and pool.n_swapped_blocks == 0
    pool.check_invariants()
    after = pool.save_block_span(c, 0, 8)
    for s0, s1 in zip(before, after):
        jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y),
                     s0, s1)


# ---------------------------------------------------------------------------
# Property suites: pool churn + scheduling exactly-once
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 32)),
                min_size=1, max_size=50))
def test_pool_churn_invariants(ops):
    """Random alloc/reserve/grow/register/release churn on a small
    oversubscribed pool with a swap tier: after every operation the pool
    invariants hold (block conservation, refcount bijection, no table
    points at a swapped-out or free block), BlockPressure never corrupts
    the books, and release stays loud."""
    cfg = reduced(get_config("internlm2-1.8b"))
    pool = PagedCachePool(cfg, n_slots=3, n_blocks=6, block_size=4,
                          max_len=32, oversubscribe=2.0, swap_blocks=4)
    live = {}                           # slot -> (gen, reserved_tokens, base)
    for op, arg in ops:
        if op == 0 and pool.n_free > 0:          # admit
            n_tok = 4 * (arg % 8) + 4            # 4..32
            if pool.can_reserve(n_tok):
                s = pool.alloc()
                pool.reserve(s, n_tok)
                live[s] = (pool.generations[s], n_tok, arg)
        elif op == 1 and live:                   # grow mapping
            s = sorted(live)[arg % len(live)]
            try:
                pool.ensure_mapped(s, min(arg, live[s][1]))
            except BlockPressure:
                pass                             # books stay sound
        elif op == 2 and live:                   # release (loud)
            s = sorted(live)[arg % len(live)]
            gen, _, _ = live.pop(s)
            pool.release(s, expected_generation=gen)
            with pytest.raises(RuntimeError):
                pool.release(s)
        elif op == 3 and live:                   # register + release
            s = sorted(live)[arg % len(live)]
            gen, n_tok, base = live.pop(s)
            n_map = int(pool.n_mapped[s]) * 4
            if n_map:
                pool.register_prefix(
                    s, (np.arange(n_map, dtype=np.int32) + base))
            pool.release(s, expected_generation=gen)
        pool.check_invariants()
    for s in list(live):
        pool.release(s, expected_generation=live.pop(s)[0])
    pool.check_invariants()
    # full drain: every non-trash block is free again
    assert pool.n_physical_in_use == 0


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 2), min_size=1, max_size=60),
       st.integers(2, 10))
def test_scheduler_queue_exactly_once(ops, n):
    """Random admit/preempt/complete churn through the real
    SlotScheduler + ArrivalQueue (dense pool, no jax): every request
    completes exactly once, preempted requests re-enter with age
    priority and are never starved, and admission order never lets a
    fresh arrival overtake a preempted one."""
    class _NullPool:                   # the slot surface the scheduler
        def __init__(self, n_slots):   # uses, with no device cache
            self.n_slots = n_slots
            self._free = sorted(range(n_slots), reverse=True)
            self._in_use = set()
            self.generations = [0] * n_slots

        n_free = property(lambda self: len(self._free))
        in_use = property(lambda self: frozenset(self._in_use))

        def alloc(self):
            slot = self._free.pop()
            self._in_use.add(slot)
            self.generations[slot] += 1
            return slot

        def release(self, slot, expected_generation=None):
            assert slot in self._in_use
            assert expected_generation == self.generations[slot]
            self._in_use.remove(slot)
            self._free.append(slot)
            self._free.sort(reverse=True)

    pool = _NullPool(2)
    sched = SlotScheduler(pool)
    reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32), max_new=4,
                    arrival_time=float(i)) for i in range(n)]
    queue = ArrivalQueue(list(reqs))
    completed, clock = [], float(n)
    for op in ops:
        clock += 1.0
        if op == 0:
            for slot, req in sched.admit_ready(queue, clock):
                # age priority: nothing ready is older than an admit
                head = queue.peek_ready()
                assert head is None or (head.arrival_time, head.rid) \
                    >= (req.arrival_time, req.rid)
        elif op == 1 and sched.running:
            slot = max(sched.running,
                       key=lambda s: sched.running[s].admit_seq)
            queue.requeue(sched.preempt(slot, clock))
        elif op == 2 and sched.running:
            slot = min(sched.running)
            completed.append(sched.retire(slot, clock, deferred=False))
        sched.check_invariants()
    while len(completed) < n:          # drain
        clock += 1.0
        sched.admit_ready(queue, clock)
        slot = min(sched.running)
        completed.append(sched.retire(slot, clock, deferred=False))
    assert sorted(r.rid for r in completed) == list(range(n))
    assert all(r.state == DONE for r in completed)
    assert len(queue) == 0 and not sched.running
