"""Property tests for the chunked linear-attention core (RWKV6 / Mamba2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.models.ssm import (linear_attention_chunked, linear_attention_scan,
                              linear_attention_step)


def _inputs(seed, B=2, T=64, H=2, K=8, V=8, per_channel=False):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, T, H, K))
    k = jax.random.normal(ks[1], (B, T, H, K))
    v = jax.random.normal(ks[2], (B, T, H, V))
    lw_shape = (B, T, H, K) if per_channel else (B, T, H, 1)
    logw = -jnp.exp(jax.random.normal(ks[3], lw_shape))
    S0 = jax.random.normal(ks[4], (B, H, K, V))
    return q, k, v, logw, S0


@pytest.mark.parametrize("mode,per_channel,chunk", [
    ("mamba", False, 16), ("mamba", True, 16), ("rwkv", True, 8),
    ("rwkv", False, 32), ("mamba", False, 64),
])
def test_chunked_matches_scan(mode, per_channel, chunk):
    q, k, v, logw, S0 = _inputs(1, per_channel=per_channel)
    u = jnp.abs(jax.random.normal(jax.random.PRNGKey(9), (2, 8))) \
        if mode == "rwkv" else None
    y1, s1 = linear_attention_scan(q, k, v, logw, S0, mode=mode, u=u)
    y2, s2 = linear_attention_chunked(q, k, v, logw, S0, mode=mode, u=u,
                                      chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4,
                               rtol=1e-3)


def test_step_matches_scan():
    """Sequential single-step decode reproduces the full scan."""
    q, k, v, logw, S0 = _inputs(2, T=16)
    y_ref, s_ref = linear_attention_scan(q, k, v, logw, S0, mode="mamba")
    S = S0.astype(jnp.float32)
    ys = []
    for t in range(16):
        y, S = linear_attention_step(q[:, t], k[:, t], v[:, t], logw[:, t],
                                     S, mode="mamba")
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(S), atol=1e-4,
                               rtol=1e-4)


def test_state_carry_composability():
    """scan(T) == scan(first half) then scan(second half) with carried S."""
    q, k, v, logw, S0 = _inputs(3, T=32)
    y_full, s_full = linear_attention_chunked(q, k, v, logw, S0, chunk=8)
    y1, s1 = linear_attention_chunked(q[:, :16], k[:, :16], v[:, :16],
                                      logw[:, :16], S0, chunk=8)
    y2, s2 = linear_attention_chunked(q[:, 16:], k[:, 16:], v[:, 16:],
                                      logw[:, 16:], s1, chunk=8)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate([y1, y2], 1)),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), atol=2e-4,
                               rtol=1e-3)


def test_zero_decay_is_cumulative_sum():
    """With w == 1 (logw = 0) and q = one-hot, outputs are running sums."""
    B, T, H, K, V = 1, 8, 1, 2, 3
    q = jnp.tile(jnp.array([1.0, 0.0]), (B, T, H, 1))
    k = jnp.tile(jnp.array([1.0, 0.0]), (B, T, H, 1))
    v = jnp.ones((B, T, H, V))
    logw = jnp.zeros((B, T, H, 1))
    S0 = jnp.zeros((B, H, K, V))
    y, _ = linear_attention_chunked(q, k, v, logw, S0, mode="mamba", chunk=4)
    expect = jnp.arange(1, T + 1, dtype=jnp.float32)[None, :, None, None] \
        * jnp.ones((B, T, H, V))
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect), atol=1e-5)


@settings(deadline=None, max_examples=15)
@given(st.integers(0, 9999), st.sampled_from([8, 16, 32]),
       st.sampled_from(["mamba", "rwkv"]))
def test_property_chunked_equals_scan(seed, chunk, mode):
    q, k, v, logw, S0 = _inputs(seed, T=64, per_channel=(mode == "rwkv"))
    u = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8))) \
        if mode == "rwkv" else None
    y1, s1 = linear_attention_scan(q, k, v, logw, S0, mode=mode, u=u)
    y2, s2 = linear_attention_chunked(q, k, v, logw, S0, mode=mode, u=u,
                                      chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-4,
                               rtol=5e-3)
