"""repro.analysis: per-rule must-flag / must-pass fixtures, the
suppression and baseline machinery (round-trip, ratchet, staleness),
CLI exit codes, and the self-check that the shipped tree is clean
modulo the checked-in `analysis_baseline.json`."""
import json
import textwrap
from pathlib import Path

from repro.analysis import __main__ as analysis_cli
from repro.analysis.core import Baseline, load_baseline, run_analysis
from repro.analysis.determinism import DeterminismRule
from repro.analysis.lock_discipline import LockDisciplineRule
from repro.analysis.pallas_contracts import PallasContractsRule
from repro.analysis.trace_safety import TraceSafetyRule

REPO = Path(__file__).resolve().parents[1]


def run_on(tmp_path, src, rel="mod.py", rules=None, baseline=None):
    """Write `src` at tmp_path/rel and analyze it (rel matters: several
    rules scope by path suffix)."""
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(src))
    return run_analysis([str(path)], root=str(tmp_path),
                        baseline=baseline, rules=rules)


def codes(report):
    return sorted(f.code for f in report.findings)


# ---------------------------------------------------------------- trace-safety

def test_ts001_sync_inside_jit(tmp_path):
    rep = run_on(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            y = jax.device_get(x)
            return y
    """, rules=[TraceSafetyRule()])
    assert codes(rep) == ["TS001"]
    assert rep.findings[0].context == "f"


def test_ts001_reaches_transitive_callee(tmp_path):
    # taint is not seeded in helpers, but the sync check still applies
    rep = run_on(tmp_path, """
        import jax

        def helper(x):
            return jax.device_get(x)

        @jax.jit
        def f(x):
            return helper(x)
    """, rules=[TraceSafetyRule()])
    assert codes(rep) == ["TS001"]
    assert rep.findings[0].context == "helper"


def test_ts002_host_coercions(tmp_path):
    rep = run_on(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            a = x.item()
            b = float(x)
            return a + b
    """, rules=[TraceSafetyRule()])
    assert codes(rep) == ["TS002", "TS002"]


def test_ts003_numpy_on_traced(tmp_path):
    rep = run_on(tmp_path, """
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.sum(x)
    """, rules=[TraceSafetyRule()])
    assert codes(rep) == ["TS003"]


def test_ts004_python_branch_on_traced(tmp_path):
    rep = run_on(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            while x < 3:
                x = x + 1
            return -x
    """, rules=[TraceSafetyRule()])
    assert codes(rep) == ["TS004", "TS004"]


def test_ts004_static_tests_pass(tmp_path):
    # shape projections, identity tests, isinstance/len, literal-default
    # params, and declared statics are all trace-time constants
    rep = run_on(tmp_path, """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("mode",))
        def f(x, mode, flag=False):
            if mode == "fast":
                x = x * 2
            if flag:
                x = x + 1
            if x is None:
                return 0
            if isinstance(x, tuple):
                x = x[0]
            if x.shape[0] > 4:
                x = x[:4]
            if len(x) > 2:
                x = x * 2
            return x
    """, rules=[TraceSafetyRule()])
    assert codes(rep) == []


def test_ts004_cfg_param_is_static_by_convention(tmp_path):
    rep = run_on(tmp_path, """
        import jax

        def forward(params, cfg, x):
            if cfg.residual:
                x = x + params["w"] * x
            return x

        step = jax.jit(forward)
    """, rules=[TraceSafetyRule()])
    assert codes(rep) == []


def test_ts004_transitive_helper_not_seeded(tmp_path):
    # a helper's int params are usually static shape math — branching on
    # them must not be flagged on guesswork
    rep = run_on(tmp_path, """
        import jax

        def pad_to(n, multiple):
            if n % multiple:
                n = n + multiple - n % multiple
            return n

        @jax.jit
        def f(x):
            k = pad_to(x.shape[0], 8)
            return x, k
    """, rules=[TraceSafetyRule()])
    assert codes(rep) == []


def test_trace_entry_via_fori_loop_body(tmp_path):
    rep = run_on(tmp_path, """
        import jax
        from jax import lax

        def run(x, n):
            def body(i, carry):
                return carry + float(carry)
            return lax.fori_loop(0, n, body, x)
    """, rules=[TraceSafetyRule()])
    assert codes(rep) == ["TS002"]


def test_ts005_audits_serving_host_syncs(tmp_path):
    src = """
        import jax

        def sync_stats(state):
            return jax.device_get(state)
    """
    flagged = run_on(tmp_path, src, rel="src/repro/serving/mod.py",
                     rules=[TraceSafetyRule()])
    assert codes(flagged) == ["TS005"]
    elsewhere = run_on(tmp_path, src, rel="src/repro/models/mod.py",
                       rules=[TraceSafetyRule()])
    assert codes(elsewhere) == []


# ------------------------------------------------------------ lock-discipline

LOCKED_CLASS = """
    import threading


    class Box:
        def __init__(self):
            self._lock = threading.Lock()
            self._items = []  # guarded_by: self._lock

        def add(self, x):
            with self._lock:
                self._items.append(x)

        def peek(self):
            return len(self._items)

        def _drain(self):  # guarded_by: self._lock
            out, self._items = self._items, []
            return out
"""


def test_ld001_flags_unguarded_access_only(tmp_path):
    rep = run_on(tmp_path, LOCKED_CLASS, rules=[LockDisciplineRule()])
    assert codes(rep) == ["LD001"]
    (f,) = rep.findings
    assert f.context == "Box.peek"
    assert "_items" in f.message


def test_ld001_deferred_callback_loses_the_lock(tmp_path):
    rep = run_on(tmp_path, """
        import threading


        class Box:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0  # guarded_by: self._lock

            def register(self, registry):
                with self._lock:
                    registry.gauge(fn=lambda: self._n)
    """, rules=[LockDisciplineRule()])
    assert codes(rep) == ["LD001"]
    assert "deferred" in rep.findings[0].message


def test_ld001_inheritance_same_module(tmp_path):
    rep = run_on(tmp_path, """
        import threading


        class Base:
            def __init__(self):
                self._lock = threading.Lock()
                self._n = 0  # guarded_by: self._lock


        class Child(Base):
            def bump(self):
                self._n += 1
    """, rules=[LockDisciplineRule()])
    assert codes(rep) == ["LD001"]
    assert rep.findings[0].context == "Child.bump"


def test_ld002_orphan_annotation(tmp_path):
    rep = run_on(tmp_path, """
        import threading


        class Box:
            def __init__(self):
                self._lock = threading.Lock()
                # guarded_by: self._lock
                self._items = []

            def use(self):
                return self._items
    """, rules=[LockDisciplineRule()])
    # the comment sits on its own line -> binds nothing -> LD002, and
    # _items is NOT guarded (that is exactly the bug LD002 catches)
    assert codes(rep) == ["LD002"]


def test_guarded_by_in_docstring_is_not_an_annotation(tmp_path):
    rep = run_on(tmp_path, '''
        class Doc:
            """Explains the convention: # guarded_by: self._lock ."""

            def use(self):
                return 1
    ''', rules=[LockDisciplineRule()])
    assert codes(rep) == []


# ---------------------------------------------------------------- determinism

def test_determinism_flags_in_pragma_module(tmp_path):
    rep = run_on(tmp_path, """
        # repro: deterministic-module
        import random
        import time


        def pick(items, key):
            h = hash(key)
            r = random.random()
            t = time.time()
            ok = time.perf_counter()
            return h, r, t, ok
    """, rules=[DeterminismRule()])
    assert codes(rep) == ["DM001", "DM002", "DM003"]


def test_determinism_scoped_by_default_paths(tmp_path):
    src = """
        def k(key):
            return hash(key)
    """
    scoped = run_on(tmp_path, src, rel="src/repro/serving/scheduler.py",
                    rules=[DeterminismRule()])
    assert codes(scoped) == ["DM001"]
    unscoped = run_on(tmp_path, src, rel="src/repro/obscure.py",
                      rules=[DeterminismRule()])
    assert codes(unscoped) == []


def test_determinism_allows_seeded_rng(tmp_path):
    rep = run_on(tmp_path, """
        # repro: deterministic-module
        import numpy as np


        def make(seed):
            return np.random.default_rng(seed)
    """, rules=[DeterminismRule()])
    assert codes(rep) == []


# ----------------------------------------------------------- pallas-contracts

def test_pl001_kernel_arity(tmp_path):
    rep = run_on(tmp_path, """
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl


        def kernel(a_ref, o_ref):
            o_ref[...] = a_ref[...]


        def call(x):
            return pl.pallas_call(
                kernel,
                grid=(4,),
                in_specs=[pl.BlockSpec((8,), lambda i: i),
                          pl.BlockSpec((8,), lambda i: i)],
                out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
            )(x, x)
    """, rules=[PallasContractsRule()])
    assert "PL001" in codes(rep)


def test_pl002_index_map_arity(tmp_path):
    rep = run_on(tmp_path, """
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl


        def kernel(a_ref, o_ref):
            o_ref[...] = a_ref[...]


        def call(x):
            return pl.pallas_call(
                kernel,
                grid=(4, 2),
                in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
                out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
            )(x)
    """, rules=[PallasContractsRule()])
    assert "PL002" in codes(rep)


def test_pl003_alias_out_of_range(tmp_path):
    rep = run_on(tmp_path, """
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl


        def kernel(a_ref, b_ref, o_ref):
            o_ref[...] = a_ref[...] + b_ref[...]


        def call(x, y):
            return pl.pallas_call(
                kernel,
                grid=(4,),
                in_specs=[pl.BlockSpec((8,), lambda i: i),
                          pl.BlockSpec((8,), lambda i: i)],
                out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
                input_output_aliases={5: 0},
            )(x, y)
    """, rules=[PallasContractsRule()])
    assert "PL003" in codes(rep)


def test_pl004_fp32_scratch_in_attention_kernels(tmp_path):
    src = """
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu


        def kernel(a_ref, o_ref, m_ref):
            o_ref[...] = a_ref[...]


        def call(x):
            return pl.pallas_call(
                kernel,
                grid=(4,),
                in_specs=[pl.BlockSpec((8,), lambda i: i)],
                out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
                scratch_shapes=[pltpu.VMEM((8, 128), jnp.bfloat16)],
            )(x)
    """
    rep = run_on(tmp_path, src, rel="src/repro/kernels/paged_attention.py",
                 rules=[PallasContractsRule()])
    assert "PL004" in codes(rep)
    # same scratch dtype is fine outside the online-softmax kernels
    rep2 = run_on(tmp_path, src, rel="src/repro/kernels/other.py",
                  rules=[PallasContractsRule()])
    assert "PL004" not in codes(rep2)
    fixed = src.replace("jnp.bfloat16", "jnp.float32")
    rep3 = run_on(tmp_path, fixed,
                  rel="src/repro/kernels/paged_attention.py",
                  rules=[PallasContractsRule()])
    assert "PL004" not in codes(rep3)


def test_pallas_clean_on_real_kernels():
    rep = run_analysis(["src/repro/kernels"], root=str(REPO),
                       rules=[PallasContractsRule()])
    assert rep.errors == []
    assert rep.findings == []


# ------------------------------------------------- suppression and baseline

SYNC_IN_JIT = """
    import jax

    @jax.jit
    def f(x):
        return jax.device_get(x){suffix}
"""


def test_suppression_by_rule_code_and_bare(tmp_path):
    for token in ("trace-safety", "TS001", ""):
        comment = (f"  # repro: ignore[{token}]" if token
                   else "  # repro: ignore")
        rep = run_on(tmp_path, SYNC_IN_JIT.format(suffix=comment),
                     rel=f"m_{token or 'bare'}.py".replace("-", "_"),
                     rules=[TraceSafetyRule()])
        assert rep.findings == [], token
    # a non-matching token does not silence the finding
    rep = run_on(tmp_path,
                 SYNC_IN_JIT.format(suffix="  # repro: ignore[determinism]"),
                 rules=[TraceSafetyRule()])
    assert codes(rep) == ["TS001"]


def test_baseline_round_trip(tmp_path):
    rep = run_on(tmp_path, SYNC_IN_JIT.format(suffix=""),
                 rules=[TraceSafetyRule()])
    assert rep.exit_code == 1 and len(rep.new) == 1

    bl_path = tmp_path / "baseline.json"
    Baseline.from_findings(rep.findings).dump(str(bl_path))
    loaded = load_baseline(str(bl_path))

    rep2 = run_on(tmp_path, SYNC_IN_JIT.format(suffix=""),
                  rules=[TraceSafetyRule()], baseline=loaded)
    assert rep2.exit_code == 0
    assert rep2.new == [] and len(rep2.baselined) == 1
    assert rep2.stale_baseline == []


def test_baseline_survives_line_moves_but_not_edits(tmp_path):
    rep = run_on(tmp_path, SYNC_IN_JIT.format(suffix=""),
                 rules=[TraceSafetyRule()])
    baseline = Baseline.from_findings(rep.findings)

    moved = "import os\n# a new comment shifting lines\n" + \
        textwrap.dedent(SYNC_IN_JIT.format(suffix=""))
    rep2 = run_on(tmp_path, moved, rules=[TraceSafetyRule()],
                  baseline=baseline)
    assert rep2.exit_code == 0 and rep2.new == []

    edited = SYNC_IN_JIT.format(suffix="").replace(
        "jax.device_get(x)", "jax.device_get(x + 1)")
    rep3 = run_on(tmp_path, edited, rules=[TraceSafetyRule()],
                  baseline=baseline)
    assert rep3.exit_code == 1      # snippet changed -> re-justify
    assert len(rep3.stale_baseline) == 1


def test_stale_baseline_entries_reported(tmp_path):
    baseline = Baseline([{
        "rule": "trace-safety", "code": "TS001", "path": "gone.py",
        "context": "f", "snippet": "jax.device_get(x)",
        "justification": "file was deleted"}])
    rep = run_on(tmp_path, "x = 1\n", rules=[TraceSafetyRule()],
                 baseline=baseline)
    assert rep.exit_code == 0       # stale entries warn, not fail
    assert len(rep.stale_baseline) == 1
    assert "prune" in rep.render()


# ----------------------------------------------------------------------- CLI

def test_cli_exit_codes_and_json(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(SYNC_IN_JIT.format(suffix="")))
    rc = analysis_cli.main(["--paths", str(bad), "--root", str(tmp_path),
                            "--baseline", "", "--json"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert [f["code"] for f in out["new"]] == ["TS001"]

    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    rc = analysis_cli.main(["--paths", str(good), "--root", str(tmp_path),
                            "--baseline", ""])
    assert rc == 0


def test_cli_write_baseline_keeps_justifications(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(SYNC_IN_JIT.format(suffix="")))
    bl = tmp_path / "bl.json"
    argv = ["--paths", str(bad), "--root", str(tmp_path),
            "--baseline", str(bl)]
    assert analysis_cli.main(argv + ["--write-baseline"]) == 0
    data = json.loads(bl.read_text())
    assert data["entries"][0]["justification"] == "TODO: justify"

    data["entries"][0]["justification"] = "deliberate: fixture"
    bl.write_text(json.dumps(data))
    assert analysis_cli.main(argv + ["--write-baseline"]) == 0
    data2 = json.loads(bl.read_text())
    assert data2["entries"][0]["justification"] == "deliberate: fixture"
    capsys.readouterr()


# ---------------------------------------------------------------- self-check

def test_repo_is_clean_modulo_baseline():
    """The shipped tree passes the gate: no errors, no rule crashes, no
    findings beyond the checked-in baseline, and no stale entries."""
    baseline = load_baseline(str(REPO / "analysis_baseline.json"))
    rep = run_analysis(["src", "tests", "benchmarks"], root=str(REPO),
                       baseline=baseline)
    assert rep.errors == []
    assert [f.render() for f in rep.new] == []
    assert rep.stale_baseline == []
    assert rep.exit_code == 0
    assert rep.baselined  # the deliberate host-sync sites are tracked


def test_injected_violation_fails_the_gate(tmp_path):
    """Acceptance check: the exact CLI the CI job runs exits nonzero
    when a violating file is injected next to clean sources."""
    (tmp_path / "clean.py").write_text("x = 1\n")
    (tmp_path / "dirty.py").write_text(textwrap.dedent(
        SYNC_IN_JIT.format(suffix="")))
    rc = analysis_cli.main(["--paths", str(tmp_path),
                            "--root", str(tmp_path), "--baseline", ""])
    assert rc == 1
