"""Continuous-batching serving subsystem tests: scheduler invariants,
cache-pool reuse, arrival queue, and static-vs-continuous greedy parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.synthetic import make_lm_stream
from repro.models import transformer as tfm
from repro.serving import (ArrivalQueue, CascadeEngine,
                           ContinuousCascadeEngine, ModelRunner, Request,
                           SlotCachePool, SlotScheduler, make_requests)
from repro.serving.request import DONE, RUNNING


@pytest.fixture(scope="module")
def runners():
    key = jax.random.PRNGKey(0)
    s_cfg = reduced(get_config("internlm2-1.8b"))
    l_cfg = s_cfg.replace(name="large", n_layers=3, d_ff=768)
    small = ModelRunner(s_cfg, tfm.init_params(s_cfg, key))
    large = ModelRunner(l_cfg, tfm.init_params(l_cfg,
                                               jax.random.fold_in(key, 1)))
    prompts = make_lm_stream(jax.random.fold_in(key, 2), 16, 8,
                             s_cfg.vocab_size)
    return small, large, prompts


@pytest.fixture(scope="module")
def tiny_cfg():
    return reduced(get_config("internlm2-1.8b"))


# ---------------------------------------------------------------------------
# Arrival queue
# ---------------------------------------------------------------------------

def test_arrival_queue_delayed_visibility():
    reqs = [Request(rid=i, prompt=np.zeros(4, np.int32), max_new=2,
                    arrival_time=t) for i, t in enumerate([0.0, 0.5, 0.5, 2.0])]
    q = ArrivalQueue(reqs)
    assert len(q) == 4 and q.n_ready == 0
    q.release(0.0)
    assert q.n_ready == 1
    q.release(1.0)
    assert q.n_ready == 3               # ties released together
    assert q.next_arrival == 2.0
    # FIFO pop order == arrival (and rid for ties)
    assert [q.pop_ready().rid for _ in range(3)] == [0, 1, 2]
    q.release(5.0)
    assert q.pop_ready().rid == 3
    assert len(q) == 0


# ---------------------------------------------------------------------------
# Scheduler: FIFO admission, no slot leaks
# ---------------------------------------------------------------------------

def test_scheduler_fifo_and_no_slot_leaks(tiny_cfg):
    pool = SlotCachePool(tiny_cfg, n_slots=3, max_len=8)
    sched = SlotScheduler(pool)
    reqs = [Request(rid=i, prompt=np.zeros(4, np.int32), max_new=2)
            for i in range(7)]
    q = ArrivalQueue(reqs)

    admitted = sched.admit_ready(q, now=0.0)
    assert [r.rid for _, r in admitted] == [0, 1, 2]        # FIFO
    assert all(r.state == RUNNING for _, r in admitted)
    assert pool.n_free == 0
    sched.check_invariants()
    # pool exhausted: nothing admitted, queue order preserved
    assert sched.admit_ready(q, now=0.0) == []
    assert q.n_ready == 4

    # retire the middle slot; next FIFO request takes exactly that slot
    mid_slot = admitted[1][0]
    r = sched.retire(mid_slot, now=1.0, deferred=False)
    assert r.rid == 1 and r.state == DONE and r.slot is None
    sched.check_invariants()
    (slot, nxt), = sched.admit_ready(q, now=1.0)
    assert nxt.rid == 3 and slot == mid_slot
    sched.check_invariants()

    # drain everything; all slots must come back
    while sched.n_active or len(q):
        for s in list(sched.active_slots):
            sched.retire(s, now=2.0, deferred=bool(s % 2), early=bool(s % 2))
        sched.admit_ready(q, now=2.0)
    sched.check_invariants()
    assert pool.n_free == 3 and sched.n_active == 0
    # double-release must be rejected
    with pytest.raises(RuntimeError):
        pool.release(0)


# ---------------------------------------------------------------------------
# Cache pool: row scatter + reuse across request generations
# ---------------------------------------------------------------------------

def test_cache_pool_scatter_rows_isolated(tiny_cfg):
    pool = SlotCachePool(tiny_cfg, n_slots=4, max_len=8)
    assert jax.tree.structure(pool.cache) == jax.tree.structure(
        pool.batch_axes)

    row = tfm.init_cache(tiny_cfg, 2, 8, dtype=jnp.float32)
    row = jax.tree.map(lambda a: jnp.ones_like(a), row)
    before = jax.tree.map(lambda a: np.asarray(a).copy(), pool.cache)
    pool.write_rows(row, [1, 3])
    for leaf, old, ax in zip(jax.tree.leaves(pool.cache),
                             jax.tree.leaves(before),
                             jax.tree.leaves(pool.batch_axes)):
        leaf = np.moveaxis(np.asarray(leaf), ax, 0)
        old = np.moveaxis(old, ax, 0)
        assert (leaf[1] == 1).all() and (leaf[3] == 1).all()
        np.testing.assert_array_equal(leaf[0], old[0])      # untouched
        np.testing.assert_array_equal(leaf[2], old[2])


def test_cache_pool_slot_reuse_generations(tiny_cfg):
    pool = SlotCachePool(tiny_cfg, n_slots=2, max_len=8)
    a, b = pool.alloc(), pool.alloc()
    assert {a, b} == {0, 1}
    with pytest.raises(RuntimeError):
        pool.alloc()
    pool.release(a)
    c = pool.alloc()
    assert c == a                                           # slot reused
    assert pool.generations[a] == 2 and pool.generations[b] == 1


# ---------------------------------------------------------------------------
# Engine: greedy parity + in-flight deferral
# ---------------------------------------------------------------------------

def test_static_continuous_greedy_parity(runners):
    """With early exit disabled the continuous engine must reproduce the
    static cascade token-for-token (greedy), including deferral routing."""
    small, large, prompts = runners
    static = CascadeEngine(small, large)
    tau = static.calibrate(prompts, 8, 4, deferral_ratio=0.5)
    sres = static.serve(prompts, 8, 4)

    cont = ContinuousCascadeEngine(small, large, n_slots=8, tau=tau,
                                   early_exit=False)
    cres = cont.run(make_requests(prompts, 4), 4)
    np.testing.assert_array_equal(cres.tokens, sres.tokens)
    np.testing.assert_array_equal(cres.deferred, sres.deferred)
    np.testing.assert_allclose(cres.confidence, sres.confidence, rtol=1e-6)
    assert cres.saved_steps == 0 and not cres.early_exited.any()


def test_parity_with_slot_reuse(runners):
    """n_slots < n_requests: slots must be recycled across generations
    without contaminating later requests' caches."""
    small, large, prompts = runners
    static = CascadeEngine(small, large, tau=-1e9)          # never defer
    sres = static.serve(prompts, 8, 4)
    cont = ContinuousCascadeEngine(small, large, n_slots=4, tau=-1e9,
                                   early_exit=False)
    cres = cont.run(make_requests(prompts, 4), 4)
    np.testing.assert_array_equal(cres.tokens, sres.tokens)
    assert cres.deferral_ratio == 0.0
    # 16 requests x 3 decode steps on 4 slots => at least 12 engine steps
    assert cres.steps >= 12


def test_parity_with_multi_step_scheduling(runners):
    """steps_per_sync > 1 (chunked decode between host syncs) must not
    change greedy outputs: finished slots self-deactivate on device."""
    small, large, prompts = runners
    static = CascadeEngine(small, large)
    tau = static.calibrate(prompts, 8, 4, deferral_ratio=0.5)
    sres = static.serve(prompts, 8, 4)
    cont = ContinuousCascadeEngine(small, large, n_slots=4, tau=tau,
                                   early_exit=False, steps_per_sync=3)
    cres = cont.run(make_requests(prompts, 4), 4)
    np.testing.assert_array_equal(cres.tokens, sres.tokens)
    np.testing.assert_array_equal(cres.deferred, sres.deferred)


def test_in_flight_deferral_evicts_and_saves(runners):
    """tau above every confidence: every request is evicted at exactly
    min_tokens and regenerated by M_L."""
    small, large, prompts = runners
    cont = ContinuousCascadeEngine(small, large, n_slots=8, tau=1e9,
                                   min_tokens=2, early_exit=True)
    res = cont.run(make_requests(prompts, 4), 4)
    assert res.deferred.all() and res.early_exited.all()
    assert all(r.n_small_steps == 2 for r in res.requests)
    assert res.saved_steps == 16 * (4 - 2)
    assert all(r.state == DONE for r in res.requests)
    # outputs are the large model's generations
    l_tokens, _ = large.generate(prompts, 8, 4)
    np.testing.assert_array_equal(res.tokens, l_tokens)
    # telemetry agrees
    assert res.stats["early_exit_ratio"] == 1.0
    assert res.stats["saved_steps"] == res.saved_steps


def test_calibrated_continuous_run(runners):
    small, large, prompts = runners
    cont = ContinuousCascadeEngine(small, large, n_slots=4, min_tokens=2,
                                   early_exit=True)
    cont.calibrate(prompts, 8, 4, deferral_ratio=0.5)
    res = cont.run(make_requests(prompts, 4), 4)
    assert res.tokens.shape == (16, 4)
    assert 0.2 <= res.deferral_ratio <= 0.9
    assert np.isfinite(res.confidence).all()
    assert res.stats["n_requests"] == 16
    assert res.stats["throughput_tok_s"] > 0


def test_max_new_one(runners):
    """Degenerate budget: the prefill token is the whole generation."""
    small, large, prompts = runners
    cont = ContinuousCascadeEngine(small, large, n_slots=8, tau=-1e9,
                                   early_exit=True)
    res = cont.run(make_requests(prompts, 1), 1)
    s_tokens, _ = small.generate(prompts, 8, 1)
    np.testing.assert_array_equal(res.tokens, s_tokens)
    assert not res.deferred.any()


def test_heterogeneous_max_new_clamped(runners):
    """A request whose max_new exceeds the run budget must still retire
    (regression: unclamped req.max_new made the run loop spin forever)."""
    small, large, prompts = runners
    cont = ContinuousCascadeEngine(small, large, n_slots=4, tau=-1e9,
                                   early_exit=False)
    reqs = make_requests(prompts[:4], 4)
    reqs[0].max_new = 99                    # larger than the run's budget
    reqs[1].max_new = 2                     # smaller: early device stop
    res = cont.run(reqs, 4)
    assert all(r.state == DONE for r in res.requests)
    assert res.requests[0].n_small_steps == 4
    assert res.requests[1].n_small_steps == 2
    s_tokens, _ = small.generate(prompts[:4], 8, 4)
    np.testing.assert_array_equal(res.requests[0].tokens, s_tokens[0])
    np.testing.assert_array_equal(res.requests[1].small_tokens,
                                  s_tokens[1, :2])


def test_mla_family_parity():
    """Vector-position decode must also hold for MLA (compressed-kv cache)."""
    key = jax.random.PRNGKey(3)
    cfg = reduced(get_config("deepseek-v2-236b"))
    cfg = cfg.replace(moe=None, family="dense", n_layers=2)
    small = ModelRunner(cfg, tfm.init_params(cfg, key))
    large = ModelRunner(cfg.replace(name="l"), tfm.init_params(
        cfg, jax.random.fold_in(key, 1)))
    prompts = make_lm_stream(jax.random.fold_in(key, 2), 4, 8,
                             cfg.vocab_size)
    static = CascadeEngine(small, large, tau=-1e9)
    sres = static.serve(prompts, 8, 3)
    cont = ContinuousCascadeEngine(small, large, n_slots=2, tau=-1e9,
                                   early_exit=False)
    cres = cont.run(make_requests(prompts, 3), 3)
    np.testing.assert_array_equal(cres.tokens, sres.tokens)
