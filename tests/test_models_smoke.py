"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each assigned family runs one forward + one Gatekeeper train step on CPU,
asserting output shapes and no NaNs; plus prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced, SHAPES
from repro.core.gatekeeper import GatekeeperConfig
from repro.launch.steps import make_train_step
from repro.models import encdec as encdec_lib
from repro.models import transformer as tfm
from repro.sharding import ParallelContext
from repro.training import optim

ARCHS = [a.replace("_", "-") for a in ARCH_IDS]
CTX = ParallelContext()


def _batch_for(cfg, key, B=2, T=16):
    b = {}
    if cfg.family == "vlm":
        P = cfg.vision.n_patches
        b["tokens"] = jax.random.randint(key, (B, T - P), 0, cfg.vocab_size)
        b["patches"] = jax.random.normal(key, (B, P, cfg.d_model))
        b["targets"] = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    elif cfg.family == "encdec":
        b["tokens"] = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
        b["frames"] = jax.random.normal(key, (B, cfg.encoder.n_frames,
                                              cfg.d_model))
        b["targets"] = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    else:
        b["tokens"] = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
        b["targets"] = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes(arch):
    cfg = reduced(get_config(arch))
    assert cfg.n_layers <= 4 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)
    B, T = 2, 16
    batch = _batch_for(cfg, key, B, T)
    if cfg.family == "encdec":
        logits = encdec_lib.forward(params, cfg, batch["frames"],
                                    batch["tokens"], CTX)
    else:
        logits = tfm.forward(params, cfg, batch["tokens"], CTX,
                             batch.get("patches"))
    assert logits.shape == (B, T, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = tfm.init_params(cfg, key)
    opt_state = optim.adamw_init(params)
    step = make_train_step(cfg, CTX, gk=GatekeeperConfig(alpha=0.3),
                           opt_cfg=optim.AdamWConfig(lr=1e-3, total_steps=10))
    batch = _batch_for(cfg, key)
    new_params, _, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()),
                     params, new_params))
    assert delta > 0
    for leaf in jax.tree.leaves(new_params):
        assert not bool(jnp.isnan(leaf).any())


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "deepseek-v2-236b",
                                  "rwkv6-3b", "zamba2-1.2b",
                                  "kimi-k2-1t-a32b", "qwen1.5-4b"])
def test_prefill_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(2)
    params = tfm.init_params(cfg, key)
    T = 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, T), 0,
                              cfg.vocab_size)
    full = tfm.forward(params, cfg, toks, CTX)
    cache = tfm.init_cache(cfg, 2, T + 4, dtype=jnp.float32)
    lg, cache = tfm.prefill(params, cfg, toks[:, :T - 1], cache, CTX)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, :T - 1]),
                               atol=1e-3, rtol=1e-3)
    step_logits, cache = tfm.decode_step(params, cfg, toks[:, T - 1], T - 1,
                                         cache, CTX)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full[:, -1]), atol=1e-3, rtol=1e-3)


def test_encdec_prefill_decode():
    cfg = reduced(get_config("whisper-small"))
    key = jax.random.PRNGKey(4)
    params = tfm.init_params(cfg, key)
    frames = jax.random.normal(key, (2, cfg.encoder.n_frames, cfg.d_model))
    T = 8
    toks = jax.random.randint(key, (2, T), 0, cfg.vocab_size)
    full = encdec_lib.forward(params, cfg, frames, toks, CTX)
    cache = encdec_lib.init_cache(cfg, 2, T + 2, dtype=jnp.float32)
    lg, cache = encdec_lib.prefill(params, cfg, frames, toks[:, :T - 1],
                                   cache, CTX)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, :T - 1]),
                               atol=1e-3, rtol=1e-3)
    step_logits, _ = encdec_lib.decode_step(params, cfg, toks[:, T - 1],
                                            T - 1, cache, CTX)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full[:, -1]), atol=1e-3, rtol=1e-3)


def test_sliding_window_variant_lowers_memory():
    """The long_500k carve-out: sliding-window cache is bounded."""
    cfg = reduced(get_config("internlm2-1.8b")).replace(sliding_window=8)
    cache = tfm.init_cache(cfg, 2, 1024, dtype=jnp.float32)
    assert cache["dense"]["k"].shape[2] == 8     # window, not 1024


def test_sliding_window_decode_ring_buffer():
    """Ring-buffer decode == full-cache decode when window >= history."""
    cfg = reduced(get_config("internlm2-1.8b"))
    cfg_win = cfg.replace(sliding_window=32)
    key = jax.random.PRNGKey(5)
    params = tfm.init_params(cfg, key)
    T = 12
    toks = jax.random.randint(key, (1, T), 0, cfg.vocab_size)
    full = tfm.forward(params, cfg, toks, CTX)
    cache = tfm.init_cache(cfg_win, 1, 64, dtype=jnp.float32)
    _, cache = tfm.prefill(params, cfg_win, toks[:, :T - 1], cache, CTX)
    step_logits, _ = tfm.decode_step(params, cfg_win, toks[:, T - 1], T - 1,
                                     cache, CTX)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full[:, -1]), atol=1e-3, rtol=1e-3)


def test_all_shapes_registered():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["train_4k"].global_batch == 256
