"""Non-blocking M_L backend tests: sync/thread/stub greedy parity
(bit-exact per request), max-wait no-starvation, drain completeness,
batch-shape policy unification, M_L queue-depth telemetry, and the
acceptance criterion that M_S decode steps interleave with in-flight
M_L regeneration under the threaded backend."""
import json
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.synthetic import make_lm_stream
from repro.models import transformer as tfm
from repro.serving import (ContinuousCascadeEngine, ModelRunner, Request,
                           RemoteStubBackend, ThreadedBackend,
                           make_requests, poisson_arrivals)
from repro.serving.large_backend import (FLUSH_DRAIN, FLUSH_FULL,
                                         FLUSH_MAX_WAIT, BatchPolicy,
                                         _Pending, make_large_backend)
from repro.serving.request import DONE

from _hypothesis_shim import given, settings, st


@pytest.fixture(scope="module")
def runners():
    key = jax.random.PRNGKey(0)
    s_cfg = reduced(get_config("internlm2-1.8b"))
    l_cfg = s_cfg.replace(name="large", n_layers=3, d_ff=768)
    small = ModelRunner(s_cfg, tfm.init_params(s_cfg, key))
    large = ModelRunner(l_cfg, tfm.init_params(l_cfg,
                                               jax.random.fold_in(key, 1)))
    prompts = make_lm_stream(jax.random.fold_in(key, 2), 16, 8,
                             s_cfg.vocab_size)
    return small, large, prompts


def ragged_prompts(key, lens, vocab):
    return [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                          (n,), 0, vocab), np.int32)
            for i, n in enumerate(lens)]


# ---------------------------------------------------------------------------
# Batch-shape policy (unit level)
# ---------------------------------------------------------------------------

def _pend(rid, plen, t=0.0):
    return _Pending(rid, np.full((plen,), rid, np.int32), t)


def test_batch_policy_full_then_max_wait_then_drain():
    pol = BatchPolicy(large_batch=3, max_wait=1.0)
    for i in range(4):
        pol.add(_pend(i, 8, t=float(i)))
    # one full batch pops immediately, remainder waits
    out = pol.take(now=0.0)
    assert len(out) == 1
    group, pad_to, reason = out[0]
    assert [p.rid for p in group] == [0, 1, 2]
    assert pad_to == 3 and reason == FLUSH_FULL
    assert pol.n_pending == 1
    # not timed out yet
    assert pol.take(now=3.5) == []
    # max-wait fires: partial group padded to large_batch
    (group, pad_to, reason), = pol.take(now=4.1)
    assert [p.rid for p in group] == [3]
    assert pad_to == 3 and reason == FLUSH_MAX_WAIT
    # drain flushes whatever remains, per length group, rid-sorted
    pol.add(_pend(9, 4))
    pol.add(_pend(7, 4))
    pol.add(_pend(8, 6))
    out = pol.take(now=0.0, drain=True)
    assert [(sorted(p.rid for p in g), r) for g, _, r in out] == [
        ([7, 9], FLUSH_DRAIN), ([8], FLUSH_DRAIN)]
    assert pol.n_pending == 0


def test_batch_policy_drain_padding():
    """Drain pads a single-length leftover up to large_batch (reuses the
    mid-run compiled shape) but flushes multi-length ragged leftovers
    exact-size — padding per-length groups that will never recur would
    just multiply M_L compute."""
    pol = BatchPolicy(large_batch=4, max_wait=None)
    pol.add(_pend(0, 8)); pol.add(_pend(1, 8))
    (_, pad_to, _), = pol.take(now=0.0, drain=True)
    assert pad_to == 4                              # uniform: padded
    pol.add(_pend(2, 8)); pol.add(_pend(3, 6))
    out = pol.take(now=0.0, drain=True)
    assert [(len(g), p) for g, p, _ in out] == [(1, 1), (1, 1)]  # exact


def test_batch_policy_none_batches_only_at_drain():
    pol = BatchPolicy(large_batch=None, max_wait=None)
    for i in range(5):
        pol.add(_pend(i, 8))
    assert pol.take(now=1e9) == []
    (group, pad_to, _), = pol.take(now=0.0, drain=True)
    assert len(group) == 5 and pad_to == 5          # exact size, no pad
    assert pol.next_deadline() is None


# op encoding for the property test: ("add", plen) | ("take",) | ("drain",)
_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("add"), st.sampled_from([4, 6, 8])),
        st.tuples(st.just("take")),
        st.tuples(st.just("drain"))),
    min_size=1, max_size=40)


@given(ops=_OPS,
       large_batch=st.one_of(st.none(), st.integers(1, 5)),
       max_wait=st.one_of(st.none(), st.just(0.5)))
@settings(max_examples=200, deadline=None)
def test_batch_policy_interleavings_conserve_requests(ops, large_batch,
                                                      max_wait):
    """Property: under ARBITRARY submit/take/drain interleavings, every
    submitted rid comes back exactly once across all take() calls plus
    the final drain (no drop, no duplicate), every emitted group is
    uniform in prompt length, rid-sorted, and padded to >= its size."""
    pol = BatchPolicy(large_batch, max_wait)
    submitted, returned = [], []
    now = 0.0
    rid = 0

    def absorb(flushes, drain):
        for group, pad_to, reason in flushes:
            plens = {int(p.prompt.shape[0]) for p in group}
            assert len(plens) == 1                  # uniform-length group
            rids = [p.rid for p in group]
            assert rids == sorted(rids)             # rid-sorted
            assert pad_to >= len(group)
            if large_batch is not None and not drain:
                assert pad_to == large_batch
            returned.extend(rids)

    for op in ops:
        now += 0.3                  # fixed clock steps: max_wait can fire
        if op[0] == "add":
            pol.add(_pend(rid, op[1], t=now))
            submitted.append(rid)
            rid += 1
        elif op[0] == "take":
            absorb(pol.take(now=now), drain=False)
        else:
            absorb(pol.take(now=now, drain=True), drain=True)
    absorb(pol.take(now=now, drain=True), drain=True)
    assert pol.n_pending == 0
    assert sorted(returned) == sorted(submitted)    # exactly-once
    assert len(returned) == len(set(returned))


@given(ops=_OPS)
@settings(max_examples=100, deadline=None)
def test_batch_policy_cancel_interleaved(ops):
    """Property: cancelling a random half of the still-pending rids at
    the end removes exactly those rids — take∪drain returns each
    surviving rid once, each cancelled rid never."""
    pol = BatchPolicy(large_batch=3, max_wait=None)
    submitted, returned = [], []
    rid = 0
    for op in ops:
        if op[0] == "add":
            pol.add(_pend(rid, op[1]))
            submitted.append(rid)
            rid += 1
        else:
            for g, _, _ in pol.take(now=0.0, drain=(op[0] == "drain")):
                returned.extend(p.rid for p in g)
    pending = [r for r in submitted if r not in returned]
    victims = pending[::2]
    assert sorted(pol.cancel(victims)) == sorted(victims)
    for g, _, _ in pol.take(now=0.0, drain=True):
        returned.extend(p.rid for p in g)
    assert sorted(returned) == sorted(set(submitted) - set(victims))


# ---------------------------------------------------------------------------
# Backends standalone: submit / poll / drain contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["sync", "thread", "stub"])
def test_backend_drain_completes_all_pending(runners, kind):
    """drain() must return every submitted request's tokens, matching a
    direct M_L generate of the same prompts."""
    small, large, prompts = runners
    be = make_large_backend(kind, large, max_new=4, large_batch=3)
    reqs = [Request(rid=i, prompt=prompts[i], max_new=4) for i in range(7)]
    for r in reqs:
        be.submit([r])
    results = list(be.poll())
    results += be.drain()
    be.close()
    assert be.n_pending == 0
    assert sorted(r.rid for r in results) == list(range(7))
    want, _ = large.generate(prompts[:7], 8, 4)
    for res in results:
        np.testing.assert_array_equal(res.tokens, want[res.rid])
    # 2 full batches of 3 + a drained leftover of 1 (padded to 3)
    reasons = sorted(r.reason for r in results)
    assert reasons.count(FLUSH_FULL) == 6 and reasons.count(FLUSH_DRAIN) == 1
    leftover = next(r for r in results if r.reason == FLUSH_DRAIN)
    assert leftover.n_real == 1 and leftover.pad_to == 3


@pytest.mark.parametrize("kind", ["sync", "thread", "stub"])
def test_poll_accepts_timeout_kwarg(runners, kind):
    """Protocol conformance: `LargeBackend.poll(timeout=...)` is part of
    the contract (the engine's drain loop relies on it) — every backend
    must accept the kwarg, including ones that never block. Regression:
    the Protocol used to declare bare poll() while implementations took
    a kwarg the engine couldn't rely on."""
    small, large, prompts = runners
    be = make_large_backend(kind, large, max_new=4, large_batch=2)
    assert be.poll(timeout=0.01) == []          # idle: empty either way
    assert be.poll() == []
    be.submit([Request(rid=0, prompt=prompts[0], max_new=4)])
    be.flush()
    got = []
    deadline = time.perf_counter() + 10.0
    while not got and time.perf_counter() < deadline:
        got = be.poll(timeout=0.05)
    be.close()
    assert [r.rid for r in got] == [0]


def test_threaded_max_wait_fires_partial_batch(runners):
    """A batch that never fills must still flush after max_wait — no
    starvation while the engine keeps decoding."""
    small, large, prompts = runners
    be = ThreadedBackend(large, max_new=4, large_batch=64, max_wait=0.05)
    reqs = [Request(rid=i, prompt=prompts[i], max_new=4) for i in range(3)]
    be.submit(reqs)
    got = []
    deadline = 100  # x 50ms poll
    while len(got) < 3 and deadline:
        got += be.poll(timeout=0.05)
        deadline -= 1
    be.close()
    assert len(got) == 3
    assert all(r.reason == FLUSH_MAX_WAIT for r in got)
    assert got[0].n_real == 3 and got[0].pad_to == 64


def test_stub_backend_serializes_roundtrip(runners):
    """The RPC-shaped backend must produce identical tokens through its
    serialized byte pipe, with injected latency accounted."""
    small, large, prompts = runners
    be = RemoteStubBackend(large, max_new=4, large_batch=None,
                           latency=0.01)
    reqs = [Request(rid=i, prompt=prompts[i], max_new=4) for i in range(4)]
    be.submit(reqs)
    results = be.drain()
    be.close()
    want, _ = large.generate(prompts[:4], 8, 4)
    assert sorted(r.rid for r in results) == [0, 1, 2, 3]
    for res in results:
        assert res.tokens.dtype == np.int32
        np.testing.assert_array_equal(res.tokens, want[res.rid])


def test_worker_death_surfaces_instead_of_hanging():
    """An M_L exception on the worker thread must raise on the caller's
    next poll, not hang drain forever."""
    class Boom:
        def generate(self, *a, **k):
            raise ValueError("boom")

    be = ThreadedBackend(Boom(), max_new=4, large_batch=1)
    be.submit([Request(rid=0, prompt=np.zeros(4, np.int32), max_new=4)])
    with pytest.raises(RuntimeError, match="worker died"):
        for _ in range(100):                    # bounded, not forever
            be.poll(timeout=0.05)
    be.close()


# ---------------------------------------------------------------------------
# Engine integration: parity across backends (acceptance)
# ---------------------------------------------------------------------------

def test_engine_parity_sync_thread_stub(runners):
    """Per-request greedy outputs must be bit-exact across all three
    M_L backends (and order-independent: results come back rid-sorted
    regardless of completion order)."""
    small, large, prompts = runners
    cont = ContinuousCascadeEngine(small, large, n_slots=8, min_tokens=2)
    tau = cont.calibrate(prompts, 8, 4, deferral_ratio=0.5)
    outs = {}
    for kind, kw in (("sync", {}), ("thread", {}),
                     ("stub", dict(stub_latency=0.002))):
        eng = ContinuousCascadeEngine(
            small, large, n_slots=8, tau=tau, min_tokens=2,
            early_exit=True, large_batch=4, large_backend=kind,
            large_max_wait=0.02, **kw)
        res = eng.run(make_requests(prompts, 4), 4)
        assert all(r.state == DONE for r in res.requests)
        assert [r.rid for r in res.requests] == list(range(16))
        outs[kind] = res
    np.testing.assert_array_equal(outs["sync"].tokens,
                                  outs["thread"].tokens)
    np.testing.assert_array_equal(outs["sync"].tokens, outs["stub"].tokens)
    np.testing.assert_array_equal(outs["sync"].deferred,
                                  outs["thread"].deferred)
    np.testing.assert_array_equal(outs["sync"].deferred,
                                  outs["stub"].deferred)


def test_mixed_flush_paths_identical_tokens(runners):
    """Regression (batch-shape policy unification): mid-run full-batch
    flushes + max-wait partials + end-of-run drain leftovers must all
    produce the same per-request tokens as one exact-size drain batch."""
    small, large, prompts = runners
    base = ContinuousCascadeEngine(small, large, n_slots=8, tau=1e9,
                                   min_tokens=2, early_exit=True,
                                   large_batch=None, large_backend="sync")
    want = base.run(make_requests(prompts, 4), 4)
    assert want.deferred.all()
    for kind in ("sync", "thread"):
        eng = ContinuousCascadeEngine(
            small, large, n_slots=8, tau=1e9, min_tokens=2,
            early_exit=True, large_batch=3, large_backend=kind,
            large_max_wait=0.01)
        res = eng.run(make_requests(prompts, 4), 4)
        np.testing.assert_array_equal(res.tokens, want.tokens)
        # 16 deferrals in batches of 3 -> at least one partial flush
        # (padded) and several full ones; tokens unaffected either way
        assert res.stats["ml_batches"] >= 6
        assert res.stats["ml_batch_occupancy"] < 1.0


def test_threaded_steps_interleave_with_large_regeneration(runners,
                                                           tmp_path):
    """Acceptance: with the ThreadedBackend on a ragged Poisson
    workload, the audit log must show M_S `step` events BETWEEN a
    `large_submit` and its `large_complete` — M_S decode proceeded
    while M_L regenerated — and nonzero M_L queue-depth samples."""
    small, large, _ = runners
    key = jax.random.PRNGKey(5)
    lens = [6, 10] * 8
    prompts = ragged_prompts(key, lens, small.cfg.vocab_size)
    arrivals = poisson_arrivals(len(prompts), rate=400.0, seed=1)
    # pre-warm every M_L shape the run can need so worker-side compile
    # doesn't serialize the first overlap window
    for plen in (6, 10):
        pad = np.zeros((4, plen), np.int32)
        large.generate(pad, plen, 6)
    audit = str(tmp_path / "audit.jsonl")
    eng = ContinuousCascadeEngine(small, large, n_slots=4, tau=1e9,
                                  min_tokens=2, early_exit=True,
                                  large_batch=4, large_backend="thread",
                                  large_max_wait=0.05)
    res = eng.run(make_requests(prompts, 6, arrivals), 6,
                  audit_path=audit)
    assert res.deferred.all()
    # per-request parity against standalone M_L runs (ragged workloads
    # have no static reference)
    for r in res.requests:
        t, _ = large.generate(r.prompt[None, :], r.prompt_len, 6)
        np.testing.assert_array_equal(r.tokens, t[0])

    events = [json.loads(l) for l in open(audit)]
    submits = {e["rid"]: i for i, e in enumerate(events)
               if e["event"] == "large_submit"}
    completes = {e["rid"]: i for i, e in enumerate(events)
                 if e["event"] == "large_complete"}
    assert set(submits) == set(completes) == set(range(16))
    interleaved = 0
    for rid, i in submits.items():
        j = completes[rid]
        interleaved += sum(1 for e in events[i + 1:j]
                           if e["event"] == "step")
    assert interleaved > 0, "no M_S steps overlapped M_L regeneration"
    # telemetry saw the M_L queue genuinely backed up mid-run
    assert res.stats["ml_queue_depth_peak"] > 0
    assert any(e.get("ml_pending", 0) > 0 for e in events
               if e["event"] == "step")


def test_sync_backend_unchanged_reference(runners):
    """The sync backend with large_batch=None must stay bit-identical
    to the static engine (the PR-1 parity guarantee, now routed through
    the backend layer)."""
    from repro.serving import CascadeEngine
    small, large, prompts = runners
    static = CascadeEngine(small, large)
    tau = static.calibrate(prompts, 8, 4, deferral_ratio=0.5)
    sres = static.serve(prompts, 8, 4)
    cont = ContinuousCascadeEngine(small, large, n_slots=8, tau=tau,
                                   early_exit=False, large_backend="sync")
    cres = cont.run(make_requests(prompts, 4), 4)
    np.testing.assert_array_equal(cres.tokens, sres.tokens)
    np.testing.assert_array_equal(cres.deferred, sres.deferred)
    assert cres.stats["ml_backend"] == "sync"
    # large_batch=None: one exact-size drain batch per prompt length
    assert cres.stats["ml_batches"] == 1
    assert cres.stats["ml_batch_occupancy"] == 1.0
