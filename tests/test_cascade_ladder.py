"""N-tier cascade ladder tests: 2-tier spec-vs-legacy bit-exact parity
(slot and paged backends), 3-tier greedy parity against a sequential
reference, per-edge calibration through the unified surface, online tau
recalibration (drift convergence + stationary hysteresis), deferral
signals, and serve.py contradictory-flag rejection."""
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.calibration import (calibrate_edges, expected_compute_cost,
                                    ladder_compute_cost)
from repro.core.deferral import (SemanticAgreementSignal, SignalObservation,
                                 pairwise_agreement)
from repro.core.recalibration import (EdgeRecalibrator, RecalibConfig,
                                      TauController)
from repro.data.synthetic import make_lm_stream
from repro.models import transformer as tfm
from repro.serving import (CascadeSpec, CascadeTier, ContinuousCascadeEngine,
                           DeferralEdge, EngineConfig, ModelRunner,
                           PagedConfig, make_requests)

PROMPT_LEN, MAX_NEW, N_REQ = 8, 4, 12


@pytest.fixture(scope="module")
def ladder():
    """Three tiny runners (small < mid < large) + calibration and live
    prompt batches."""
    key = jax.random.PRNGKey(0)
    s_cfg = reduced(get_config("internlm2-1.8b"))
    m_cfg = s_cfg.replace(name="mid", n_layers=3)
    l_cfg = s_cfg.replace(name="large", n_layers=3, d_ff=768)
    small = ModelRunner(s_cfg, tfm.init_params(s_cfg, key))
    mid = ModelRunner(m_cfg, tfm.init_params(m_cfg,
                                             jax.random.fold_in(key, 1)))
    large = ModelRunner(l_cfg, tfm.init_params(l_cfg,
                                               jax.random.fold_in(key, 2)))
    cal = make_lm_stream(jax.random.fold_in(key, 3), N_REQ, PROMPT_LEN,
                         s_cfg.vocab_size)
    live = make_lm_stream(jax.random.fold_in(key, 4), N_REQ, PROMPT_LEN,
                          s_cfg.vocab_size)
    return small, mid, large, cal, live


def _legacy_engine(small, large, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return ContinuousCascadeEngine(small, large, **kw)


# ---------------------------------------------------------------------------
# Tentpole invariant: a 2-tier CascadeSpec reproduces the legacy engine
# bit-exactly, on both KV backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["slot", "paged"])
def test_two_tier_spec_matches_legacy(ladder, backend):
    small, _, large, cal, live = ladder
    paged_kw = dict(block_size=4, prefill_chunk=4) if backend == "paged" \
        else {}
    legacy = _legacy_engine(small, large, n_slots=4, backend=backend,
                            **paged_kw)
    tau = legacy.calibrate(cal, PROMPT_LEN, MAX_NEW, deferral_ratio=0.4)
    ref = legacy.run(make_requests(live, MAX_NEW), MAX_NEW)

    spec = CascadeSpec.two_tier(small, large, tau=tau)
    cfg = EngineConfig(n_slots=4, backend=backend,
                       paged=PagedConfig(**paged_kw))
    new = ContinuousCascadeEngine(spec, cfg).run(
        make_requests(live, MAX_NEW), MAX_NEW)

    assert np.array_equal(ref.tokens, new.tokens)
    assert np.array_equal(ref.confidence, new.confidence)
    assert np.array_equal(ref.deferred, new.deferred)
    assert np.array_equal(ref.early_exited, new.early_exited)
    assert ref.stats["compute_cost"] == new.stats["compute_cost"]
    # 2-tier ladder cost is bitwise the legacy scalar formula
    assert new.stats["compute_cost"] == expected_compute_cost(
        new.deferral_ratio, 0.2, 1.0)


def test_deprecation_shim_equivalence(ladder):
    small, _, large, _, _ = ladder
    with pytest.warns(DeprecationWarning, match="CascadeSpec"):
        eng = ContinuousCascadeEngine(small, large, n_slots=3, tau=-1.5,
                                      margin=0.1, min_tokens=3,
                                      backend="paged", block_size=4,
                                      large_backend="thread", large_batch=2,
                                      cost_small=0.3)
    assert eng.spec.n_tiers == 2
    assert eng.tau == -1.5 and eng.margin == 0.1 and eng.min_tokens == 3
    assert eng.config.backend == "paged"
    assert eng.config.paged.block_size == 4
    assert eng.config.ml.kind == "thread" and eng.config.ml.large_batch == 2
    assert eng.spec.tiers[0].cost == 0.3
    with pytest.raises(TypeError, match="unknown"):
        _legacy_engine(small, large, not_a_kwarg=1)
    # spec-first construction must stay warning-free
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        ContinuousCascadeEngine(CascadeSpec.two_tier(small, large),
                                EngineConfig(n_slots=2))


# ---------------------------------------------------------------------------
# 3-tier ladder vs sequential reference
# ---------------------------------------------------------------------------

def _sequential_reference(runners, taus, prompts, max_new):
    """Greedy N-tier cascade, one tier at a time over the whole batch:
    tier i generates for everything that reached it; rows with
    conf < taus[i] move on."""
    n = prompts.shape[0]
    final = np.zeros((n, max_new), np.int64)
    served = np.zeros(n, np.int64)
    reach = np.arange(n)
    for i, r in enumerate(runners):
        tokens, conf = r.generate(prompts[reach], PROMPT_LEN, max_new)
        tokens, conf = np.asarray(tokens), np.asarray(conf)
        final[reach] = tokens
        served[reach] = i
        if i == len(runners) - 1:
            break
        reach = reach[conf < taus[i]]
        if reach.size == 0:
            break
    return final, served


def test_three_tier_matches_sequential_reference(ladder):
    small, mid, large, cal, live = ladder
    spec = CascadeSpec(
        tiers=[CascadeTier("small", runner=small, cost=0.2),
               CascadeTier("mid", runner=mid, cost=0.5),
               CascadeTier("large", runner=large, cost=1.0)],
        edges=[DeferralEdge(), DeferralEdge()])
    taus = calibrate_edges(spec, cal, max_new=MAX_NEW,
                           deferral_ratio=[0.5, 0.5])
    assert taus == spec.taus and len(taus) == 2

    eng = ContinuousCascadeEngine(spec, EngineConfig(n_slots=4,
                                                     early_exit=False))
    res = eng.run(make_requests(live, MAX_NEW), MAX_NEW)
    ref_tokens, ref_served = _sequential_reference(
        [small, mid, large], taus, live, MAX_NEW)

    assert np.array_equal(res.tokens, ref_tokens)
    assert [r.tier for r in res.requests] == ref_served.tolist()
    assert res.stats["n_tiers"] == 3
    assert res.stats["tier_served"] == np.bincount(
        ref_served, minlength=3).tolist()
    # reach fractions: tier 0 sees everything, deeper tiers the deferrals
    reach = res.stats["tier_reach"]
    assert reach[0] == 1.0 and reach[1] >= reach[2]
    assert res.stats["compute_cost"] == pytest.approx(
        ladder_compute_cost(reach, [0.2, 0.5, 1.0]))


def test_calibrate_edges_sentinels_and_unification(ladder):
    small, _, large, cal, _ = ladder
    spec = CascadeSpec.two_tier(small, large)
    # unified surface: engine.calibrate is a thin wrapper over
    # calibrate_edges — same validation batch, same tau
    tau = calibrate_edges(spec, cal, max_new=MAX_NEW,
                          deferral_ratio=0.4)[0]
    eng = ContinuousCascadeEngine(CascadeSpec.two_tier(small, large),
                                  EngineConfig(n_slots=4))
    assert eng.calibrate(cal, PROMPT_LEN, MAX_NEW,
                         deferral_ratio=0.4) == tau
    # ratio sentinels survive the ladder path
    lo = calibrate_edges(CascadeSpec.two_tier(small, large), cal,
                         max_new=MAX_NEW, deferral_ratio=0.0)[0]
    hi = calibrate_edges(CascadeSpec.two_tier(small, large), cal,
                         max_new=MAX_NEW, deferral_ratio=1.0)[0]
    assert lo < tau < hi
    with pytest.raises(ValueError, match="deferral ratios"):
        calibrate_edges(CascadeSpec.two_tier(small, large), cal,
                        max_new=MAX_NEW, deferral_ratio=[0.2, 0.3])


# ---------------------------------------------------------------------------
# Online tau recalibration
# ---------------------------------------------------------------------------

def _poisson_conf_stream(rng, n, mean, spread=1.0):
    """Confidence stream with Poisson-thinned burstiness: inter-arrival
    gaps don't matter to the controller, only the conf marginal, but
    drawing per-arrival keeps the test honest about streaming order."""
    return rng.normal(mean, spread, size=n)


def test_recalibration_converges_under_drift():
    rng = np.random.default_rng(0)
    base = _poisson_conf_stream(rng, 4000, mean=-2.0)
    tau0 = float(np.quantile(base, 0.2))          # offline calibration
    # the gate guarantees convergence only to within its deadband, so a
    # +-0.05 acceptance needs deadband < 0.05
    ctl = TauController(tau0, 0.2, RecalibConfig(ewma_alpha=0.02,
                                                 deadband=0.04,
                                                 rearm=0.01))
    drifted_mean = -3.5                           # traffic got harder
    stream = _poisson_conf_stream(rng, 8000, mean=drifted_mean)
    for c in stream:
        ctl.observe(float(c))
    # realized deferral ratio of the final tau on fresh drifted traffic
    fresh = _poisson_conf_stream(rng, 4000, mean=drifted_mean)
    realized = float((fresh < ctl.tau).mean())
    assert ctl.n_updates > 0
    assert abs(realized - 0.2) < 0.05
    # trace records movement for the bench artifact
    assert ctl.trace[0] == (0, tau0) and len(ctl.trace) > 1


def test_recalibration_stationary_hysteresis():
    rng = np.random.default_rng(1)
    # tau0 at the exact 0.2 quantile of the (stationary) N(-2, 1)
    # stream: the EWMA drift detector sees only sampling noise, which
    # the deadband must absorb — tau genuinely stays put
    tau0 = -2.0 + 1.0 * -0.8416212335729143
    ctl = TauController(tau0, 0.2)
    for c in _poisson_conf_stream(rng, 6000, mean=-2.0):
        ctl.observe(float(c))
    assert ctl.n_updates == 0 and ctl.tau == tau0


def test_recalibrator_validation():
    with pytest.raises(ValueError, match="rearm"):
        RecalibConfig(deadband=0.05, rearm=0.1)
    with pytest.raises(ValueError, match="target_ratio"):
        TauController(0.0, 1.5)
    with pytest.raises(ValueError, match="target ratios"):
        EdgeRecalibrator([0.0, 0.0], [0.2])
    rec = EdgeRecalibrator([-1.0, -2.0], 0.2)
    assert rec.tau(0) == -1.0 and rec.tau(1) == -2.0
    s = rec.summary()
    assert s["tau_final"] == [-1.0, -2.0] and s["tau_updates"] == [0, 0]


def test_engine_recalibration_stats(ladder):
    small, _, large, cal, live = ladder
    spec = CascadeSpec.two_tier(small, large)
    calibrate_edges(spec, cal, max_new=MAX_NEW, deferral_ratio=0.4)
    cfg = EngineConfig(n_slots=4,
                       recalibration=RecalibConfig(warmup=4,
                                                   deadband=0.1),
                       recalib_target=0.4)
    res = ContinuousCascadeEngine(spec, cfg).run(
        make_requests(live, MAX_NEW), MAX_NEW)
    rc = res.stats["recalibration"]
    assert set(rc) == {"tau_final", "tau_updates", "ewma_ratio",
                       "tau_trace"}
    assert len(rc["tau_final"]) == 1
    # the stats' live tau is the controller's, not the spec's frozen one
    assert res.stats["edge_tau"] == rc["tau_final"]


# ---------------------------------------------------------------------------
# Deferral signals
# ---------------------------------------------------------------------------

def test_pairwise_agreement_values():
    same = np.tile(np.arange(5), (3, 1))
    assert pairwise_agreement(same) == 1.0
    disjoint = np.stack([np.zeros(4), np.ones(4)])
    assert pairwise_agreement(disjoint) == 0.0
    # [3, 2] matrix with one disagreeing row: pairs (0,1)=1.0,
    # (0,2)=(1,2)=0.5 -> mean 2/3
    m = np.array([[1, 2], [1, 2], [1, 9]])
    assert pairwise_agreement(m) == pytest.approx(2.0 / 3.0)


def test_runner_sample_deterministic(ladder):
    small, _, _, cal, _ = ladder
    prompts = cal[:3]
    a = small.sample(prompts, PROMPT_LEN, MAX_NEW, seed=7, temperature=0.8)
    b = small.sample(prompts, PROMPT_LEN, MAX_NEW, seed=7, temperature=0.8)
    c = small.sample(prompts, PROMPT_LEN, MAX_NEW, seed=8, temperature=0.8)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    with pytest.raises(ValueError, match="temperature"):
        small.sample(prompts, PROMPT_LEN, MAX_NEW, temperature=0.0)


def test_semantic_agreement_signal(ladder):
    small, _, _, cal, _ = ladder
    sig = SemanticAgreementSignal(k=3, temperature=0.8)
    assert not sig.supports_running
    obs = SignalObservation(prompt=np.asarray(cal[0]), mean_confidence=-1.0,
                            runner=small, max_new=MAX_NEW)
    score = sig.finalize(obs)
    assert 0.0 <= score <= 1.0
    assert sig.finalize(obs) == score      # deterministic per prompt
    with pytest.raises(ValueError, match="remote"):
        sig.finalize(SignalObservation(prompt=np.asarray(cal[0]),
                                       mean_confidence=-1.0, runner=None))
    with pytest.raises(ValueError, match="k >= 2"):
        SemanticAgreementSignal(k=1)


def test_spec_validation():
    with pytest.raises(ValueError, match="at least 2 tiers"):
        CascadeSpec(tiers=[CascadeTier("only", runner=object())], edges=[])
    with pytest.raises(ValueError, match="deferral edges"):
        CascadeSpec(tiers=[CascadeTier("a", runner=object()),
                           CascadeTier("b", runner=object())], edges=[])
    with pytest.raises(ValueError, match="tier 0"):
        CascadeSpec(tiers=[CascadeTier("a"),
                           CascadeTier("b", runner=object())],
                    edges=[DeferralEdge()])
    with pytest.raises(ValueError, match="runner or a backend"):
        CascadeSpec(tiers=[CascadeTier("a", runner=object()),
                           CascadeTier("b")],
                    edges=[DeferralEdge()])
    # sampling signal on an edge whose gating tier is remote-only
    with pytest.raises(ValueError, match="samples"):
        CascadeSpec(
            tiers=[CascadeTier("a", runner=object()),
                   CascadeTier("b", backend="sync"),
                   CascadeTier("c", runner=object())],
            edges=[DeferralEdge(),
                   DeferralEdge(signal="semantic_agreement")])


# ---------------------------------------------------------------------------
# serve.py rejects contradictory flag combinations at argparse time
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("argv", [
    ["--large-backend", "sync", "--ml-address", "h:1"],
    ["--large-backend", "thread", "--ml-spawn", "2"],
    ["--large-backend", "stub", "--ml-retries", "5"],
    ["--large-backend", "sync", "--stub-latency", "0.1"],
    ["--backend", "slot", "--block-size", "4"],
    ["--backend", "slot", "--paged-kernel", "on"],
    ["--backend", "slot", "--no-prefix-sharing"],
    ["--recalib-step", "0.2"],
    ["--signal-k", "8"],
    ["--engine", "static", "--tiers", "3"],
    ["--engine", "static", "--recalibrate"],
    ["--tiers", "1"],
    ["--tiers", "3", "--large-backend", "socket", "--ml-address", "h:1"],
    ["--large-backend", "socket"],
])
def test_serve_rejects_contradictory_flags(argv):
    from repro.launch import serve
    with pytest.raises(SystemExit) as exc:
        serve.main(argv)
    assert exc.value.code == 2                  # argparse error exit
