"""Training substrate tests: optimizer, schedules, checkpointing, loop."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gatekeeper import GatekeeperConfig
from repro.data.pipeline import BatchIterator
from repro.data.synthetic import (make_captions, make_classification,
                                  make_lm_stream, make_qa)
from repro.models.classifier import (MLPClassifierConfig, classifier_forward,
                                     init_classifier)
from repro.training import checkpoint, optim
from repro.training.loop import evaluate_classifier, make_train_step, train


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    cfg = optim.AdamWConfig(lr=0.1, weight_decay=0.0, schedule="constant",
                            warmup_steps=0, clip_norm=None)
    state = optim.adamw_init(params)
    for _ in range(300):
        grads = jax.tree.map(lambda p: 2 * p, params)
        params, state, _ = optim.adamw_update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_schedule_shapes():
    cfg = optim.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            schedule="cosine", min_lr_ratio=0.1)
    lrs = [float(optim.schedule_lr(cfg, jnp.asarray(s)))
           for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5, abs=0.01)
    assert lrs[2] == pytest.approx(1.0, abs=0.01)
    assert lrs[4] == pytest.approx(0.1, abs=0.01)


def test_grad_clip():
    params = {"w": jnp.zeros(4)}
    cfg = optim.AdamWConfig(clip_norm=1.0)
    state = optim.adamw_init(params)
    grads = {"w": jnp.full(4, 100.0)}
    _, _, m = optim.adamw_update(cfg, grads, state, params)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_classifier_trains_on_synthetic():
    key = jax.random.PRNGKey(0)
    data = make_classification(key, 2000, n_classes=4, hard_frac=0.0)
    cfg = MLPClassifierConfig(d_in=data.x.shape[1], n_classes=4,
                              hidden=(32,))
    params = init_classifier(cfg, key)
    step = make_train_step(lambda p, b: classifier_forward(p, cfg, b["inputs"]),
                           optim.AdamWConfig(lr=1e-2, total_steps=100),
                           loss_kind="ce")
    it = BatchIterator({"inputs": data.x, "targets": data.y}, 128)
    res = train(params, step, it.forever(), 100, log_every=100)
    _, _, correct = evaluate_classifier(
        lambda p, x: classifier_forward(p, cfg, x), res.params,
        data.x, data.y)
    assert correct.mean() > 0.9         # easy-only data is learnable


def test_gatekeeper_stage_reduces_incorrect_confidence():
    """Stage-2 fine-tuning raises entropy on incorrect predictions."""
    key = jax.random.PRNGKey(1)
    data = make_classification(key, 3000, n_classes=8, hard_frac=0.5)
    cfg = MLPClassifierConfig(d_in=data.x.shape[1], n_classes=8, hidden=(16,))
    params = init_classifier(cfg, key)
    apply_fn = lambda p, b: classifier_forward(p, cfg, b["inputs"])
    it = BatchIterator({"inputs": data.x, "targets": data.y}, 256)
    step1 = make_train_step(apply_fn, optim.AdamWConfig(lr=1e-2,
                                                        total_steps=150),
                            loss_kind="ce")
    params = train(params, step1, it.forever(), 150, log_every=200).params
    step2 = make_train_step(apply_fn,
                            optim.AdamWConfig(lr=3e-3, total_steps=100),
                            loss_kind="gatekeeper",
                            gk_cfg=GatekeeperConfig(alpha=0.2))
    opt = optim.adamw_init(params)
    batch = {"inputs": jnp.asarray(data.x[:512]),
             "targets": jnp.asarray(data.y[:512])}
    _, _, m0 = step2(params, opt, batch)
    params2 = train(params, step2, it.forever(), 100, log_every=200).params
    _, _, m1 = step2(params2, optim.adamw_init(params2), batch)
    assert float(m1["mean_entropy_incorrect"]) > \
        float(m0["mean_entropy_incorrect"])


def test_checkpoint_roundtrip():
    key = jax.random.PRNGKey(2)
    tree = {"a": jax.random.normal(key, (4, 5)),
            "nested": {"b": jnp.arange(7, dtype=jnp.int32)}}
    with tempfile.TemporaryDirectory() as tmp:
        checkpoint.save_checkpoint(tmp, tree, step=42)
        restored = checkpoint.restore_checkpoint(tmp, tree)
        assert checkpoint.checkpoint_step(tmp) == 42
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_generators_shapes():
    key = jax.random.PRNGKey(3)
    qa = make_qa(key, 100)
    assert qa.tokens.shape == (100, 8)
    assert qa.loss_mask.sum() == 100          # one answer position each
    caps = make_captions(key, 50, n_patches=4, d_model=16)
    assert caps.patches.shape == (50, 4, 16)
    assert caps.tokens.shape[1] == 4
    stream = make_lm_stream(key, 10, 64, 512)
    assert stream.shape == (10, 64) and stream.max() < 512


def test_batch_iterator_deterministic():
    data = {"x": np.arange(100)}
    it1 = BatchIterator(data, 10, key=jax.random.PRNGKey(0))
    it2 = BatchIterator(data, 10, key=jax.random.PRNGKey(0))
    b1 = next(iter(it1.epoch()))
    b2 = next(iter(it2.epoch()))
    np.testing.assert_array_equal(b1["x"], b2["x"])
    assert len(it1) == 10
