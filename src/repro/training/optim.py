"""Optimizers in pure JAX (optax is not installed in this container).

AdamW with decoupled weight decay, global-norm clipping, and cosine/linear
schedules. Optimizer state mirrors the param pytree, so it inherits param
shardings (ZeRO-style: FSDP-sharded params → FSDP-sharded moments).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.sharding import AbstractParam


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0
    schedule: str = "cosine"        # constant | cosine | linear
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "cosine":
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = 1.0 - (1 - cfg.min_lr_ratio) * frac
    else:
        raise ValueError(cfg.schedule)
    return cfg.lr * warm * decay


def adamw_init(params: Any) -> AdamWState:
    def zeros_like(p):
        if isinstance(p, AbstractParam):
            # moments kept in fp32 regardless of param dtype (bf16-safe)
            return AbstractParam(p.shape, jnp.float32, p.logical_axes)
        return jnp.zeros(p.shape, jnp.float32)
    is_leaf = lambda x: isinstance(x, AbstractParam)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros_like, params, is_leaf=is_leaf),
        nu=jax.tree.map(zeros_like, params, is_leaf=is_leaf),
    )


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, grads: Any, state: AdamWState,
                 params: Any):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    return (new_params, AdamWState(step, new_mu, new_nu),
            {"lr": lr, "grad_norm": gnorm})


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: float = 0.1
    momentum: float = 0.9
    weight_decay: float = 0.0


def sgd_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgd_update(cfg: SGDConfig, grads, vel, params):
    def upd(g, v, p):
        g32 = g.astype(jnp.float32) + cfg.weight_decay * p.astype(jnp.float32)
        v = cfg.momentum * v + g32
        return (p.astype(jnp.float32) - cfg.lr * v).astype(p.dtype), v
    flat = jax.tree.map(upd, grads, vel, params)
    new_p = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_p, new_v
