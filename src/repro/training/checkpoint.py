"""Checkpointing without orbax: pytree -> (structure json, npz of leaves).

Host-gathered (this container is single-host); sharded restore re-places
leaves with the provided shardings.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save_checkpoint(path: str, tree: Any, step: Optional[int] = None):
    os.makedirs(path, exist_ok=True)
    names, leaves, _ = _flatten_with_paths(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    meta = {"names": names, "step": step,
            "dtypes": [str(np.asarray(l).dtype) for l in leaves]}
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f)


def restore_checkpoint(path: str, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). Optionally device_put with `shardings`."""
    names, like_leaves, treedef = _flatten_with_paths(like)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    assert meta["names"] == names, "checkpoint/model structure mismatch"
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves = [jnp.asarray(data[f"leaf_{i}"]) for i in range(len(names))]
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(shardings)
        leaves = [jax.device_put(l, s) for l, s in zip(leaves, sh_leaves)]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def checkpoint_step(path: str) -> Optional[int]:
    try:
        with open(os.path.join(path, "meta.json")) as f:
            return json.load(f).get("step")
    except FileNotFoundError:
        return None
