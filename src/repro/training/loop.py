"""Training loops: Stage-1 standard training and Stage-2 Gatekeeper
fine-tuning (the paper's two-stage recipe, §3.2), for classifiers and
token models alike.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gatekeeper import (GatekeeperConfig, gatekeeper_loss,
                                   standard_ce_loss)
from repro.core.baselines import static_partition_loss
from repro.training import optim


@dataclasses.dataclass
class TrainResult:
    params: Any
    history: Dict[str, list]


def make_train_step(apply_fn: Callable, opt_cfg: optim.AdamWConfig,
                    loss_kind: str = "ce",
                    gk_cfg: Optional[GatekeeperConfig] = None,
                    aux_weight: float = 0.0):
    """Build a jitted (params, opt_state, batch) -> (params, opt_state, metrics).

    apply_fn(params, batch) must return either logits or (logits, aux_loss).
    batch: {"inputs": ..., "targets": ..., optional "loss_mask", "easy_mask"}.
    loss_kind: "ce" (Stage 1) | "gatekeeper" (Stage 2) | "static_partition".
    """

    def loss_fn(params, batch):
        out = apply_fn(params, batch)
        model_aux = jnp.zeros((), jnp.float32)
        if isinstance(out, tuple):
            logits, model_aux = out
        else:
            logits = out
        mask = batch.get("loss_mask")
        if loss_kind == "ce":
            loss, aux = standard_ce_loss(logits, batch["targets"], mask)
        elif loss_kind == "gatekeeper":
            loss, aux = gatekeeper_loss(logits, batch["targets"], gk_cfg, mask)
        elif loss_kind == "static_partition":
            loss, aux = static_partition_loss(
                logits, batch["targets"], batch["easy_mask"],
                alpha=gk_cfg.alpha if gk_cfg else 0.5, valid_mask=mask)
        else:
            raise ValueError(loss_kind)
        total = loss + aux_weight * model_aux
        aux = dict(aux)
        aux["model_aux"] = model_aux
        return total, aux

    @jax.jit
    def step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt_state, om = optim.adamw_update(opt_cfg, grads, opt_state,
                                                   params)
        metrics = {**aux, **om, "total_loss": loss}
        return params, opt_state, metrics

    return step


def train(params, step_fn, batches, n_steps: int,
          log_every: int = 50, log_fn=None) -> TrainResult:
    """Generic loop over an (infinite) batch iterator."""
    opt_state = optim.adamw_init(params)
    history: Dict[str, list] = {}
    it = iter(batches)
    for i in range(n_steps):
        batch = next(it)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (i + 1) % log_every == 0 or i == 0:
            m = {k: float(v) for k, v in metrics.items()
                 if jnp.ndim(v) == 0}
            for k, v in m.items():
                history.setdefault(k, []).append(v)
            history.setdefault("step", []).append(i + 1)
            if log_fn:
                log_fn(i + 1, m)
    return TrainResult(params=params, history=history)


def evaluate_classifier(apply_fn, params, x, y, batch: int = 4096):
    """Returns (predictions, max-softmax confidence, correctness)."""
    preds, confs = [], []
    for i in range(0, len(x), batch):
        logits = apply_fn(params, jnp.asarray(x[i:i + batch]))
        p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        preds.append(np.asarray(p.argmax(-1)))
        confs.append(np.asarray(p.max(-1)))
    preds = np.concatenate(preds)
    confs = np.concatenate(confs)
    return preds, confs, (preds == np.asarray(y)).astype(np.float64)
