"""Logical-axis sharding (MaxText-style) for params, activations and caches.

Every parameter/activation carries a tuple of *logical* axis names; a rule
table maps logical names to mesh axes. The builder is divisibility-aware:
GSPMD rejects explicit shardings on non-divisible dims (verified in this
container), so a rule that doesn't divide falls back to replication, and a
mesh axis is never used twice within one PartitionSpec (first logical axis
that can take it wins — e.g. batch=1 long-context decode frees the `data`
axis for the KV-cache sequence dim).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_mesh(shape, axes) -> Mesh:
    """Version-portable `jax.make_mesh` with Auto axis types: jax 0.4.x
    predates `jax.sharding.AxisType` (Auto is its only behaviour)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
    return jax.make_mesh(shape, axes)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """Version-portable shard_map: jax >= 0.5 exposes `jax.shard_map`
    (replication check kwarg `check_vma`); 0.4.x ships it under
    `jax.experimental.shard_map` with the kwarg named `check_rep`."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)

# ---------------------------------------------------------------------------
# Rule table: logical axis -> preferred mesh axes, in priority order.
# "pod" is a pure data-parallel axis; it only ever shards `batch`.
# ---------------------------------------------------------------------------
DEFAULT_RULES: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
    ("batch", ("pod", "data")),
    ("embed", ("data",)),          # FSDP/ZeRO-3 dim of weight matrices
    ("vocab", ("model",)),
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("ffn", ("model",)),
    ("experts", ("model",)),
    ("expert_embed", ("data",)),   # FSDP dim of expert matrices (gathered
                                   # per-layer at use — ZeRO-3 semantics)
    ("expert_ffn", ()),            # alt TP dim of expert matrices; route the
                                   # decode path to "gather tokens" when set
    ("d_inner", ("model",)),       # SSM channel dim
    ("cache_seq", ("data",)),      # KV-cache seq; only wins when batch can't
    ("seq_mp", ("model",)),        # sequence-parallel regions (MoE dispatch)
    ("classes", ()),
    ("unembed_d", ()),             # d-dim of the fused unembed/entropy
                                   # contraction; -> ("data",) turns the
                                   # table d-gather into partial-logit psums
    ("layers", ()),
    ("seq", ()),
    ("kv_seq", ()),                # K/V seq dim: keep replicated under
                                   # sequence parallelism ("gather x once,
                                   # not k and v") unless overridden too
    ("head_dim", ()),
    ("kv_lora", ()),
    ("q_lora", ()),
    ("state", ()),
    ("conv", ()),
    ("act_embed", ()),             # activation embed dim: replicated (TP)
)


def rules_dict(overrides: Optional[dict] = None) -> dict:
    d = {k: tuple(v) for k, v in DEFAULT_RULES}
    if overrides:
        for k, v in overrides.items():
            d[k] = tuple(v) if v else ()
    return d


@dataclasses.dataclass(frozen=True)
class AbstractParam:
    """Pytree leaf standing in for a parameter during abstract init.

    Carries shape/dtype (for ShapeDtypeStruct) and logical axes (for
    sharding). Never allocates.
    """
    shape: Tuple[int, ...]
    dtype: Any
    logical_axes: Tuple[Optional[str], ...]

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (
            f"shape {self.shape} vs axes {self.logical_axes}")


def logical_to_spec(logical_axes: Sequence[Optional[str]],
                    shape: Sequence[int],
                    mesh: Mesh,
                    rules: Optional[dict] = None) -> P:
    """Build a PartitionSpec honoring divisibility and axis-uniqueness."""
    rules = rules or rules_dict()
    mesh_axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set = set()
    spec = []
    for dim, name in zip(shape, logical_axes):
        assigned: Tuple[str, ...] = ()
        if name is not None:
            remaining = dim
            picked = []
            for mesh_axis in rules.get(name, ()):
                if mesh_axis in used or mesh_axis not in mesh_axis_sizes:
                    continue
                size = mesh_axis_sizes[mesh_axis]
                if remaining % size == 0:
                    picked.append(mesh_axis)
                    used.add(mesh_axis)
                    remaining //= size
            assigned = tuple(picked)
        if len(assigned) == 0:
            spec.append(None)
        elif len(assigned) == 1:
            spec.append(assigned[0])
        else:
            spec.append(assigned)
    return P(*spec)


def sharding_for(leaf: AbstractParam, mesh: Mesh,
                 rules: Optional[dict] = None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(leaf.logical_axes, leaf.shape,
                                               mesh, rules))


def tree_shardings(tree: Any, mesh: Mesh, rules: Optional[dict] = None) -> Any:
    """Map a pytree of AbstractParam to NamedShardings (opt states reuse it)."""
    return jax.tree.map(
        lambda l: sharding_for(l, mesh, rules),
        tree, is_leaf=lambda x: isinstance(x, AbstractParam))


def tree_shape_structs(tree: Any) -> Any:
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype),
        tree, is_leaf=lambda x: isinstance(x, AbstractParam))


def constrain(x, logical_axes: Sequence[Optional[str]],
              mesh: Optional[Mesh] = None, rules: Optional[dict] = None):
    """with_sharding_constraint via logical axes; no-op without a mesh."""
    if mesh is None or len(mesh.devices.ravel()) <= 1:
        return x
    spec = logical_to_spec(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    """Carries the mesh + axis names through model code.

    mesh=None means single-device execution: all collectives/constraints
    become no-ops and MoE uses the local (non-all-to-all) path.
    """
    mesh: Optional[Mesh] = None
    data_axis: str = "data"
    model_axis: str = "model"
    pod_axis: Optional[str] = None
    rules: Optional[dict] = None

    @property
    def model_parallel_size(self) -> int:
        if self.mesh is None:
            return 1
        return dict(zip(self.mesh.axis_names,
                        self.mesh.devices.shape)).get(self.model_axis, 1)

    def constrain(self, x, logical_axes):
        return constrain(x, logical_axes, self.mesh, self.rules)


def param_count(tree: Any) -> int:
    """Total parameter count; works on real arrays and AbstractParams."""
    leaves = jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, AbstractParam))
    total = 0
    for l in leaves:
        shape = l.shape
        total += int(np.prod(shape)) if shape else 1
    return total
