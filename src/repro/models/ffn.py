"""Feed-forward blocks: dense (SwiGLU / GELU) and Mixture-of-Experts.

MoE design (TPU-adapted, MaxText-style, FLOP-honest):
  * Experts are sharded over the `model` mesh axis (expert parallelism).
  * Training/prefill ("scatter" path): activations are resharded so tokens
    are split over BOTH (data, model); each device routes its local tokens,
    packs per-destination capacity buffers, exchanges them with
    `lax.all_to_all` over the model axis, runs a sort + `lax.ragged_dot`
    grouped matmul over its local experts, and reverses the exchange.
    Compute and communication both scale with *active* (top-k) FLOPs — no
    GShard dense-dispatch einsum (which would be ~100x the useful FLOPs at
    384 experts).
  * Decode ("local" path): tokens are few; each model shard gathers only the
    assignments that hit its local experts into a small capacity buffer,
    computes, and the result is psum-combined over the model axis.
  * Single-device path (no mesh): same sort + ragged_dot math without
    collectives — used by smoke tests and CPU training, and as the oracle
    for the distributed paths.

Capacity overflow drops assignments (standard GShard semantics, gates NOT
renormalized); the router aux load-balance loss keeps overflow rare.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ParamFactory, swiglu
from repro.sharding import ParallelContext, shard_map


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    d_model: int
    d_ff: int
    activation: str = "swiglu"     # "swiglu" | "gelu"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                      # per-expert hidden dim
    n_experts: int
    top_k: int
    n_shared_experts: int = 0
    shared_d_ff: Optional[int] = None   # defaults to d_ff * n_shared
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    normalize_gates: bool = True   # renormalize top-k gates to sum 1


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def init_mlp(pf: ParamFactory, cfg: MLPConfig, stacked: int = 0) -> dict:
    L = (stacked,) if stacked else ()
    LA = ("layers",) if stacked else ()
    d, f = cfg.d_model, cfg.d_ff
    p = {"w_up": pf.param("w_up", L + (d, f), LA + ("embed", "ffn"), fan_in=d),
         "w_down": pf.param("w_down", L + (f, d), LA + ("ffn", "embed"), fan_in=f)}
    if cfg.activation == "swiglu":
        p["w_gate"] = pf.param("w_gate", L + (d, f), LA + ("embed", "ffn"), fan_in=d)
    else:
        p["b_up"] = pf.param("b_up", L + (f,), LA + ("ffn",), init="zeros")
        p["b_down"] = pf.param("b_down", L + (d,), LA + ("act_embed",), init="zeros")
    return p


def mlp_forward(params: dict, cfg: MLPConfig, x: jnp.ndarray,
                ctx: ParallelContext) -> jnp.ndarray:
    if cfg.activation == "swiglu":
        h = swiglu(jnp.einsum("btd,df->btf", x, params["w_gate"]),
                   jnp.einsum("btd,df->btf", x, params["w_up"]))
    else:
        h = jax.nn.gelu(jnp.einsum("btd,df->btf", x, params["w_up"])
                        + params["b_up"])
    h = ctx.constrain(h, ("batch", "seq", "ffn"))
    y = jnp.einsum("btf,fd->btd", h, params["w_down"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.activation != "swiglu":
        y = y + params["b_down"].astype(y.dtype)
    return ctx.constrain(y, ("batch", "seq", "act_embed"))


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def init_moe(pf: ParamFactory, cfg: MoEConfig, stacked: int = 0) -> dict:
    L = (stacked,) if stacked else ()
    LA = ("layers",) if stacked else ()
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": pf.param("router", L + (d, E), LA + ("embed", "experts"),
                           fan_in=d, dtype=jnp.float32),
        # dedicated logical axes so the expert matrices' FSDP/TP dims can be
        # re-ruled independently of dense params (hillclimb: "gather tokens,
        # not weights" at decode). Defaults reproduce the old
        # embed->data / ffn->() sharding exactly.
        "w_gate": pf.param("we_gate", L + (E, d, f),
                           LA + ("experts", "expert_embed", "expert_ffn"),
                           fan_in=d),
        "w_up": pf.param("we_up", L + (E, d, f),
                         LA + ("experts", "expert_embed", "expert_ffn"),
                         fan_in=d),
        "w_down": pf.param("we_down", L + (E, f, d),
                           LA + ("experts", "expert_ffn", "expert_embed"),
                           fan_in=f),
    }
    if cfg.n_shared_experts > 0:
        sf = cfg.shared_d_ff or cfg.d_ff * cfg.n_shared_experts
        shared_cfg = MLPConfig(cfg.d_model, sf, "swiglu")
        p["shared"] = init_mlp(pf.scope("shared"), shared_cfg, stacked)
    return p


def _route(router_w: jnp.ndarray, x2d: jnp.ndarray, cfg: MoEConfig):
    """Router: returns (gates [T,k] fp32, expert_idx [T,k] int32, aux_loss)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.normalize_gates:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # switch-style load-balance aux: E * sum_e f_e * P_e
    E = cfg.n_experts
    counts = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(counts.sum(), 1.0)
    frac_prob = probs.mean(axis=0)
    aux = E * jnp.sum(frac_tokens * frac_prob)
    return gates, idx.astype(jnp.int32), aux


def _expert_ffn(xs: jnp.ndarray, w_gate, w_up, w_down,
                group_sizes: jnp.ndarray) -> jnp.ndarray:
    """Grouped SwiGLU over sorted assignments. xs [A, d] sorted by expert;
    weights [E, d, f]; group_sizes [E]."""
    g = jax.lax.ragged_dot(xs, w_gate, group_sizes)
    u = jax.lax.ragged_dot(xs, w_up, group_sizes)
    h = swiglu(g, u)
    return jax.lax.ragged_dot(h, w_down, group_sizes)


def _expert_ffn_capacity(xflat: jnp.ndarray, eflat: jnp.ndarray,
                         w_gate, w_up, w_down, n_experts: int,
                         capacity_factor: float = 2.0) -> jnp.ndarray:
    """Per-expert-capacity batched SwiGLU (GShard-style block-diagonal).

    xflat [A, d] assignment rows; eflat [A] LOCAL expert id, with the
    sentinel id `n_experts` marking padding rows. Rows are packed into an
    [E+1, cap, d] buffer (sentinel bucket last, zero weights) and computed
    with batched einsums — FLOPs are E*cap*d*f, i.e. within capacity_factor
    of the useful work, unlike `lax.ragged_dot` whose XLA fallback computes
    every group densely (E x waste; verified in-container). On TPU this is
    also the MXU-friendly form. Per-expert overflow drops rows (standard
    GShard semantics). Returns [A, d] with dropped/padding rows zeroed.
    """
    A, d = xflat.shape
    cap = int(np.ceil(A / n_experts * capacity_factor))
    cap = max(8, int(np.ceil(cap / 8)) * 8)
    onehot = jax.nn.one_hot(eflat, n_experts + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    slot = (pos * onehot).sum(-1)
    slot = jnp.where(eflat < n_experts, slot, cap)        # drop sentinel
    buf = jnp.zeros((n_experts + 1, cap, d), xflat.dtype)
    buf = buf.at[jnp.minimum(eflat, n_experts), slot].set(xflat, mode="drop")
    wz = lambda w: jnp.concatenate(
        [w, jnp.zeros((1,) + w.shape[1:], w.dtype)], axis=0)
    h = swiglu(jnp.einsum("ecd,edf->ecf", buf, wz(w_gate)),
               jnp.einsum("ecd,edf->ecf", buf, wz(w_up)))
    out = jnp.einsum("ecf,efd->ecd", h, wz(w_down))
    res = out[jnp.minimum(eflat, n_experts), jnp.minimum(slot, cap - 1)]
    keep = ((slot < cap) & (eflat < n_experts))[:, None]
    return res * keep.astype(res.dtype)


def _moe_local_math(x2d, router_w, w_gate, w_up, w_down, cfg: MoEConfig):
    """Single-device oracle: full sort + ragged_dot over all experts."""
    T, d = x2d.shape
    gates, idx, aux = _route(router_w, x2d, cfg)
    A = T * cfg.top_k
    flat_e = idx.reshape(A)
    flat_g = gates.reshape(A)
    order = jnp.argsort(flat_e)
    tok = order // cfg.top_k
    xs = x2d[tok]
    group_sizes = jnp.bincount(flat_e, length=cfg.n_experts).astype(jnp.int32)
    out = _expert_ffn(xs, w_gate, w_up, w_down, group_sizes)
    y = jnp.zeros((T, d), out.dtype).at[tok].add(
        out * flat_g[order][:, None].astype(out.dtype))
    return y.astype(x2d.dtype), aux


def _pack_by_destination(x2d, tok, dst, valid, n_dst: int, capacity: int):
    """Scatter assignment rows into per-destination capacity buffers.

    Returns (buffer [n_dst, capacity, d], slot [A] position used (>=capacity
    means dropped)).
    """
    onehot = jax.nn.one_hot(dst, n_dst, dtype=jnp.int32) * valid[:, None]
    pos = jnp.cumsum(onehot, axis=0) - onehot          # rank within dest
    slot = (pos * onehot).sum(-1)                      # [A]
    slot = jnp.where(valid.astype(bool), slot, capacity)   # invalid -> dropped
    buf = jnp.zeros((n_dst, capacity, x2d.shape[-1]), x2d.dtype)
    buf = buf.at[dst, slot].set(x2d[tok], mode="drop")
    return buf, slot


def _moe_scatter_shard(x_loc, router_w, w_gate_loc, w_up_loc, w_down_loc,
                       cfg: MoEConfig, model_axis: str, mp: int):
    """Per-device body of the training/prefill MoE (inside shard_map).

    x_loc: [T_loc, d] tokens local to this device (sharded over data AND
    model). Expert weights: local shard [E_loc, d, f].
    """
    T_loc, d = x_loc.shape
    E = cfg.n_experts
    E_loc = E // mp
    gates, idx, aux = _route(router_w, x_loc, cfg)
    A = T_loc * cfg.top_k
    flat_e = idx.reshape(A)
    flat_g = gates.reshape(A)
    tok = jnp.arange(A) // cfg.top_k
    dst = flat_e // E_loc                               # owner shard
    cap = int(np.ceil(A / mp * cfg.capacity_factor))
    cap = max(8, int(np.ceil(cap / 8)) * 8)
    valid = jnp.ones((A,), jnp.int32)
    xsend, slot = _pack_by_destination(x_loc, tok, dst, valid, mp, cap)
    esend = jnp.full((mp, cap), E_loc, jnp.int32)      # sentinel = padding
    esend = esend.at[dst, slot].set(flat_e % E_loc, mode="drop")
    # exchange: after all_to_all, row m holds what shard m sent here
    xrecv = jax.lax.all_to_all(xsend, model_axis, 0, 0, tiled=True)
    erecv = jax.lax.all_to_all(esend, model_axis, 0, 0, tiled=True)
    # per-expert-capacity grouped compute over local experts
    xflat = xrecv.reshape(mp * cap, d)
    eflat = erecv.reshape(mp * cap)
    out = _expert_ffn_capacity(xflat, eflat, w_gate_loc, w_up_loc,
                               w_down_loc, E_loc,
                               capacity_factor=2.0 * cfg.capacity_factor)
    yrecv = out.reshape(mp, cap, d).astype(x_loc.dtype)
    ysend = jax.lax.all_to_all(yrecv, model_axis, 0, 0, tiled=True)
    # combine: gather each assignment's result from (dst, slot)
    res = ysend[dst, jnp.minimum(slot, cap - 1)]
    res = res * (slot < cap)[:, None].astype(res.dtype)
    y = jnp.zeros((T_loc, d), res.dtype).at[tok].add(
        res * flat_g[:, None].astype(res.dtype))
    dropped = (slot >= cap).astype(jnp.float32).mean()
    return y.astype(x_loc.dtype), aux, dropped


def _moe_decode_shard(x_loc, router_w, w_gate_loc, w_up_loc, w_down_loc,
                      cfg: MoEConfig, model_axis: str, mp: int):
    """Decode-path body: x_loc [T, d] REPLICATED over model axis; each shard
    computes contributions of its local experts, psum combines."""
    T, d = x_loc.shape
    E = cfg.n_experts
    E_loc = E // mp
    gates, idx, aux = _route(router_w, x_loc, cfg)
    A = T * cfg.top_k
    flat_e = idx.reshape(A)
    flat_g = gates.reshape(A)
    tok = jnp.arange(A) // cfg.top_k
    shard = jax.lax.axis_index(model_axis)
    base = shard * E_loc
    local = (flat_e >= base) & (flat_e < base + E_loc)
    e_loc = jnp.clip(flat_e - base, 0, E_loc - 1)
    # pack local assignments into a small capacity buffer
    cap = int(np.ceil(A / mp * 2.0))
    cap = max(8, int(np.ceil(cap / 8)) * 8)
    rank = jnp.cumsum(local.astype(jnp.int32)) - local.astype(jnp.int32)
    slot = jnp.where(local, rank, cap)
    xbuf = jnp.zeros((cap, d), x_loc.dtype).at[slot].set(x_loc[tok], mode="drop")
    ebuf = jnp.full((cap,), E_loc, jnp.int32).at[slot].set(e_loc, mode="drop")
    out = _expert_ffn_capacity(xbuf, ebuf, w_gate_loc, w_up_loc, w_down_loc,
                               E_loc, capacity_factor=2.0)
    res = out[jnp.minimum(slot, cap - 1)]
    res = res * ((slot < cap) & local)[:, None].astype(res.dtype)
    y = jnp.zeros((T, d), res.dtype).at[tok].add(
        res * flat_g[:, None].astype(res.dtype))
    y = jax.lax.psum(y, model_axis)
    aux = jax.lax.pmean(aux, model_axis)
    return y.astype(x_loc.dtype), aux


def _axes_of(spec_entry) -> tuple:
    """PartitionSpec entry -> tuple of mesh axis names."""
    if spec_entry is None:
        return ()
    if isinstance(spec_entry, str):
        return (spec_entry,)
    return tuple(spec_entry)


def _gather_dim(w, axes, dim):
    """Explicit FSDP-style all-gather of weight dim `dim` over mesh axes."""
    for ax in axes:
        w = jax.lax.all_gather(w, ax, axis=dim, tiled=True)
    return w


def moe_forward(params: dict, cfg: MoEConfig, x: jnp.ndarray,
                ctx: ParallelContext, decode: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE block. x [B, T, d] -> (y, aux_loss). Dispatches to the local,
    scatter (train/prefill) or decode path based on ctx/mesh.

    shard_map in_specs are DERIVED from the sharding rules so the step's
    parameter shardings and the shard_map body always agree (no silent
    GSPMD reshard). Two weight layouts are supported:
      * expert_embed sharded (default, ZeRO-3): the body all-gathers the
        weight's d-dim per layer before use — right for training where
        tokens >> weights.
      * expert_ffn sharded (decode hillclimb): weights stay put; the body
        all-gathers the TOKENS over the ffn-sharding axis, computes
        partial results against its (expert, f-slice) shard, psums, and
        slices its token rows back — right for decode where
        weights >> tokens (2 TB vs 1.8 MB for kimi-k2).
    """
    B, T, d = x.shape
    mp = ctx.model_parallel_size
    shared_y = 0.0
    if cfg.n_shared_experts > 0:
        sf = cfg.shared_d_ff or cfg.d_ff * cfg.n_shared_experts
        shared_y = mlp_forward(params["shared"], MLPConfig(d, sf, "swiglu"),
                               x, ctx)

    if ctx.mesh is None or mp == 1 or cfg.n_experts % mp != 0:
        x2d = x.reshape(B * T, d)
        y, aux = _moe_local_math(x2d, params["router"], params["w_gate"],
                                 params["w_up"], params["w_down"], cfg)
        return shared_y + y.reshape(B, T, d), aux

    mesh = ctx.mesh
    ma = ctx.model_axis
    batch_axes = tuple(a for a in (ctx.pod_axis, ctx.data_axis)
                       if a is not None and B % _axis_size(mesh, a) == 0)
    from jax.sharding import PartitionSpec as P
    from repro.sharding import logical_to_spec

    E, f = cfg.n_experts, cfg.d_ff
    rspec = logical_to_spec(("embed", "experts"), (d, E), mesh, ctx.rules)
    gspec = logical_to_spec(("experts", "expert_embed", "expert_ffn"),
                            (E, d, f), mesh, ctx.rules)
    dspec = logical_to_spec(("experts", "expert_ffn", "expert_embed"),
                            (E, f, d), mesh, ctx.rules)
    wspec = {"router": rspec, "w_gate": gspec, "w_up": gspec,
             "w_down": dspec}
    r_d_axes = _axes_of(rspec[0])
    r_e_axes = _axes_of(rspec[1])        # router must see ALL experts
    d_axes = _axes_of(gspec[1])          # expert_embed mesh axes
    f_axes = _axes_of(gspec[2])          # expert_ffn mesh axes
    assert len(f_axes) <= 1, "one ffn-sharding axis supported"

    def prep_weights(rw, wg, wu, wd):
        rw = _gather_dim(_gather_dim(rw, r_d_axes, 0), r_e_axes, 1)
        wg = _gather_dim(wg, d_axes, 1)
        wu = _gather_dim(wu, d_axes, 1)
        wd = _gather_dim(wd, d_axes, 2)
        return rw, wg, wu, wd

    if not decode and T % mp == 0:
        # scatter path: tokens over (batch axes, model); weights gathered
        # along any FSDP dims (tokens >> weights in training)
        xspec = P(batch_axes if batch_axes else None, ma, None)

        def body(xl, rw, wg, wu, wd):
            Bl, Tl, _ = xl.shape
            rw, wg, wu, wd = prep_weights(rw, wg, wu, wd)
            # scatter path computes against full-f experts
            wg = _gather_dim(wg, f_axes, 2)
            wu = _gather_dim(wu, f_axes, 2)
            wd = _gather_dim(wd, f_axes, 1)
            y, aux, dropped = _moe_scatter_shard(
                xl.reshape(Bl * Tl, d), rw, wg, wu, wd, cfg, ma, mp)
            # aux/dropped are per-device scalars; mean over ALL axes so the
            # outputs are replicated (shard_map out_spec P())
            allaxes = tuple(mesh.axis_names)
            aux = jax.lax.pmean(aux, allaxes)
            dropped = jax.lax.pmean(dropped, allaxes)
            return y.reshape(Bl, Tl, d), aux, dropped

        y, aux, _dropped = shard_map(
            body, mesh=mesh,
            in_specs=(xspec, wspec["router"], wspec["w_gate"],
                      wspec["w_up"], wspec["w_down"]),
            out_specs=(xspec, P(), P()),
            check_vma=False,
        )(x, params["router"], params["w_gate"], params["w_up"],
          params["w_down"])
        return shared_y + y, aux

    # decode path: tokens replicated over model, sharded over batch axes
    xspec = P(batch_axes if batch_axes else None, None, None)
    tok_gather_axes = tuple(a for a in f_axes if a in batch_axes)

    def body_dec(xl, rw, wg, wu, wd):
        Bl, Tl, _ = xl.shape
        rw, wg, wu, wd = prep_weights(rw, wg, wu, wd)
        x2 = xl.reshape(Bl * Tl, d)
        # "gather tokens, not weights": bring every device's tokens in,
        # compute against the local (E/mp, d, f/|f_axes|) weight shard,
        # psum the partial results, slice our token rows back out.
        for ax in tok_gather_axes:
            x2 = jax.lax.all_gather(x2, ax, axis=0, tiled=True)
        y, aux = _moe_decode_shard(x2, rw, wg, wu, wd, cfg, ma, mp)
        for ax in f_axes:
            # partial sums over the f-slice; tokens replicated over any
            # f-axis NOT in batch_axes, so psum alone is correct there
            y = jax.lax.psum(y, ax)
            if ax in tok_gather_axes:
                idx = jax.lax.axis_index(ax) * Bl * Tl
                y = jax.lax.dynamic_slice_in_dim(y, idx, Bl * Tl, 0)
        aux = jax.lax.pmean(aux, tuple(a for a in mesh.axis_names if a != ma))
        return y.reshape(Bl, Tl, d), aux

    y, aux = shard_map(
        body_dec, mesh=mesh,
        in_specs=(xspec, wspec["router"], wspec["w_gate"], wspec["w_up"],
                  wspec["w_down"]),
        out_specs=(xspec, P()),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    return shared_y + y, aux


def _axis_size(mesh, name):
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)
