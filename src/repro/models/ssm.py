"""Attention-free sequence mixers: RWKV6 ("Finch") and Mamba2 (SSD).

Both are linear recurrences over a per-head matrix state S in R^{K x V}:

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = q_t^T S_t                     (Mamba2, inclusive)
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)   (RWKV6, exclusive + bonus)

with data-dependent decay w_t — scalar per head for Mamba2 (the SSD case),
per key-channel for RWKV6. We implement one chunked kernel-style algorithm
for both (TPU adaptation: chunk-parallel matmuls feed the MXU; the only
sequential dependency is the O(T/chunk) state carry through `lax.scan`).

Stability: decay products are evaluated strictly as exp(cum_t - cum_s) with
t >= s (always <= 1); nothing is exponentiated positively, so no overflow.
The per-channel (RWKV) path materializes the [c, c, K] decay tensor per
chunk; the scalar (Mamba) path needs only [c, c].
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamFactory, rms_norm
from repro.sharding import ParallelContext


# ---------------------------------------------------------------------------
# Chunked linear attention core
# ---------------------------------------------------------------------------

def linear_attention_scan(q, k, v, logw, state0, *, mode="mamba", u=None):
    """Naive per-step scan — the oracle for the chunked path and tests.

    q,k: [B,T,H,K]; v: [B,T,H,V]; logw broadcastable to [B,T,H,K];
    state0: [B,H,K,V]. Returns (y [B,T,H,V], state [B,H,K,V]).
    """
    B, T, H, K = q.shape
    logw = jnp.broadcast_to(logw, (B, T, H, K)).astype(jnp.float32)

    def step(S, xs):
        qt, kt, vt, lw = xs
        w = jnp.exp(lw)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        if mode == "mamba":
            S = w[..., None] * S + kv
            y = jnp.einsum("bhk,bhkv->bhv", qt, S)
        else:   # rwkv
            Su = S + u[None, :, :, None] * kv
            y = jnp.einsum("bhk,bhkv->bhv", qt, Su)
            S = w[..., None] * S + kv
        return S, y

    xs = (q.astype(jnp.float32).transpose(1, 0, 2, 3),
          k.astype(jnp.float32).transpose(1, 0, 2, 3),
          v.astype(jnp.float32).transpose(1, 0, 2, 3),
          logw.transpose(1, 0, 2, 3))
    S, ys = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3), S


def linear_attention_chunked(q, k, v, logw, state0, *, mode="mamba",
                             u=None, chunk: int = 64):
    """Chunk-parallel evaluation of the recurrences above.

    Shapes as in `linear_attention_scan`; `logw` may be [B,T,H,1] (scalar
    decay, Mamba/SSD) or [B,T,H,K] (per-channel, RWKV6). T must be divisible
    by `chunk` (configs pad; decode uses `linear_attention_step`).
    """
    B, T, H, K = q.shape
    V = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    NC, c = T // chunk, chunk
    scalar_decay = (logw.shape[-1] == 1)

    def reshape(x):
        return x.astype(jnp.float32).reshape(B, NC, c, H, x.shape[-1]) \
                .transpose(1, 0, 2, 3, 4)  # [NC, B, c, H, *]

    qc, kc, vc = reshape(q), reshape(k), reshape(v)
    lw = reshape(jnp.broadcast_to(
        logw, (B, T, H, logw.shape[-1])))

    tri_incl = jnp.tril(jnp.ones((c, c), bool))
    tri_strict = jnp.tril(jnp.ones((c, c), bool), k=-1)

    def chunk_step(S, xs):
        qt, kt, vt, lwt = xs                       # [B,c,H,*]
        cum = jnp.cumsum(lwt, axis=1)              # inclusive [B,c,H,Kw]
        cum_ex = cum - lwt                         # exclusive
        last = cum[:, -1:, :, :]                   # [B,1,H,Kw]
        out_cum = cum if mode == "mamba" else cum_ex
        # inter-chunk: q decayed from chunk start against carried state
        qdec = qt * jnp.exp(_expand(out_cum, K))
        y = jnp.einsum("bthk,bhkv->bthv", qdec, S)
        # intra-chunk
        if scalar_decay:
            # A[t,s] = exp(out_cum_t - cum_s) — [B,H,c,c]
            diff = out_cum[:, :, None, :, 0] - cum[:, None, :, :, 0]
            tri = tri_incl if mode == "mamba" else tri_strict
            amat = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
            scores = jnp.einsum("bthk,bshk->btsh", qt, kt) * amat
        else:
            diff = out_cum[:, :, None, :, :] - cum[:, None, :, :, :]
            tri = tri_incl if mode == "mamba" else tri_strict
            amat = jnp.where(tri[None, :, :, None, None], jnp.exp(diff), 0.0)
            scores = jnp.einsum("bthk,bshk,btshk->btsh", qt, kt, amat)
        y = y + jnp.einsum("btsh,bshv->bthv", scores, vt)
        if mode == "rwkv":
            y = y + jnp.einsum("bthk,bthk,bthv->bthv",
                               qt * u[None, None, :, :], kt, vt)
        # state update: S' = exp(cum_last) * S + sum_s exp(cum_last-cum_s) k v
        kdec = kt * jnp.exp(_expand(last - cum, K))
        S = (jnp.exp(_expand(last, K))[:, 0, :, :, None] * S
             + jnp.einsum("bshk,bshv->bhkv", kdec, vt))
        return S, y

    S, ys = jax.lax.scan(chunk_step, state0.astype(jnp.float32),
                         (qc, kc, vc, lw))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, H, V)
    return y, S


def _expand(cum, K):
    """Broadcast a [..., Kw] decay (Kw in {1, K}) to [..., K]."""
    if cum.shape[-1] == 1:
        return jnp.broadcast_to(cum, cum.shape[:-1] + (K,))
    return cum


def linear_attention_step(qt, kt, vt, logw_t, S, *, mode="mamba", u=None):
    """Single decode step. qt,kt [B,H,K]; vt [B,H,V]; logw_t [B,H,Kw];
    S [B,H,K,V] fp32. Returns (y [B,H,V], S')."""
    K = qt.shape[-1]
    w = jnp.exp(_expand(logw_t.astype(jnp.float32), K))
    kv = jnp.einsum("bhk,bhv->bhkv", kt.astype(jnp.float32),
                    vt.astype(jnp.float32))
    if mode == "mamba":
        S = w[..., None] * S + kv
        y = jnp.einsum("bhk,bhkv->bhv", qt.astype(jnp.float32), S)
    else:
        Su = S + u[None, :, :, None] * kv
        y = jnp.einsum("bhk,bhkv->bhv", qt.astype(jnp.float32), Su)
        S = w[..., None] * S + kv
    return y, S


# ---------------------------------------------------------------------------
# RWKV6 block (time mix + channel mix)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    d_model: int
    d_ff: int
    head_dim: int = 64
    lora_mix: int = 32
    lora_decay: int = 64
    chunk: int = 32

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim


def init_rwkv_block(pf: ParamFactory, cfg: RWKVConfig, stacked: int = 0) -> dict:
    L = (stacked,) if stacked else ()
    LA = ("layers",) if stacked else ()
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    r = cfg.lora_mix
    p = {
        # data-dependent lerp (ddlerp) mixing: 5 streams (r,k,v,g,w)
        "mu_base": pf.param("mu_base", L + (5, d), LA + (None, "act_embed"),
                            init="uniform", scale=0.5),
        "mix_A": pf.param("mix_A", L + (d, 5 * r), LA + ("embed", None), fan_in=d),
        "mix_B": pf.param("mix_B", L + (5, r, d), LA + (None, None, "embed"),
                          fan_in=r),
        # projections
        "wr": pf.param("wr", L + (d, H, hd), LA + ("embed", "heads", "head_dim"), fan_in=d),
        "wk": pf.param("wk", L + (d, H, hd), LA + ("embed", "heads", "head_dim"), fan_in=d),
        "wv": pf.param("wv", L + (d, H, hd), LA + ("embed", "heads", "head_dim"), fan_in=d),
        "wg": pf.param("wg", L + (d, H, hd), LA + ("embed", "heads", "head_dim"), fan_in=d),
        "wo": pf.param("wo", L + (H, hd, d), LA + ("heads", "head_dim", "embed"),
                       fan_in=H * hd),
        # data-dependent decay: logw = -exp(w0 + tanh(x A_w) B_w)
        "w0": pf.param("w0", L + (H, hd), LA + ("heads", "head_dim"),
                       init="constant", scale=-0.6),
        "decay_A": pf.param("decay_A", L + (d, cfg.lora_decay), LA + ("embed", None),
                            fan_in=d),
        "decay_B": pf.param("decay_B", L + (cfg.lora_decay, H, hd),
                            LA + (None, "heads", "head_dim"), fan_in=cfg.lora_decay),
        "u": pf.param("u", L + (H, hd), LA + ("heads", "head_dim"),
                      init="uniform", scale=0.5),
        "ln_x": pf.param("ln_x", L + (H, hd), LA + ("heads", "head_dim"),
                         init="zeros"),
        # channel mix
        "cm_mu": pf.param("cm_mu", L + (2, d), LA + (None, "act_embed"),
                          init="uniform", scale=0.5),
        "cm_wk": pf.param("cm_wk", L + (d, cfg.d_ff), LA + ("embed", "ffn"), fan_in=d),
        "cm_wr": pf.param("cm_wr", L + (d, d), LA + ("embed", "embed"), fan_in=d),
        "cm_wv": pf.param("cm_wv", L + (cfg.d_ff, d), LA + ("ffn", "embed"),
                          fan_in=cfg.d_ff),
        "norm1": pf.param("norm1", L + (d,), LA + ("act_embed",), init="zeros"),
        "norm2": pf.param("norm2", L + (d,), LA + ("act_embed",), init="zeros"),
    }
    return p


def _token_shift(x, last):
    """shifted[t] = x[t-1]; shifted[0] = last (carry from previous segment)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _rwkv_mix_streams(p, x, shifted):
    """ddlerp: per-stream mixing coefficients with a low-rank data path."""
    r = p["mix_B"].shape[1]
    base = jnp.tanh(jnp.einsum("btd,dr->btr", x, p["mix_A"]))  # [B,T,5r]
    base = base.reshape(base.shape[:-1] + (5, r))
    delta = jnp.einsum("btsr,srd->btsd", base, p["mix_B"])
    mu = p["mu_base"][None, None] + delta                      # [B,T,5,d]
    xx = shifted - x
    return x[:, :, None, :] + xx[:, :, None, :] * jax.nn.sigmoid(mu)


def _rwkv_time_mix_inputs(p, cfg: RWKVConfig, x, shifted):
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    mixed = _rwkv_mix_streams(p, x, shifted)      # [B,T,5,d]
    xr, xk, xv, xg, xw = [mixed[:, :, i, :] for i in range(5)]
    rr = jnp.einsum("btd,dhk->bthk", xr, p["wr"])
    kk = jnp.einsum("btd,dhk->bthk", xk, p["wk"])
    vv = jnp.einsum("btd,dhk->bthk", xv, p["wv"])
    gg = jax.nn.silu(jnp.einsum("btd,dhk->bthk", xg, p["wg"]))
    dec = jnp.einsum("btr,rhk->bthk",
                     jnp.tanh(jnp.einsum("btd,dr->btr", xw, p["decay_A"])),
                     p["decay_B"])
    logw = -jnp.exp(p["w0"][None, None].astype(jnp.float32)
                    + dec.astype(jnp.float32))          # [B,T,H,hd], < 0
    return rr, kk, vv, gg, logw


def rwkv_block_forward(p: dict, cfg: RWKVConfig, x: jnp.ndarray,
                       ctx: ParallelContext,
                       state: Optional[dict] = None
                       ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Full-sequence RWKV6 block (time mix + channel mix), pre-norm residual.
    `state` (decode/carry): {"shift1","shift2" [B,d], "S" [B,H,K,V] fp32}."""
    B, T, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    h = rms_norm(x, p["norm1"])
    last1 = state["shift1"] if state is not None else jnp.zeros((B, d), x.dtype)
    shifted = _token_shift(h, last1.astype(h.dtype))
    rr, kk, vv, gg, logw = _rwkv_time_mix_inputs(p, cfg, h, shifted)
    rr = ctx.constrain(rr, ("batch", "seq", "heads", "head_dim"))
    kk = ctx.constrain(kk, ("batch", "seq", "heads", "head_dim"))
    vv = ctx.constrain(vv, ("batch", "seq", "heads", "head_dim"))
    S0 = (state["S"] if state is not None
          else jnp.zeros((B, H, hd, hd), jnp.float32))
    chunk = cfg.chunk if T % cfg.chunk == 0 else 1
    if chunk > 1:
        y, S = linear_attention_chunked(rr, kk, vv, logw, S0, mode="rwkv",
                                        u=p["u"].astype(jnp.float32),
                                        chunk=chunk)
    else:
        y, S = linear_attention_scan(rr, kk, vv, logw, S0, mode="rwkv",
                                     u=p["u"].astype(jnp.float32))
    # per-head group norm, gate, project out
    y = rms_norm(y.astype(x.dtype), p["ln_x"]) * gg
    y = jnp.einsum("bthk,hkd->btd", y, p["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    x = x + ctx.constrain(y, ("batch", "seq", "act_embed"))

    # channel mix
    h2 = rms_norm(x, p["norm2"])
    last2 = state["shift2"] if state is not None else jnp.zeros((B, d), x.dtype)
    sh2 = _token_shift(h2, last2.astype(h2.dtype))
    mu = jax.nn.sigmoid(p["cm_mu"][None, None])
    xk2 = h2 + (sh2 - h2) * mu[:, :, 0, :]
    xr2 = h2 + (sh2 - h2) * mu[:, :, 1, :]
    kk2 = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk2, p["cm_wk"])))
    kk2 = ctx.constrain(kk2, ("batch", "seq", "ffn"))
    vv2 = jnp.einsum("btf,fd->btd", kk2, p["cm_wv"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    y2 = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr2, p["cm_wr"])) * vv2
    x = x + ctx.constrain(y2, ("batch", "seq", "act_embed"))

    new_state = {"shift1": h[:, -1, :], "shift2": h2[:, -1, :], "S": S}
    return x, new_state


def init_rwkv_state(cfg: RWKVConfig, batch: int, dtype=jnp.bfloat16,
                    stacked: int = 0, abstract=False) -> dict:
    from repro.sharding import AbstractParam
    L = (stacked,) if stacked else ()
    LA = ("layers",) if stacked else ()
    H, hd, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    specs = {
        "shift1": (L + (batch, d), dtype, LA + ("batch", "act_embed")),
        "shift2": (L + (batch, d), dtype, LA + ("batch", "act_embed")),
        "S": (L + (batch, H, hd, hd), jnp.float32,
              LA + ("batch", "heads", "head_dim", "state")),
    }
    if abstract:
        return {k: AbstractParam(s, dt, ax) for k, (s, dt, ax) in specs.items()}
    return {k: jnp.zeros(s, dt) for k, (s, dt, ax) in specs.items()}


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 64

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init_mamba2_block(pf: ParamFactory, cfg: Mamba2Config, stacked: int = 0) -> dict:
    L = (stacked,) if stacked else ()
    LA = ("layers",) if stacked else ()
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    conv_ch = di + 2 * N
    return {
        "norm": pf.param("norm", L + (d,), LA + ("act_embed",), init="zeros"),
        "in_proj": pf.param("in_proj", L + (d, 2 * di + 2 * N + H),
                            LA + ("embed", "d_inner"), fan_in=d),
        "conv_w": pf.param("conv_w", L + (cfg.conv_width, conv_ch),
                           LA + ("conv", "d_inner"), init="normal",
                           fan_in=cfg.conv_width),
        "conv_b": pf.param("conv_b", L + (conv_ch,), LA + ("d_inner",),
                           init="zeros"),
        "A_log": pf.param("A_log", L + (H,), LA + ("heads",),
                          init="constant", scale=0.0),
        "dt_bias": pf.param("dt_bias", L + (H,), LA + ("heads",),
                            init="constant", scale=-1.0),
        "D": pf.param("D", L + (H,), LA + ("heads",), init="ones"),
        "out_norm": pf.param("out_norm", L + (di,), LA + ("d_inner",),
                             init="zeros"),
        "out_proj": pf.param("out_proj", L + (di, d), LA + ("d_inner", "embed"),
                             fan_in=di),
    }


def _causal_conv(x, w, b, carry=None):
    """Depthwise causal conv. x [B,T,C]; w [W,C]; carry [B,W-1,C] history.
    Returns (y [B,T,C], new_carry)."""
    W = w.shape[0]
    B, T, C = x.shape
    if carry is None:
        carry = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    y = sum(xp[:, i:i + T, :] * w[i][None, None, :] for i in range(W))
    new_carry = xp[:, T:, :] if T >= 1 else carry
    new_carry = xp[:, -(W - 1):, :]
    return y + b[None, None, :], new_carry


def mamba2_block_forward(p: dict, cfg: Mamba2Config, x: jnp.ndarray,
                         ctx: ParallelContext,
                         state: Optional[dict] = None
                         ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Full-sequence Mamba2 block. state: {"conv" [B,W-1,C], "S" [B,H,N,P]}."""
    B, T, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    h = rms_norm(x, p["norm"])
    zxbcdt = jnp.einsum("btd,de->bte", h, p["in_proj"])
    z, xc, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xc, Bc, Cc], axis=-1)
    conv_carry = state["conv"] if state is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                      conv_carry)
    conv_out = jax.nn.silu(conv_out)
    xc, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)
    xc = ctx.constrain(xc, ("batch", "seq", "d_inner"))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # [H] negative
    logw = (dt * A[None, None, :])[..., None]             # [B,T,H,1]
    xh = xc.reshape(B, T, H, P)
    v = xh * dt[..., None]
    q = jnp.broadcast_to(Cc[:, :, None, :], (B, T, H, N))
    k = jnp.broadcast_to(Bc[:, :, None, :], (B, T, H, N))
    S0 = (state["S"] if state is not None
          else jnp.zeros((B, H, N, P), jnp.float32))
    chunk = cfg.chunk if T % cfg.chunk == 0 else 1
    if chunk > 1:
        y, S = linear_attention_chunked(q, k, v, logw, S0, mode="mamba",
                                        chunk=chunk)
    else:
        y, S = linear_attention_scan(q, k, v, logw, S0, mode="mamba")
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, T, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"])
    y = jnp.einsum("bte,ed->btd", y, p["out_proj"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    x = x + ctx.constrain(y, ("batch", "seq", "act_embed"))
    return x, {"conv": new_conv, "S": S}


def init_mamba2_state(cfg: Mamba2Config, batch: int, dtype=jnp.bfloat16,
                      stacked: int = 0, abstract=False) -> dict:
    from repro.sharding import AbstractParam
    L = (stacked,) if stacked else ()
    LA = ("layers",) if stacked else ()
    C = cfg.d_inner + 2 * cfg.d_state
    specs = {
        "conv": (L + (batch, cfg.conv_width - 1, C), dtype,
                 LA + ("batch", "conv", "d_inner")),
        "S": (L + (batch, cfg.n_heads, cfg.d_state, cfg.head_dim), jnp.float32,
              LA + ("batch", "heads", "state", "head_dim")),
    }
    if abstract:
        return {k: AbstractParam(s, dt, ax) for k, (s, dt, ax) in specs.items()}
    return {k: jnp.zeros(s, dt) for k, (s, dt, ax) in specs.items()}
