"""Small classifiers for the paper's encoder-only experiments (§4.1).

The paper uses a custom CNN / MobileNet as M_S and ResNets as M_L on image
data. Our CPU-scale repro uses feature-vector tasks (data/synthetic.py), so
M_S / M_L are MLPs of different capacity — the cascade dynamics (capacity
gap, confidence tuning) are what matter, not the conv stem.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamFactory


@dataclasses.dataclass(frozen=True)
class MLPClassifierConfig:
    d_in: int
    n_classes: int
    hidden: Tuple[int, ...] = (128, 128)
    dropout: float = 0.0


def init_classifier(cfg: MLPClassifierConfig, key, abstract: bool = False):
    pf = ParamFactory(None if abstract else key, jnp.float32, abstract)
    dims = (cfg.d_in,) + cfg.hidden + (cfg.n_classes,)
    params = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"w{i}"] = pf.param(f"w{i}", (a, b), ("embed", "ffn"), fan_in=a)
        params[f"b{i}"] = pf.param(f"b{i}", (b,), ("ffn",), init="zeros")
    return params


def classifier_forward(params, cfg: MLPClassifierConfig, x: jnp.ndarray,
                       *, key=None) -> jnp.ndarray:
    n = len(cfg.hidden) + 1
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            x = jax.nn.relu(x)
            if cfg.dropout > 0 and key is not None:
                keep = jax.random.bernoulli(jax.random.fold_in(key, i),
                                            1 - cfg.dropout, x.shape)
                x = jnp.where(keep, x / (1 - cfg.dropout), 0.0)
    return x


def make_apply(cfg: MLPClassifierConfig):
    def apply(params, x):
        return classifier_forward(params, cfg, x)
    return jax.jit(apply)
