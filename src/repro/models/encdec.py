"""Encoder-decoder model (whisper-small). The audio frontend (mel + conv) is
a STUB per the assignment: callers provide precomputed frame embeddings
[B, n_frames, d_model]; we add sinusoidal positions and run the transformer
backbone. Decoder layers: causal self-attn + cross-attn + GELU MLP.

TPU adaptation note (DESIGN.md): the decoder uses RoPE instead of Whisper's
learned positions — positional scheme is orthogonal to the paper's cascade
technique and RoPE keeps the decode cache machinery uniform across archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.models import attention as attn_lib
from repro.models import ffn as ffn_lib
from repro.models.common import embed_tokens, rms_norm
from repro.models.transformer import (attn_config, mlp_config, _maybe_remat,
                                      _logits, init_cache as _dec_init_cache)
from repro.sharding import ParallelContext
import dataclasses


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * dim / d)
    emb = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


def encode(params, cfg: ModelConfig, frames: jnp.ndarray,
           ctx: ParallelContext) -> jnp.ndarray:
    """frames: [B, n_frames, d_model] stub frontend output -> encoder states."""
    enc = params["encoder"]
    x = frames.astype(cfg.cdtype())
    x = x + sinusoidal_positions(x.shape[1], x.shape[2]).astype(x.dtype)
    x = ctx.constrain(x, ("batch", "seq", "act_embed"))
    ac = dataclasses.replace(attn_config(cfg), causal=False)
    positions = jnp.arange(x.shape[1])[None, :]

    def block(carry, p):
        x = carry
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        y, _ = attn_lib.gqa_forward(p["attn"], ac, h, positions, ctx)
        x = x + y
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + ffn_lib.mlp_forward(p["mlp"], mlp_config(cfg, "gelu"), h, ctx)
        return x, None

    x, _ = jax.lax.scan(_maybe_remat(block, cfg), x, enc["stack"])
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


def cross_kv(params, cfg: ModelConfig, enc_out: jnp.ndarray) -> dict:
    """Precompute per-decoder-layer cross K/V (done once per request)."""
    cross = params["encoder"]["cross"]
    kv = jax.vmap(lambda p: attn_lib.cross_attn_kv(p, enc_out))(cross)
    return kv   # {"k": [L,B,S,H,hd], "v": ...}


def _decoder_trunk(params, cfg: ModelConfig, x, positions, kv, ctx,
                   cache=None, cache_offset=0, decode=False, position=None):
    ac = attn_config(cfg)
    cross_p = params["encoder"]["cross"]
    cross_norm = params["encoder"]["cross_norm"]
    blocks = params["blocks"]["dense"]

    def block(carry, xs):
        x = carry
        p, cp, cn, kv_l, c_l = xs
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        if decode:
            y, nc = attn_lib.gqa_decode(p["attn"], ac, h, position, c_l, ctx)
        else:
            y, nc = attn_lib.gqa_forward(p["attn"], ac, h, positions, ctx,
                                         c_l, cache_offset)
        x = x + y
        h = rms_norm(x, cn, cfg.norm_eps)
        x = x + attn_lib.cross_attn_forward(cp, ac, h, kv_l, ctx)
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + ffn_lib.mlp_forward(p["mlp"], mlp_config(cfg, "gelu"), h, ctx)
        return x, nc

    if cache is None:
        # training: no self-attn cache; emulate per-layer None with dummies
        def block_nc(carry, xs):
            p, cp, cn, kv_l = xs
            x, _ = block(carry, (p, cp, cn, kv_l, None))
            return x, None
        x, _ = jax.lax.scan(_maybe_remat(block_nc, cfg), x,
                            (blocks, cross_p, cross_norm, kv))
        return x, None
    x, new_cache = jax.lax.scan(_maybe_remat(block, cfg), x,
                                (blocks, cross_p, cross_norm, kv, cache))
    return x, new_cache


def forward(params, cfg: ModelConfig, frames, dec_tokens,
            ctx: ParallelContext):
    """Training forward: encoder + teacher-forced decoder. Returns logits."""
    enc_out = encode(params, cfg, frames, ctx)
    kv = cross_kv(params, cfg, enc_out)
    x = embed_tokens(params["embedding"], dec_tokens).astype(cfg.cdtype())
    positions = jnp.arange(x.shape[1])[None, :]
    x, _ = _decoder_trunk(params, cfg, x, positions, kv, ctx)
    return _logits(params, cfg, x, ctx)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               abstract: bool = False, dtype=None) -> dict:
    """Self-attn cache for the decoder + slot for precomputed cross KV."""
    from repro.sharding import AbstractParam
    dtype = dtype or cfg.cdtype()
    cache = _dec_init_cache(cfg, batch, max_len, abstract=abstract, dtype=dtype)
    L, H, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
    S = cfg.encoder.n_frames
    shape = (L, batch, S, H, hd)
    axes = ("layers", "batch", "seq", "heads", "head_dim")
    if abstract:
        kv = {"k": AbstractParam(shape, dtype, axes),
              "v": AbstractParam(shape, dtype, axes)}
    else:
        kv = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    cache["cross_kv"] = kv
    return cache


def prefill(params, cfg: ModelConfig, frames, dec_tokens, cache,
            ctx: ParallelContext, last_only: bool = False):
    """Encode + teacher-forced prefix; fills self cache and cross KV."""
    enc_out = encode(params, cfg, frames, ctx)
    kv = cross_kv(params, cfg, enc_out)
    kv = jax.tree.map(lambda a, c: a.astype(c.dtype), kv, cache["cross_kv"])
    x = embed_tokens(params["embedding"], dec_tokens).astype(cfg.cdtype())
    positions = jnp.arange(x.shape[1])[None, :]
    x, new_self = _decoder_trunk(params, cfg, x, positions, kv, ctx,
                                 cache=cache["dense"], cache_offset=0)
    if last_only:
        x = x[:, -1:, :]
    logits = _logits(params, cfg, x, ctx)
    return logits, {"dense": new_self, "cross_kv": kv}


def decode_step(params, cfg: ModelConfig, token, position, cache,
                ctx: ParallelContext):
    if token.ndim == 1:
        token = token[:, None]
    x = embed_tokens(params["embedding"], token).astype(cfg.cdtype())
    kv = jax.tree.map(lambda a: a.astype(cfg.cdtype()), cache["cross_kv"])
    x, new_self = _decoder_trunk(params, cfg, x, None, kv, ctx,
                                 cache=cache["dense"], decode=True,
                                 position=position)
    logits = _logits(params, cfg, x, ctx)
    return logits[:, 0, :], {"dense": new_self, "cross_kv": cache["cross_kv"]}
