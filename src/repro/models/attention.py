"""Attention blocks: GQA (optional QKV bias / sliding window / cross-attn)
and DeepSeek-style MLA (multi-head latent attention, kv_lora compression with
decoupled RoPE and weight-absorbed decode).

All functions operate on ONE layer's params (scan slices stacked trees).
Caches are dicts of arrays; decode uses dynamic_update_slice at `position`.

Cache layouts (per layer-sliced leaf):
  * dense  — [batch, max_len, ...]: one contiguous row per sequence; writes
    go to absolute position `position`, reads mask `idx <= position`.
  * paged  — [n_blocks, block_size, ...] + a page table `pages` [B, M]
    mapping each row's logical block m to a physical block id (0 is the
    shared trash block). Writes scatter to
    (pages[b, pos // block_size], pos % block_size); reads gather the
    row's blocks back into a dense [B, M*block_size, ...] view and apply
    the same per-row validity mask — so paged and dense attention compute
    identical masked softmaxes over the valid prefix.
  Paged mode is selected by passing `pages`; sliding-window ring caches
  cannot be paged (serving.paged_pool rejects those configs).

Paged DECODE has two interchangeable implementations (same masked
softmax, pinned by tests/test_paged_kernel.py):
  * XLA fallback (default on CPU) — `gather_blocks` materializes the
    dense view, then dense attention. Callers tighten it by passing a
    page table sliced to the active block prefix (the serving engine
    buckets `ceil((max_pos + steps)/block_size)` to a power of two so
    only O(log M) shapes ever compile) — the gather then reads only
    blocks the mask can reach.
  * Pallas kernel (default on TPU; kernels/paged_attention.py) — walks
    the page table inside the kernel, one block per kv grid step, no
    dense view in HBM; the single-token cache write is also a kernel.
  Selection: the `paged_kernel` argument when given, else the
  REPRO_PAGED_KERNEL env var, else backend default (kernels/ops.py).

Sharding: head dims carry logical axis "heads"/"kv_heads" (→ `model`);
the output projection contracts the sharded head axis, so XLA inserts the
canonical tensor-parallel all-reduce after each attention block.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kernel_ops
from repro.models.common import (ParamFactory, apply_rope, make_causal_mask,
                                 make_sliding_mask, rms_norm)
from repro.sharding import ParallelContext

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None     # None = full causal
    causal: bool = True                      # False for encoder self-attn
    attn_chunk: Optional[int] = None         # online-softmax KV chunking
    # MLA fields (used only by the mla_* functions)
    q_lora: int = 0
    kv_lora: int = 0
    rope_dim: int = 64
    v_head_dim: int = 0


# ---------------------------------------------------------------------------
# Paged-cache primitives (shared by GQA and MLA)
# ---------------------------------------------------------------------------

def gather_blocks(leaf: jnp.ndarray, pages: jnp.ndarray) -> jnp.ndarray:
    """Gather a paged cache leaf into a dense per-row view.

    leaf  [n_blocks, block_size, ...] — physical block storage
    pages [B, M] int32               — per-row page table (logical -> physical)

    Returns [B, M*block_size, ...]: row b's logical sequence, blocks
    concatenated in logical order. Unmapped entries point at the trash
    block (id 0); the positions they contribute lie beyond the row's valid
    prefix and are removed by the caller's `idx <= pos` mask.

    Decode callers pass `pages` sliced to the ACTIVE block prefix
    (columns `[0, ceil((max_pos + steps)/block_size))`, bucketed) so the
    gather never reads blocks the validity mask cannot reach — the
    masked softmax over the shorter view is exactly the full-view one.
    """
    B, M = pages.shape
    g = jnp.take(leaf, pages.reshape(-1), axis=0)        # [B*M, bs, ...]
    return g.reshape((B, M * leaf.shape[1]) + leaf.shape[2:])


def _paged_write(leaf: jnp.ndarray, pages: jnp.ndarray, tpos: jnp.ndarray,
                 values: jnp.ndarray) -> jnp.ndarray:
    """Scatter `values` [B, T, ...] at absolute token positions `tpos`
    ([T] shared across rows, or [B, T]) through the page table. Positions
    whose logical block is unmapped (table entry 0) land in the trash
    block — callers rely on this for padded prefill chunks and for
    inactive decode rows (see engine one_step)."""
    bs = leaf.shape[1]
    B = pages.shape[0]
    if tpos.ndim == 1:
        tpos = jnp.broadcast_to(tpos[None, :], (B, tpos.shape[0]))
    blk_idx = jnp.clip(tpos // bs, 0, pages.shape[1] - 1)   # [B, T]
    blk = jnp.take_along_axis(pages, blk_idx, axis=1)       # [B, T]
    off = tpos % bs
    return leaf.at[blk, off].set(values.astype(leaf.dtype))


# ---------------------------------------------------------------------------
# Standard GQA
# ---------------------------------------------------------------------------

def init_gqa(pf: ParamFactory, cfg: AttnConfig, stacked: int = 0) -> dict:
    L = (stacked,) if stacked else ()
    LA = ("layers",) if stacked else ()
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": pf.param("wq", L + (d, H, hd), LA + ("embed", "heads", "head_dim"),
                       fan_in=d),
        "wk": pf.param("wk", L + (d, KV, hd), LA + ("embed", "kv_heads", "head_dim"),
                       fan_in=d),
        "wv": pf.param("wv", L + (d, KV, hd), LA + ("embed", "kv_heads", "head_dim"),
                       fan_in=d),
        "wo": pf.param("wo", L + (H, hd, d), LA + ("heads", "head_dim", "embed"),
                       fan_in=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = pf.param("bq", L + (H, hd), LA + ("heads", "head_dim"), init="zeros")
        p["bk"] = pf.param("bk", L + (KV, hd), LA + ("kv_heads", "head_dim"), init="zeros")
        p["bv"] = pf.param("bv", L + (KV, hd), LA + ("kv_heads", "head_dim"), init="zeros")
    return p


def init_gqa_cache(cfg: AttnConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16, stacked: int = 0, abstract=False) -> dict:
    from repro.sharding import AbstractParam
    L = (stacked,) if stacked else ()
    LA = ("layers",) if stacked else ()
    shape = L + (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    axes = LA + ("batch", "cache_seq", "kv_heads", "head_dim")
    if abstract:
        return {"k": AbstractParam(shape, dtype, axes),
                "v": AbstractParam(shape, dtype, axes)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _attend(q, k, v, mask, scale, ctx: ParallelContext,
            chunk: Optional[int] = None):
    """q [B,Tq,H,hd]; k,v [B,Tk,KV,hd]; mask [Tq,Tk] or [B,Tq,Tk] bool.

    If `chunk` is set and divides Tk, runs the online-softmax KV-chunked
    schedule (flash-attention dataflow at the XLA level): the [Tq, Tk]
    score tensor is never live in full — only one [Tq, chunk] tile per
    scan step. This is the XLA analogue of kernels/flash_attention.py and
    is what the TPU kernel does inside VMEM.
    """
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    group = H // KV
    qg = q.reshape(B, Tq, KV, group, hd)
    if chunk:
        while k.shape[1] % chunk:
            chunk //= 2
    if chunk and chunk >= 128 and k.shape[1] > chunk:
        return _attend_chunked(qg, k, v, mask, scale, chunk
                               ).reshape(B, Tq, H, v.shape[-1]).astype(q.dtype)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if mask.ndim == 2:
        m = mask[None, None, None, :, :]
    else:
        m = mask[:, None, None, :, :]
    scores = jnp.where(m, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Tq, H, v.shape[-1]).astype(q.dtype)


def _attend_chunked(qg, k, v, mask, scale, chunk: int):
    """Online-softmax over KV chunks. qg [B,Tq,KV,g,hd]; returns
    [B,Tq,KV,g,hd] fp32-accumulated. Masked-out rows produce zeros."""
    B, Tq, KV, g, hd = qg.shape
    Tk = k.shape[1]
    nc = Tk // chunk
    neg = jnp.float32(-jnp.inf)

    def body(carry, i):
        m, l, acc = carry                            # [B,KV,g,Tq](x2), [B,KV,g,Tq,hd]
        ks = jax.lax.dynamic_slice_in_dim(k, i * chunk, chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, i * chunk, chunk, 1)
        mk = jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, mask.ndim - 1)
        s = jnp.einsum("btkgh,bskh->bkgts", qg, ks,
                       preferred_element_type=jnp.float32) * scale
        mb = (mk[None, None, None, :, :] if mk.ndim == 2
              else mk[:, None, None, :, :])
        s = jnp.where(mb, s, neg)
        cm = s.max(-1)                               # [B,KV,g,Tq]
        nm = jnp.maximum(m, cm)
        # exp(-inf - -inf) guards: fully-masked rows stay at zero weight
        safe = jnp.isfinite(nm)
        p = jnp.where(safe[..., None], jnp.exp(s - nm[..., None]), 0.0)
        alpha = jnp.where(safe, jnp.exp(jnp.minimum(m - nm, 0.0)), 0.0)
        alpha = jnp.where(jnp.isfinite(m), alpha, 0.0)
        l = l * alpha + p.sum(-1)
        pv = jnp.einsum("bkgts,bskh->bkgth", p.astype(vs.dtype), vs,
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (nm, l, acc), None

    init = (jnp.full((B, KV, g, Tq), neg, jnp.float32),
            jnp.zeros((B, KV, g, Tq), jnp.float32),
            jnp.zeros((B, KV, g, Tq, v.shape[-1]), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(nc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]     # [B,KV,g,Tq,hd]
    return out.transpose(0, 3, 1, 2, 4)


def gqa_forward(params: dict, cfg: AttnConfig, x: jnp.ndarray,
                positions: jnp.ndarray, ctx: ParallelContext,
                cache: Optional[dict] = None,
                cache_offset=0,
                pages: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Full-sequence self-attention (training / prefill).

    If `cache` is given, writes K/V at [cache_offset, cache_offset+T) and
    attends over the written prefix (prefill); else attends in-sequence.
    `cache_offset` may be a traced scalar (chunked prefill resumes at the
    chunk's start). With `pages` [B, M] the cache is block-paged
    ([n_blocks, block_size, ...] leaves): the chunk's K/V scatter through
    the page table and attention runs over the gathered logical view.
    """
    B, T, d = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = ctx.constrain(q, ("batch", "seq", "heads", "head_dim"))
    k = ctx.constrain(k, ("batch", "kv_seq", "kv_heads", "head_dim"))
    v = ctx.constrain(v, ("batch", "kv_seq", "kv_heads", "head_dim"))
    scale = 1.0 / np.sqrt(cfg.head_dim)

    new_cache = None
    if pages is not None:
        assert cache is not None and cfg.sliding_window is None, \
            "paged caches do not support sliding-window attention"
        tpos = cache_offset + jnp.arange(T)
        ck = _paged_write(cache["k"], pages, tpos, k)
        cv = _paged_write(cache["v"], pages, tpos, v)
        new_cache = {"k": ck, "v": cv}
        kk = gather_blocks(ck, pages)
        vv = gather_blocks(cv, pages)
        mask = make_causal_mask(T, kk.shape[1], cache_offset)
        out = _attend(q, kk.astype(q.dtype), vv.astype(q.dtype), mask, scale,
                      ctx, cfg.attn_chunk)
    elif cache is not None and cache["k"].shape[1] < T:
        # windowed ring-buffer cache smaller than the prompt: attend
        # IN-SEQUENCE (sliding mask) and store only the last `window`
        # tokens at their ring slots (slot = position % window).
        S = cache["k"].shape[1]
        k_last = k[:, T - S:]
        v_last = v[:, T - S:]
        shift = (T - S) % S
        ck = jnp.roll(k_last.astype(cache["k"].dtype), shift, axis=1)
        cv = jnp.roll(v_last.astype(cache["v"].dtype), shift, axis=1)
        new_cache = {"k": ck, "v": cv}
        window = cfg.sliding_window or S
        mask = make_sliding_mask(T, T, cache_offset, window)
        out = _attend(q, k, v, mask, scale, ctx, cfg.attn_chunk)
    elif cache is not None:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_offset, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_offset, 0, 0))
        new_cache = {"k": ck, "v": cv}
        S = ck.shape[1]
        if cfg.sliding_window:
            mask = make_sliding_mask(T, S, cache_offset, cfg.sliding_window)
        else:
            mask = make_causal_mask(T, S, cache_offset)
        out = _attend(q, ck.astype(q.dtype), cv.astype(q.dtype), mask, scale,
                      ctx, cfg.attn_chunk)
    else:
        if not cfg.causal:
            mask = jnp.ones((T, T), bool)
        elif cfg.sliding_window:
            mask = make_sliding_mask(T, T, 0, cfg.sliding_window)
        else:
            mask = make_causal_mask(T, T, 0)
        out = _attend(q, k, v, mask, scale, ctx, cfg.attn_chunk)

    y = jnp.einsum("bthk,hkd->btd", out, params["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    y = ctx.constrain(y, ("batch", "seq", "act_embed"))
    return y, new_cache


def _flash_decode_sharded(q, ck, cv, mask, scale, ctx: ParallelContext):
    """Decode attention over a sequence-sharded KV cache WITHOUT gathering
    the cache (flash-decode): each shard computes a partial
    (row-max, lse, p@v) over its local seq chunk, then psum-combines.

    Returns None when the cache's seq dim is not sharded (caller falls
    back to the dense path)."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding import logical_to_spec, shard_map
    mesh = ctx.mesh
    cache_spec = logical_to_spec(("batch", "cache_seq", "kv_heads", None),
                                 ck.shape, mesh, ctx.rules)
    if cache_spec[1] is None or mask.ndim != 2:
        return None
    seq_axes = (cache_spec[1],) if isinstance(cache_spec[1], str) \
        else tuple(cache_spec[1])
    qspec = P(cache_spec[0], None, None, None)
    kvspec = P(cache_spec[0], cache_spec[1], cache_spec[2], None)
    mspec = P(None, cache_spec[1])

    def body(ql, kl, vl, ml):
        B, Tq, H, hd = ql.shape
        KV = kl.shape[2]
        g = H // KV
        qg = ql.reshape(B, Tq, KV, g, hd)
        s = jnp.einsum("btkgh,bskh->bkgts", qg, kl,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(ml[None, None, None, :, :], s, -jnp.inf)
        m = s.max(-1)                                   # local row max
        M = jax.lax.pmax(m, seq_axes)                   # global row max
        safe = jnp.isfinite(M)
        p = jnp.where(safe[..., None], jnp.exp(s - M[..., None]), 0.0)
        l = jax.lax.psum(p.sum(-1), seq_axes)
        pv = jnp.einsum("bkgts,bskh->bkgth", p.astype(vl.dtype), vl,
                        preferred_element_type=jnp.float32)
        pv = jax.lax.psum(pv, seq_axes)
        out = pv / jnp.maximum(l, 1e-30)[..., None]
        return (out.transpose(0, 3, 1, 2, 4)
                .reshape(B, Tq, H, hd).astype(ql.dtype))

    return shard_map(body, mesh=mesh,
                     in_specs=(qspec, kvspec, kvspec, mspec),
                     out_specs=qspec, check_vma=False)(q, ck, cv, mask)


def gqa_decode(params: dict, cfg: AttnConfig, x: jnp.ndarray,
               position, cache: dict, ctx: ParallelContext,
               pages: Optional[jnp.ndarray] = None,
               paged_kernel: Optional[bool] = None
               ) -> Tuple[jnp.ndarray, dict]:
    """One-token decode. x [B,1,d]; position is either a scalar int (whole
    batch at the same depth — the static serving engine) or an int vector
    [B] of per-row depths (continuous batching: each slot of the KV pool
    decodes at its own position; writes become row scatters and the
    validity mask becomes per-row).

    With `pages` [B, M] the cache is block-paged: the new K/V scatters to
    (pages[b, pos // block_size], pos % block_size) and attention runs
    over each row's valid prefix with the `idx <= pos` mask —
    token-identical to the dense path. Requires per-row positions.
    `paged_kernel` picks the Pallas paged flash-decode kernel (walks the
    page table in-kernel, no dense gather) vs the XLA gather fallback;
    None = REPRO_PAGED_KERNEL env / backend default.

    For sliding-window configs the cache is a ring buffer of size `window`;
    the write slot is position % window and relative order is handled by
    the positional mask below. Ring caches cannot be paged.
    """
    B, T, d = x.shape
    assert T == 1
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    pos = jnp.asarray(position)
    per_row = pos.ndim == 1                    # [B] per-slot positions
    pos_bt = pos[:, None] if per_row else pos[None, None]   # [B,1] / [1,1]
    q = apply_rope(q, pos_bt, cfg.rope_theta)
    k = apply_rope(k, pos_bt, cfg.rope_theta)
    scale = 1.0 / np.sqrt(cfg.head_dim)
    if pages is not None:
        assert per_row and cfg.sliding_window is None, \
            "paged decode needs per-row positions and no sliding window"
        if kernel_ops.paged_kernel_enabled(paged_kernel):
            # Pallas path: in-kernel paged write + flash-decode walking
            # the page table — no dense [B, M*bs, ...] view in HBM
            ck = kernel_ops.paged_write_token(cache["k"], pages, pos,
                                              k[:, 0])
            cv = kernel_ops.paged_write_token(cache["v"], pages, pos,
                                              v[:, 0])
            out = kernel_ops.paged_flash_decode_gqa(q, ck, cv, pages, pos,
                                                    scale=scale)
        else:
            # XLA fallback / parity reference: scatter + dense gather of
            # the (caller-tightened) active block prefix
            ck = _paged_write(cache["k"], pages, pos[:, None], k[:, 0:1])
            cv = _paged_write(cache["v"], pages, pos[:, None], v[:, 0:1])
            kk = gather_blocks(ck, pages)
            vv = gather_blocks(cv, pages)
            mask = (jnp.arange(kk.shape[1])[None, :]
                    <= pos[:, None])[:, None, :]
            out = _attend(q, kk.astype(q.dtype), vv.astype(q.dtype), mask,
                          scale, ctx)
        y = jnp.einsum("bthk,hkd->btd", out, params["wo"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
        return y, {"k": ck, "v": cv}
    S = cache["k"].shape[1]
    ring = cfg.sliding_window is not None and S <= cfg.sliding_window
    slot = jnp.mod(pos, S) if ring else pos
    if per_row:
        rows = jnp.arange(B)
        ck = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    idx = jnp.arange(S)
    if ring:
        # ring buffer: slot s holds absolute position p iff p % S == s and
        # p in (position - S, position]; every slot written so far is valid
        # once position >= S - 1. Mask = slots with abs pos > position - S.
        if per_row:
            abs_pos = pos[:, None] - jnp.mod(pos[:, None] - idx[None, :], S)
            mask = (abs_pos >= 0)[:, None, :]              # [B, 1, S]
        else:
            abs_pos = pos - jnp.mod(pos - idx, S)
            mask = (abs_pos >= 0)[None, :]                 # [1, S]
    elif per_row:
        mask = idx[None, :] <= pos[:, None]                # [B, S]
        if cfg.sliding_window:
            mask = mask & (idx[None, :] > pos[:, None] - cfg.sliding_window)
        mask = mask[:, None, :]                            # [B, 1, S]
    else:
        mask = (idx <= pos)[None, :]
        if cfg.sliding_window:
            # linear cache larger than the window: restrict attendance
            mask = mask & (idx > pos - cfg.sliding_window)[None, :]
    out = None
    if ctx.mesh is not None:
        out = _flash_decode_sharded(q, ck.astype(q.dtype),
                                    cv.astype(q.dtype), mask, scale, ctx)
    if out is None:
        out = _attend(q, ck.astype(q.dtype), cv.astype(q.dtype), mask, scale,
                      ctx)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec): KV computed once from encoder output
# ---------------------------------------------------------------------------

def init_cross_attn(pf: ParamFactory, cfg: AttnConfig, stacked: int = 0) -> dict:
    L = (stacked,) if stacked else ()
    LA = ("layers",) if stacked else ()
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "wq": pf.param("xwq", L + (d, H, hd), LA + ("embed", "heads", "head_dim"), fan_in=d),
        "wk": pf.param("xwk", L + (d, H, hd), LA + ("embed", "heads", "head_dim"), fan_in=d),
        "wv": pf.param("xwv", L + (d, H, hd), LA + ("embed", "heads", "head_dim"), fan_in=d),
        "wo": pf.param("xwo", L + (H, hd, d), LA + ("heads", "head_dim", "embed"),
                       fan_in=H * hd),
    }


def cross_attn_kv(params: dict, enc_out: jnp.ndarray) -> dict:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"])
    return {"k": k, "v": v}


def cross_attn_forward(params: dict, cfg: AttnConfig, x: jnp.ndarray,
                       kv: dict, ctx: ParallelContext) -> jnp.ndarray:
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    S = kv["k"].shape[1]
    mask = jnp.ones((x.shape[1], S), bool)
    out = _attend(q, kv["k"].astype(q.dtype), kv["v"].astype(q.dtype), mask,
                  1.0 / np.sqrt(cfg.head_dim), ctx)
    return jnp.einsum("bthk,hkd->btd", out, params["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def init_mla(pf: ParamFactory, cfg: AttnConfig, stacked: int = 0) -> dict:
    L = (stacked,) if stacked else ()
    LA = ("layers",) if stacked else ()
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.head_dim, cfg.rope_dim, cfg.v_head_dim or cfg.head_dim
    qr, kvr = cfg.q_lora, cfg.kv_lora
    p = {
        "wdq": pf.param("wdq", L + (d, qr), LA + ("embed", "q_lora"), fan_in=d),
        "q_norm": pf.param("q_norm", L + (qr,), LA + ("q_lora",), init="zeros"),
        "wuq": pf.param("wuq", L + (qr, H, dn + dr), LA + ("q_lora", "heads", "head_dim"),
                        fan_in=qr),
        "wdkv": pf.param("wdkv", L + (d, kvr), LA + ("embed", "kv_lora"), fan_in=d),
        "kv_norm": pf.param("kv_norm", L + (kvr,), LA + ("kv_lora",), init="zeros"),
        "wkr": pf.param("wkr", L + (d, dr), LA + ("embed", "head_dim"), fan_in=d),
        "wuk": pf.param("wuk", L + (kvr, H, dn), LA + ("kv_lora", "heads", "head_dim"),
                        fan_in=kvr),
        "wuv": pf.param("wuv", L + (kvr, H, dv), LA + ("kv_lora", "heads", "head_dim"),
                        fan_in=kvr),
        "wo": pf.param("wo", L + (H, dv, d), LA + ("heads", "head_dim", "embed"),
                       fan_in=H * dv),
    }
    return p


def init_mla_cache(cfg: AttnConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16, stacked: int = 0, abstract=False) -> dict:
    """MLA caches the COMPRESSED kv (kv_lora) + shared rope key — this is the
    architecture's memory win (cache is head-count independent)."""
    from repro.sharding import AbstractParam
    L = (stacked,) if stacked else ()
    LA = ("layers",) if stacked else ()
    ckv_shape = L + (batch, max_len, cfg.kv_lora)
    kr_shape = L + (batch, max_len, cfg.rope_dim)
    ckv_axes = LA + ("batch", "cache_seq", "kv_lora")
    kr_axes = LA + ("batch", "cache_seq", "head_dim")
    if abstract:
        return {"ckv": AbstractParam(ckv_shape, dtype, ckv_axes),
                "kr": AbstractParam(kr_shape, dtype, kr_axes)}
    return {"ckv": jnp.zeros(ckv_shape, dtype), "kr": jnp.zeros(kr_shape, dtype)}


def _mla_qkr(params, cfg, x, positions):
    cq = jnp.einsum("btd,dr->btr", x, params["wdq"])
    cq = rms_norm(cq, params["q_norm"])
    q = jnp.einsum("btr,rhk->bthk", cq, params["wuq"])
    dn = cfg.head_dim
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(params: dict, cfg: AttnConfig, x: jnp.ndarray,
                positions: jnp.ndarray, ctx: ParallelContext,
                cache: Optional[dict] = None, cache_offset=0,
                pages: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Training / prefill path: materializes per-head K/V (compute-friendly);
    the cache still stores only (ckv, kr). `pages` selects the block-paged
    cache layout (chunked prefill): the chunk's compressed kv / rope key
    scatter through the page table, attention gathers the logical view."""
    B, T, d = x.shape
    dn, dr, dv = cfg.head_dim, cfg.rope_dim, cfg.v_head_dim or cfg.head_dim
    q_nope, q_rope = _mla_qkr(params, cfg, x, positions)
    ckv = jnp.einsum("btd,dr->btr", x, params["wdkv"])
    kr = apply_rope(jnp.einsum("btd,dk->btk", x, params["wkr"])[:, :, None, :],
                    positions, cfg.rope_theta)[:, :, 0, :]
    new_cache = None
    if pages is not None:
        assert cache is not None
        tpos = cache_offset + jnp.arange(T)
        cckv = _paged_write(cache["ckv"], pages, tpos, ckv)
        ckr = _paged_write(cache["kr"], pages, tpos, kr)
        new_cache = {"ckv": cckv, "kr": ckr}
        ckv_all = gather_blocks(cckv, pages).astype(x.dtype)
        kr_all = gather_blocks(ckr, pages).astype(x.dtype)
        S = ckv_all.shape[1]
        mask = make_causal_mask(T, S, cache_offset)
    elif cache is not None:
        cckv = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, cache_offset, 0))
        ckr = jax.lax.dynamic_update_slice(
            cache["kr"], kr.astype(cache["kr"].dtype), (0, cache_offset, 0))
        new_cache = {"ckv": cckv, "kr": ckr}
        ckv_all, kr_all = cckv.astype(x.dtype), ckr.astype(x.dtype)
        S = ckv_all.shape[1]
        mask = make_causal_mask(T, S, cache_offset)
    else:
        ckv_all, kr_all, S = ckv, kr, T
        mask = make_causal_mask(T, T, 0)
    ckv_n = rms_norm(ckv_all, params["kv_norm"])
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv_n, params["wuk"])
    v = jnp.einsum("bsr,rhk->bshk", ckv_n, params["wuv"])
    k_nope = ctx.constrain(k_nope, ("batch", "seq", "heads", "head_dim"))
    v = ctx.constrain(v, ("batch", "seq", "heads", "head_dim"))
    scale = 1.0 / np.sqrt(dn + dr)
    if cfg.attn_chunk:
        # chunked (online-softmax) path: the two-term MLA score equals one
        # GQA score over concatenated (nope || rope) head dims — the
        # [T, S] tensor is never live (same schedule as _attend_chunked).
        q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_cat = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_all[:, :, None, :],
                                      k_nope.shape[:3] + (dr,))], axis=-1)
        out = _attend(q_cat, k_cat, v, mask, scale, ctx,
                      cfg.attn_chunk).astype(x.dtype)
    else:
        scores = (jnp.einsum("bthk,bshk->bhts", q_nope, k_nope,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bthk,bsk->bhts", q_rope, kr_all,
                               preferred_element_type=jnp.float32)) * scale
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhts,bshk->bthk", probs.astype(v.dtype), v,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return ctx.constrain(y, ("batch", "seq", "act_embed")), new_cache


def mla_decode(params: dict, cfg: AttnConfig, x: jnp.ndarray,
               position, cache: dict, ctx: ParallelContext,
               pages: Optional[jnp.ndarray] = None,
               paged_kernel: Optional[bool] = None
               ) -> Tuple[jnp.ndarray, dict]:
    """Weight-absorbed decode: scores/values computed directly against the
    compressed cache — per-step FLOPs and cache reads are O(kv_lora), not
    O(heads*head_dim). This is the TPU-friendly MLA inference form.

    `position` is a scalar or an int vector [B] of per-row depths
    (continuous batching), mirroring `gqa_decode`. `pages` [B, M] selects
    the block-paged cache layout (requires per-row positions);
    `paged_kernel` picks the Pallas paged flash-decode kernel over the
    XLA gather fallback (None = env / backend default, see ops.py)."""
    B, T, d = x.shape
    assert T == 1
    dn, dr, dv = cfg.head_dim, cfg.rope_dim, cfg.v_head_dim or cfg.head_dim
    pos = jnp.asarray(position)
    per_row = pos.ndim == 1
    pos_bt = pos[:, None] if per_row else pos[None, None]
    q_nope, q_rope = _mla_qkr(params, cfg, x, pos_bt)
    ckv_new = jnp.einsum("btd,dr->btr", x, params["wdkv"])
    kr_new = apply_rope(jnp.einsum("btd,dk->btk", x, params["wkr"])[:, :, None, :],
                        pos_bt, cfg.rope_theta)[:, :, 0, :]
    scale = 1.0 / np.sqrt(dn + dr)
    if pages is not None and kernel_ops.paged_kernel_enabled(paged_kernel):
        assert per_row, "paged decode needs per-row positions"
        cckv = kernel_ops.paged_write_token(cache["ckv"], pages, pos,
                                            ckv_new[:, 0])
        ckr = kernel_ops.paged_write_token(cache["kr"], pages, pos,
                                           kr_new[:, 0])
        # absorb W_uk into q; the kernel rms-norms each ckv block in
        # fp32 and returns the latent context — no dense gather
        q_abs = jnp.einsum("bthk,rhk->bthr", q_nope, params["wuk"])
        ctx_lat = kernel_ops.paged_flash_decode_mla(
            q_abs, q_rope, cckv, ckr, params["kv_norm"], pages, pos,
            scale=scale).astype(x.dtype)
        out = jnp.einsum("bthr,rhk->bthk", ctx_lat, params["wuv"])
        y = jnp.einsum("bthk,hkd->btd", out, params["wo"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
        return y, {"ckv": cckv, "kr": ckr}
    if pages is not None:
        assert per_row, "paged decode needs per-row positions"
        cckv = _paged_write(cache["ckv"], pages, pos[:, None], ckv_new)
        ckr = _paged_write(cache["kr"], pages, pos[:, None], kr_new)
        ckv_seq = gather_blocks(cckv, pages)               # [B, M*bs, r]
        kr_seq = gather_blocks(ckr, pages)
    elif per_row:
        rows = jnp.arange(B)
        cckv = cache["ckv"].at[rows, pos].set(
            ckv_new[:, 0].astype(cache["ckv"].dtype))
        ckr = cache["kr"].at[rows, pos].set(
            kr_new[:, 0].astype(cache["kr"].dtype))
        ckv_seq, kr_seq = cckv, ckr
    else:
        cckv = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, pos, 0))
        ckr = jax.lax.dynamic_update_slice(
            cache["kr"], kr_new.astype(cache["kr"].dtype), (0, pos, 0))
        ckv_seq, kr_seq = cckv, ckr
    S = ckv_seq.shape[1]
    ckv_n = rms_norm(ckv_seq.astype(x.dtype), params["kv_norm"])
    # absorb W_uk into q: q_abs [B,1,H,kv_lora]
    q_abs = jnp.einsum("bthk,rhk->bthr", q_nope, params["wuk"])
    scores = (jnp.einsum("bthr,bsr->bhts", q_abs, ckv_n,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bthk,bsk->bhts", q_rope, kr_seq.astype(x.dtype),
                           preferred_element_type=jnp.float32)) * scale
    if per_row:
        mask = (jnp.arange(S)[None, :] <= pos[:, None])[:, None, None, :]
    else:
        mask = (jnp.arange(S) <= pos)[None, None, None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhts,bsr->bthr", probs.astype(x.dtype), ckv_n,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("bthr,rhk->bthk", ctx_lat, params["wuv"])
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y, {"ckv": cckv, "kr": ckr}
