"""Unified decoder LM covering the dense / MoE / MLA / VLM / SSM / hybrid
families, with scan-over-layers, KV/SSM caches, prefill and one-token decode.

API (pure functions over nested-dict params):
    init_params(cfg, key, abstract=False)           -> params
    forward(params, cfg, tokens, ctx, extra_embeds) -> logits [B, T, V]
    init_cache(cfg, batch, max_len, abstract=False) -> cache
    prefill(params, cfg, tokens, cache, ctx, ...)   -> (logits, cache)
    decode_step(params, cfg, token, position, cache, ctx) -> (logits, cache)

Layer stacking: homogeneous groups are stacked on a leading `layers` dim and
folded with `lax.scan` (compile time independent of depth — essential for
lowering llama3-405B's 126 layers 80x in the dry-run). The zamba2 hybrid
runs a Python loop of [6-mamba-scan + shared-attn] super-blocks because its
attention block re-uses ONE weight set (scan xs can't express weight tying).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import attention as attn_lib
from repro.models import ffn as ffn_lib
from repro.models import ssm as ssm_lib
from repro.models.common import (ParamFactory, embed_tokens, init_embedding,
                                 init_rms_norm, rms_norm, unembed)
from repro.sharding import ParallelContext


# ---------------------------------------------------------------------------
# Config adapters
# ---------------------------------------------------------------------------

def attn_config(cfg: ModelConfig, cross: bool = False) -> attn_lib.AttnConfig:
    mla = cfg.mla
    return attn_lib.AttnConfig(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim,
        qkv_bias=cfg.qkv_bias,
        rope_theta=cfg.rope_theta,
        attn_chunk=cfg.attn_chunk or None,
        sliding_window=cfg.sliding_window,
        q_lora=mla.q_lora if mla else 0,
        kv_lora=mla.kv_lora if mla else 0,
        rope_dim=mla.rope_dim if mla else 64,
        v_head_dim=mla.v_head_dim if mla else 0,
    )


def mlp_config(cfg: ModelConfig, activation: str = "swiglu") -> ffn_lib.MLPConfig:
    return ffn_lib.MLPConfig(cfg.d_model, cfg.d_ff, activation)


def moe_config(cfg: ModelConfig) -> ffn_lib.MoEConfig:
    m = cfg.moe
    return ffn_lib.MoEConfig(
        d_model=cfg.d_model, d_ff=m.d_ff_expert, n_experts=m.n_experts,
        top_k=m.top_k, n_shared_experts=m.n_shared_experts,
        shared_d_ff=m.shared_d_ff, capacity_factor=m.capacity_factor,
        router_aux_weight=m.router_aux_weight)


def rwkv_config(cfg: ModelConfig) -> ssm_lib.RWKVConfig:
    return ssm_lib.RWKVConfig(cfg.d_model, cfg.d_ff, head_dim=cfg.head_dim
                              if cfg.head_dim <= cfg.d_model else 64)


def mamba_config(cfg: ModelConfig) -> ssm_lib.Mamba2Config:
    return ssm_lib.Mamba2Config(cfg.d_model, d_state=cfg.ssm_state,
                                head_dim=cfg.ssm_head_dim)


def _group_sizes(cfg: ModelConfig) -> Dict[str, int]:
    """Stacked layer-group sizes per family."""
    if cfg.family == "moe":
        nd = cfg.moe.n_dense_layers
        return {"dense": nd, "moe": cfg.n_layers - nd}
    if cfg.family in ("dense", "vlm", "encdec"):
        return {"dense": cfg.n_layers}
    if cfg.family == "ssm_rwkv":
        return {"rwkv": cfg.n_layers}
    if cfg.family == "hybrid":
        return {"mamba": cfg.n_layers}
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_attn_block(pf: ParamFactory, cfg: ModelConfig, n: int) -> dict:
    ac = attn_config(cfg)
    init_attn = attn_lib.init_mla if cfg.mla else attn_lib.init_gqa
    return {
        "norm1": init_rms_norm(pf, "norm1", cfg.d_model, stacked=n),
        "attn": init_attn(pf.scope("attn"), ac, stacked=n),
        "norm2": init_rms_norm(pf, "norm2", cfg.d_model, stacked=n),
    }


def init_params(cfg: ModelConfig, key, abstract: bool = False) -> dict:
    pf = ParamFactory(None if abstract else key, cfg.pdtype(), abstract)
    params: Dict[str, Any] = {
        "embedding": init_embedding(pf, cfg.vocab_size, cfg.d_model),
        "final_norm": init_rms_norm(pf, "final_norm", cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = pf.param(
            "unembed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
            fan_in=cfg.d_model)
    groups = _group_sizes(cfg)
    blocks: Dict[str, Any] = {}
    if "dense" in groups and groups["dense"]:
        n = groups["dense"]
        act = "gelu" if cfg.family == "encdec" else "swiglu"
        b = _init_attn_block(pf.scope("dense"), cfg, n)
        b["mlp"] = ffn_lib.init_mlp(pf.scope("dense_mlp"),
                                    mlp_config(cfg, act), n)
        blocks["dense"] = b
    if "moe" in groups and groups["moe"]:
        n = groups["moe"]
        b = _init_attn_block(pf.scope("moe"), cfg, n)
        b["moe"] = ffn_lib.init_moe(pf.scope("moe_ffn"), moe_config(cfg), n)
        blocks["moe"] = b
    if "rwkv" in groups:
        blocks["rwkv"] = ssm_lib.init_rwkv_block(
            pf.scope("rwkv"), rwkv_config(cfg), stacked=groups["rwkv"])
    if "mamba" in groups:
        blocks["mamba"] = ssm_lib.init_mamba2_block(
            pf.scope("mamba"), mamba_config(cfg), stacked=groups["mamba"])
        if cfg.shared_attn_every:
            sb = _init_attn_block(pf.scope("shared"), cfg, 0)
            sb["mlp"] = ffn_lib.init_mlp(pf.scope("shared_mlp"),
                                         mlp_config(cfg), 0)
            blocks["shared_attn"] = sb
    params["blocks"] = blocks
    if cfg.encoder is not None:
        params["encoder"] = _init_encoder(pf.scope("encoder"), cfg)
    return params


def _init_encoder(pf: ParamFactory, cfg: ModelConfig) -> dict:
    """Bidirectional encoder stack (whisper-style, GELU MLP, no rope —
    sinusoidal positions added to the stub frame embeddings)."""
    n = cfg.encoder.n_layers
    b = _init_attn_block(pf, cfg, n)
    b["mlp"] = ffn_lib.init_mlp(pf.scope("enc_mlp"),
                                mlp_config(cfg, "gelu"), n)
    cross = attn_lib.init_cross_attn(pf.scope("cross"), attn_config(cfg),
                                     stacked=cfg.n_layers)
    return {"stack": b, "final_norm": init_rms_norm(pf, "enc_norm", cfg.d_model),
            "cross": cross,
            "cross_norm": init_rms_norm(pf, "cross_norm", cfg.d_model,
                                        stacked=cfg.n_layers)}


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               abstract: bool = False, dtype=None) -> dict:
    dtype = dtype or cfg.cdtype()
    groups = _group_sizes(cfg)
    cache: Dict[str, Any] = {}
    ac = attn_config(cfg)
    kv_len = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    if "dense" in groups and groups["dense"]:
        cache["dense"] = (attn_lib.init_mla_cache if cfg.mla else
                          attn_lib.init_gqa_cache)(
            ac, batch, kv_len, dtype, stacked=groups["dense"], abstract=abstract)
    if "moe" in groups and groups["moe"]:
        cache["moe"] = (attn_lib.init_mla_cache if cfg.mla else
                        attn_lib.init_gqa_cache)(
            ac, batch, kv_len, dtype, stacked=groups["moe"], abstract=abstract)
    if "rwkv" in groups:
        cache["rwkv"] = ssm_lib.init_rwkv_state(
            rwkv_config(cfg), batch, dtype, stacked=groups["rwkv"],
            abstract=abstract)
    if "mamba" in groups:
        cache["mamba"] = ssm_lib.init_mamba2_state(
            mamba_config(cfg), batch, dtype, stacked=groups["mamba"],
            abstract=abstract)
        if cfg.shared_attn_every:
            n_inv = cfg.n_layers // cfg.shared_attn_every
            sa_len = min(kv_len, 4096)   # shared attn uses windowed cache
            cache["shared_attn"] = attn_lib.init_gqa_cache(
                dataclasses.replace(ac, sliding_window=sa_len), batch, sa_len,
                dtype, stacked=n_inv, abstract=abstract)
    return cache


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(cfg.remat)


def _attn_ffn_block(p, cfg: ModelConfig, x, positions, ctx,
                    cache=None, cache_offset=0, decode=False, position=None,
                    ffn_kind="mlp", pages=None, paged_kernel=None):
    """One pre-norm transformer block (attention or MLA + dense/MoE FFN).
    Returns (x, new_cache, aux). `pages` selects the block-paged cache
    layout; `paged_kernel` the Pallas-vs-XLA paged decode implementation
    (see models.attention)."""
    ac = attn_config(cfg)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if decode:
        fwd = attn_lib.mla_decode if cfg.mla else attn_lib.gqa_decode
        y, new_cache = fwd(p["attn"], ac, h, position, cache, ctx,
                           pages=pages, paged_kernel=paged_kernel)
    else:
        fwd = attn_lib.mla_forward if cfg.mla else attn_lib.gqa_forward
        y, new_cache = fwd(p["attn"], ac, h, positions, ctx, cache,
                           cache_offset, pages=pages)
    x = x + y
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if ffn_kind == "moe":
        y, aux = ffn_lib.moe_forward(p["moe"], moe_config(cfg), h, ctx,
                                     decode=decode)
    else:
        y = ffn_lib.mlp_forward(p["mlp"], mlp_config(cfg), h, ctx)
    return x + y, new_cache, aux


def _scan_group(block_fn, stacked_params, x, stacked_cache, cfg: ModelConfig):
    """Fold a homogeneous stacked group. block_fn(p_layer, x, cache_layer) ->
    (x, new_cache_layer, aux). Returns (x, new_stacked_cache, aux_sum)."""
    if not cfg.scan_layers:
        n = jax.tree.leaves(stacked_params)[0].shape[0]
        blk = _maybe_remat(block_fn, cfg)
        caches, aux_sum = [], jnp.zeros((), jnp.float32)
        for i in range(n):
            p_i = jax.tree.map(lambda a, i=i: a[i], stacked_params)
            c_i = (None if stacked_cache is None
                   else jax.tree.map(lambda a, i=i: a[i], stacked_cache))
            x, nc, aux = blk(p_i, x, c_i)
            caches.append(nc)
            aux_sum = aux_sum + aux
        new_cache = (None if stacked_cache is None else
                     jax.tree.map(lambda *ls: jnp.stack(ls), *caches))
        return x, new_cache, aux_sum

    def body(carry, xs):
        x, aux_sum = carry
        if stacked_cache is None:
            p_layer, c_layer = xs, None
        else:
            p_layer, c_layer = xs
        x, new_c, aux = block_fn(p_layer, x, c_layer)
        return (x, aux_sum + aux), new_c

    wrapped = _maybe_remat(body, cfg)
    xs = stacked_params if stacked_cache is None else (stacked_params,
                                                       stacked_cache)
    (x, aux_sum), new_cache = jax.lax.scan(wrapped, (x, jnp.zeros((), jnp.float32)), xs)
    return x, new_cache, aux_sum


# ---------------------------------------------------------------------------
# Trunk
# ---------------------------------------------------------------------------

def _trunk(params, cfg: ModelConfig, x, positions, ctx,
           cache=None, cache_offset=0, decode=False, position=None,
           pages=None, paged_kernel=None):
    """Runs all layer groups. x [B,T,d] embeddings. Returns (x, cache, aux).
    `pages` [B, M] routes attention caches through a page table (the
    physical block storage is shared by value, the table by structure:
    every stacked layer's leaf is indexed by the same table);
    `paged_kernel` selects the Pallas paged-decode kernels per layer."""
    blocks = params["blocks"]
    new_cache: Dict[str, Any] = {}
    aux_total = jnp.zeros((), jnp.float32)
    groups = _group_sizes(cfg)

    for kind in ("dense", "moe"):
        if kind not in blocks or not groups.get(kind):
            continue
        def block_fn(p, x_, c_, _kind=kind):
            return _attn_ffn_block(p, cfg, x_, positions, ctx, c_,
                                   cache_offset, decode, position,
                                   ffn_kind=("moe" if _kind == "moe" else "mlp"),
                                   pages=pages, paged_kernel=paged_kernel)
        c = cache.get(kind) if cache is not None else None
        x, nc, aux = _scan_group(block_fn, blocks[kind], x, c, cfg)
        if nc is not None:
            new_cache[kind] = nc
        aux_total = aux_total + aux

    if "rwkv" in blocks:
        rc = rwkv_config(cfg)
        def rwkv_fn(p, x_, c_):
            x_, st = ssm_lib.rwkv_block_forward(p, rc, x_, ctx, c_)
            return x_, st, jnp.zeros((), jnp.float32)
        c = cache.get("rwkv") if cache is not None else None
        if c is None:   # states are mandatory carries; make fresh ones
            c = ssm_lib.init_rwkv_state(rc, x.shape[0], x.dtype,
                                        stacked=groups["rwkv"])
        x, nc, _ = _scan_group(rwkv_fn, blocks["rwkv"], x, c, cfg)
        new_cache["rwkv"] = nc

    if "mamba" in blocks:
        mc = mamba_config(cfg)
        n = groups["mamba"]
        every = cfg.shared_attn_every
        def mamba_fn(p, x_, c_):
            x_, st = ssm_lib.mamba2_block_forward(p, mc, x_, ctx, c_)
            return x_, st, jnp.zeros((), jnp.float32)
        c = cache.get("mamba") if cache is not None else None
        if c is None:
            c = ssm_lib.init_mamba2_state(mc, x.shape[0], x.dtype, stacked=n)
        if not every:
            x, nc, _ = _scan_group(mamba_fn, blocks["mamba"], x, c, cfg)
            new_cache["mamba"] = nc
        else:
            # zamba2: super-blocks of `every` mamba layers + SHARED attn block
            n_inv = n // every
            sa_cache = cache.get("shared_attn") if cache is not None else None
            sa_new, mamba_new = [], []
            sa_cfg = cfg.replace(sliding_window=(
                sa_cache["k"].shape[2] if sa_cache is not None else 4096))
            for g in range(n_inv + (1 if n % every else 0)):
                lo, hi = g * every, min((g + 1) * every, n)
                p_g = jax.tree.map(lambda a, lo=lo, hi=hi: a[lo:hi],
                                   blocks["mamba"])
                c_g = jax.tree.map(lambda a, lo=lo, hi=hi: a[lo:hi], c)
                x, nc_g, _ = _scan_group(mamba_fn, p_g, x, c_g, cfg)
                mamba_new.append(nc_g)
                if g < n_inv:
                    c_sa = (None if sa_cache is None else
                            jax.tree.map(lambda a, g=g: a[g], sa_cache))
                    x, nc_sa, _ = _attn_ffn_block(
                        blocks["shared_attn"], sa_cfg, x, positions, ctx,
                        c_sa, cache_offset, decode, position, ffn_kind="mlp")
                    if nc_sa is not None:
                        sa_new.append(nc_sa)
            new_cache["mamba"] = jax.tree.map(
                lambda *ls: jnp.concatenate(ls), *mamba_new)
            if sa_new:
                new_cache["shared_attn"] = jax.tree.map(
                    lambda *ls: jnp.stack(ls), *sa_new)
    return x, (new_cache if new_cache else None), aux_total


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, tokens, extra_embeds, ctx):
    x = embed_tokens(params["embedding"], tokens).astype(cfg.cdtype())
    if extra_embeds is not None:
        # VLM: patch embeddings prepended (stub frontend output)
        x = jnp.concatenate([extra_embeds.astype(cfg.cdtype()), x], axis=1)
    return ctx.constrain(x, ("batch", "seq", "act_embed"))


def _logits(params, cfg: ModelConfig, x, ctx):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params.get("unembed", params["embedding"])
    logits = unembed(x, table)
    return ctx.constrain(logits, ("batch", "seq", "vocab"))


def forward(params, cfg: ModelConfig, tokens, ctx: ParallelContext,
            extra_embeds=None, return_aux: bool = False):
    """Full-sequence forward (training). tokens [B, T]; extra_embeds
    [B, P, d] (VLM patch stubs / audio handled by encdec module)."""
    x = _embed_inputs(params, cfg, tokens, extra_embeds, ctx)
    B, T, _ = x.shape
    positions = jnp.arange(T)[None, :]
    x, _, aux = _trunk(params, cfg, x, positions, ctx)
    logits = _logits(params, cfg, x, ctx)
    if return_aux:
        return logits, aux
    return logits


def prefill(params, cfg: ModelConfig, tokens, cache, ctx: ParallelContext,
            extra_embeds=None, last_only: bool = False, cache_offset=0,
            pages=None, last_index=None):
    """Process the prompt (or one chunk of it), filling caches. Returns
    (logits, cache).

    last_only=True unembeds only the final position ([B, 1, V]) — the
    serving path needs just the next-token distribution, and unembedding
    all S positions against a 100k+ vocab dominates prefill compute
    (2·B·S·d·V flops) for no consumer.

    Chunked prefill: `cache_offset` (scalar, may be traced) is the absolute
    position of tokens[:, 0] — call repeatedly with consecutive chunks to
    fill a long prompt without materializing its full attention. `pages`
    [B, M] routes cache writes/reads through a page table (block-paged
    serving backend). `last_index` ([B] or scalar, may be traced) unembeds
    that position instead of -1, so a right-padded final chunk still yields
    the true last-prompt-token logits.
    """
    x = _embed_inputs(params, cfg, tokens, extra_embeds, ctx)
    B, T, _ = x.shape
    positions = jnp.arange(T)[None, :] + cache_offset
    x, new_cache, _ = _trunk(params, cfg, x, positions, ctx, cache=cache,
                             cache_offset=cache_offset, pages=pages)
    if last_index is not None:
        idx = jnp.broadcast_to(jnp.asarray(last_index), (B,))
        x = x[jnp.arange(B), idx][:, None, :]
    elif last_only:
        x = x[:, -1:, :]
    return _logits(params, cfg, x, ctx), new_cache


def decode_step(params, cfg: ModelConfig, token, position, cache,
                ctx: ParallelContext, pages=None, paged_kernel=None):
    """One-token decode. token [B] or [B,1]; position scalar OR int vector
    [B] of per-row decode depths (continuous batching over a slot pool —
    each row attends/writes at its own position). `pages` [B, M] routes
    the per-row cache access through a page table (block-paged backend;
    requires vector positions); `paged_kernel` picks the Pallas paged
    flash-decode kernels over the XLA gather fallback (None = env /
    backend default). Returns (logits [B, V], cache)."""
    if token.ndim == 1:
        token = token[:, None]
    x = _embed_inputs(params, cfg, token, None, ctx)
    pos = jnp.asarray(position)
    positions = pos[:, None] if pos.ndim == 1 else jnp.full((1, 1), position)
    x, new_cache, _ = _trunk(params, cfg, x, positions, ctx, cache=cache,
                             decode=True, position=position, pages=pages,
                             paged_kernel=paged_kernel)
    logits = _logits(params, cfg, x, ctx)
    return logits[:, 0, :], new_cache
