"""Shared model components: param factory, norms, RoPE, embeddings.

Models are pure functions over nested-dict param pytrees. Initialization runs
in one of two modes through `ParamFactory`:
  * real     — allocates jnp arrays (smoke tests, CPU training),
  * abstract — returns `AbstractParam` leaves (shape/dtype/logical axes) for
               the multi-pod dry-run: no allocation, exact shardings.
"""
from __future__ import annotations

import zlib
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import AbstractParam


class ParamFactory:
    """Creates named parameters with logical sharding axes.

    RNG handling: each parameter derives its key by folding the path hash
    into the base key, so init is order-independent and stable across
    refactors.
    """

    def __init__(self, key: jax.Array | None, dtype=jnp.float32,
                 abstract: bool = False):
        self.key = key
        self.dtype = dtype
        self.abstract = abstract
        self._path: list[str] = []

    # -- scoping ----------------------------------------------------------
    def scope(self, name: str) -> "ParamFactory":
        child = ParamFactory(self.key, self.dtype, self.abstract)
        child._path = self._path + [name]
        return child

    def _key_for(self, name: str) -> jax.Array:
        # stable across processes (builtin hash() is salted per process,
        # which made init — and every downstream metric — unreproducible)
        path = "/".join(self._path + [name]).encode()
        h = np.uint32(zlib.crc32(path) % (2**31))
        return jax.random.fold_in(self.key, h)

    # -- creators ---------------------------------------------------------
    def param(self, name: str, shape: Sequence[int],
              axes: Sequence[Optional[str]],
              init: str = "normal", scale: float = 1.0,
              fan_in: Optional[int] = None, dtype=None):
        shape = tuple(int(s) for s in shape)
        dtype = dtype or self.dtype
        if self.abstract:
            return AbstractParam(shape, dtype, tuple(axes))
        k = self._key_for(name)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "normal":
            fi = fan_in if fan_in is not None else (shape[0] if len(shape) > 1
                                                    else shape[-1])
            std = scale / np.sqrt(max(fi, 1))
            return (jax.random.normal(k, shape, jnp.float32) * std).astype(dtype)
        if init == "uniform":
            return (jax.random.uniform(k, shape, jnp.float32, -scale, scale)
                    ).astype(dtype)
        if init == "constant":
            return jnp.full(shape, scale, dtype)
        raise ValueError(f"unknown init {init}")


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def init_rms_norm(pf: ParamFactory, name: str, dim: int, stacked: int = 0):
    shape = (stacked, dim) if stacked else (dim,)
    axes = ("layers", "act_embed") if stacked else ("act_embed",)
    return pf.param(name, shape, axes, init="zeros")


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)                       # [head_dim/2]


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: [..., T, H, D]; positions: broadcastable to [..., T]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., T, D/2]
    cos = jnp.cos(angles)[..., None, :]                     # [..., T, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding (vocab sharded over `model`)
# ---------------------------------------------------------------------------

def init_embedding(pf: ParamFactory, vocab: int, d_model: int):
    return pf.param("embedding", (vocab, d_model), ("vocab", "embed"),
                    init="normal", fan_in=d_model)


def embed_tokens(table: jnp.ndarray, tokens: jnp.ndarray,
                 scale_by_sqrt_dim: bool = False) -> jnp.ndarray:
    out = jnp.take(table, tokens, axis=0)
    if scale_by_sqrt_dim:
        out = out * np.sqrt(table.shape[-1])
    return out


def unembed(x: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Tied or untied unembedding: x [..., d] @ table.T -> logits [..., V]."""
    return jnp.einsum("...d,vd->...v", x, table,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def make_causal_mask(q_len: int, kv_len: int, q_offset) -> jnp.ndarray:
    """[q_len, kv_len] bool; True = attendable. q_offset = absolute position
    of q index 0 (scalar or traced)."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    return kv_pos <= q_pos


def make_sliding_mask(q_len: int, kv_len: int, q_offset,
                      window: int) -> jnp.ndarray:
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    return (kv_pos <= q_pos) & (kv_pos > q_pos - window)


def swiglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(gate) * up
