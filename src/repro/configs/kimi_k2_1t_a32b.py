"""Kimi K2 — trillion-param MoE (paper-table) [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) per-expert d_ff=2048 vocab=163840,
MoE 384 experts top-8 (+1 shared expert, DeepSeek-V3-style), first layer
dense. MLA in the real model is approximated here with GQA kv=8 per the
assignment line (which specifies GQA kv=8).
"""
from repro.configs import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,            # 7168 / 64
    d_ff=18432,              # dense-layer FFN (first layer)
    vocab_size=163840,
    rope_theta=50000.0,
    moe=MoESpec(n_experts=384, top_k=8, d_ff_expert=2048,
                n_shared_experts=1, shared_d_ff=2048, n_dense_layers=1),
    param_dtype="bfloat16",
    source="arXiv:2501.kimi2",
)
