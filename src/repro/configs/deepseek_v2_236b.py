"""DeepSeek-V2 236B — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434].

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400. MLA: q_lora=1536,
kv_lora=512, decoupled rope_dim=64, v_head_dim=128. First layer dense FFN
(d_ff = 12288 as in the release).
"""
from repro.configs import ModelConfig, MoESpec, MLASpec

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,          # MLA: per-head KV derived from the latent
    head_dim=128,            # nope dim
    d_ff=12288,              # dense-layer FFN
    vocab_size=102400,
    rope_theta=10000.0,
    moe=MoESpec(n_experts=160, top_k=6, d_ff_expert=1536,
                n_shared_experts=2, shared_d_ff=3072, n_dense_layers=1),
    mla=MLASpec(q_lora=1536, kv_lora=512, rope_dim=64, v_head_dim=128),
    param_dtype="bfloat16",
    source="arXiv:2405.04434",
)
