"""Model configuration registry + assigned input shapes.

Each assigned architecture lives in its own module (src/repro/configs/<id>.py)
exporting `CONFIG`. `get_config(name)` resolves ids; `reduced(cfg)` builds the
CPU-smoke variant (<=2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    shared_d_ff: Optional[int] = None
    n_dense_layers: int = 1          # leading layers with dense FFN
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLASpec:
    q_lora: int = 1536
    kv_lora: int = 512
    rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    """Encoder stack for enc-dec (audio) models. The modality frontend is a
    stub: input_specs provide precomputed frame embeddings [B, n_frames, d]."""
    n_layers: int
    n_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class VisionSpec:
    """VLM stub frontend: precomputed patch embeddings [B, n_patches, d]."""
    n_patches: int = 576


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm_rwkv | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoESpec] = None
    mla: Optional[MLASpec] = None
    ssm_state: int = 64
    ssm_head_dim: int = 64
    shared_attn_every: int = 0       # hybrid: shared attn block period
    encoder: Optional[EncoderSpec] = None
    vision: Optional[VisionSpec] = None
    sliding_window: Optional[int] = None
    # runtime policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "none"              # none | full | dots
    scan_layers: bool = True
    attn_chunk: int = 0              # >0: online-softmax KV-chunked attention
    microbatches: int = 1            # train-step gradient accumulation
    source: str = ""                 # citation

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm_rwkv"

    @property
    def sub_quadratic(self) -> bool:
        return (self.family in ("ssm_rwkv", "hybrid")
                or self.sliding_window is not None)

    def pdtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.param_dtype]

    def cdtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.compute_dtype]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = (
    "kimi_k2_1t_a32b",
    "deepseek_v2_236b",
    "qwen1_5_32b",
    "llama3_405b",
    "whisper_small",
    "rwkv6_3b",
    "phi_3_vision_4_2b",
    "qwen1_5_4b",
    "internlm2_1_8b",
    "zamba2_1_2b",
)

# public ids (with dashes) -> module names
_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIAS.update({
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen1.5-32b": "qwen1_5_32b",
    "llama3-405b": "llama3_405b",
    "whisper-small": "whisper_small",
    "rwkv6-3b": "rwkv6_3b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "qwen1.5-4b": "qwen1_5_4b",
    "internlm2-1.8b": "internlm2_1_8b",
    "zamba2-1.2b": "zamba2_1_2b",
})


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIAS.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def list_configs() -> Tuple[str, ...]:
    return ARCH_IDS


def reduced(cfg: ModelConfig) -> ModelConfig:
    """CPU-smoke variant of the same family: 2 layers, d_model<=512,
    <=4 experts, small vocab."""
    kw = dict(
        name=cfg.name + "-reduced",
        n_layers=2,
        d_model=min(cfg.d_model, 256),
        n_heads=4, n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        param_dtype="float32", compute_dtype="float32",
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_ff_expert=64,
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
            shared_d_ff=64 if cfg.moe.n_shared_experts else None,
            n_dense_layers=1)
    if cfg.mla is not None:
        kw["mla"] = MLASpec(q_lora=64, kv_lora=32, rope_dim=16, v_head_dim=32)
        kw["head_dim"] = 32
    if cfg.encoder is not None:
        kw["encoder"] = EncoderSpec(n_layers=2, n_frames=16)
    if cfg.vision is not None:
        kw["vision"] = VisionSpec(n_patches=8)
    if cfg.family == "hybrid":
        kw["n_layers"] = 4
        kw["shared_attn_every"] = 2
    if cfg.family == "ssm_rwkv":
        kw["d_model"] = 128   # 2 heads of 64
    return cfg.replace(**kw)
