"""Whisper-small — enc-dec audio, conv frontend STUBBED [arXiv:2212.04356].

12L encoder + 12L decoder, d_model=768 12H d_ff=3072 vocab=51865. The
mel-spectrogram + conv feature extractor is a stub: input_specs provide
precomputed frame embeddings [B, 1500, 768] (per the assignment carve-out).
GELU MLPs, bidirectional encoder, cross-attention decoder.
"""
from repro.configs import ModelConfig, EncoderSpec

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,              # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    encoder=EncoderSpec(n_layers=12, n_frames=1500),
    source="arXiv:2212.04356",
)
