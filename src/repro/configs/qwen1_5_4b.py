"""Qwen1.5-4B — dense, QKV bias [hf:Qwen/Qwen1.5-0.5B family].

40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936.
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=5000000.0,
    source="hf:Qwen/Qwen1.5-0.5B",
)
