"""Zamba2 1.2B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

38 Mamba2 layers, d_model=2048, ssm_state=64; a SHARED-weight attention+MLP
block (32H, d_ff=8192) is invoked every 6 mamba layers (weight re-use is
Zamba2's signature trick; the release interleaves two shared blocks — we
approximate with one, noted in DESIGN.md).
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,             # mamba2 layers
    d_model=2048,
    n_heads=32,              # shared attention block heads
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,               # shared block MLP
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    shared_attn_every=6,
    source="arXiv:2411.15242",
)
