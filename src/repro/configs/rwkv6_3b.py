"""RWKV6 "Finch" 3B — attention-free, data-dependent decay [arXiv:2404.05892].

32L d_model=2560 d_ff=8960 vocab=65536; head_dim 64 (40 heads).
"""
from repro.configs import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm_rwkv",
    n_layers=32,
    d_model=2560,
    n_heads=40,              # d_model / 64 (informational; RWKV derives it)
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    source="arXiv:2404.05892",
)
