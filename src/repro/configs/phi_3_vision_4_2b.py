"""Phi-3-vision 4.2B — phi3-mini decoder + CLIP frontend (STUBBED)
[hf:microsoft/Phi-3-vision-128k-instruct].

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064. Vision encoder +
projector stubbed: input_specs provide patch embeddings [B, 576, 3072]
prepended to the token embeddings.
"""
from repro.configs import ModelConfig, VisionSpec

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    vision=VisionSpec(n_patches=576),
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)
