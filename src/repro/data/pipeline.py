"""Minimal but real data pipeline: deterministic shuffling, epoch batching,
device placement with mesh-aware sharding of the batch dim.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.sharding import logical_to_spec


class BatchIterator:
    """Shuffled epoch iterator over aligned arrays.

    yields dicts of jnp arrays; if a mesh is given, batches are placed with
    batch-dim sharding over the data axes (host-local data feeding).
    """

    def __init__(self, arrays: dict, batch_size: int, *, key=None,
                 mesh: Optional[Mesh] = None, drop_last: bool = True,
                 batch_axes=("batch",)):
        self.arrays = {k: np.asarray(v) for k, v in arrays.items()}
        sizes = {v.shape[0] for v in self.arrays.values()}
        assert len(sizes) == 1, f"misaligned arrays: { {k: v.shape for k, v in self.arrays.items()} }"
        self.n = sizes.pop()
        self.batch_size = batch_size
        self.mesh = mesh
        self.drop_last = drop_last
        self._rng = np.random.default_rng(
            0 if key is None else int(jax.random.randint(key, (), 0, 2**31 - 1)))

    def __len__(self):
        if self.drop_last:
            return self.n // self.batch_size
        return int(np.ceil(self.n / self.batch_size))

    def epoch(self) -> Iterator[dict]:
        order = self._rng.permutation(self.n)
        nb = len(self)
        for i in range(nb):
            idx = order[i * self.batch_size:(i + 1) * self.batch_size]
            batch = {k: v[idx] for k, v in self.arrays.items()}
            if self.mesh is not None:
                batch = {k: self._place(v) for k, v in batch.items()}
            yield batch

    def _place(self, arr: np.ndarray):
        axes = ("batch",) + (None,) * (arr.ndim - 1)
        spec = logical_to_spec(axes, arr.shape, self.mesh)
        return jax.device_put(arr, NamedSharding(self.mesh, spec))

    def forever(self) -> Iterator[dict]:
        while True:
            yield from self.epoch()
