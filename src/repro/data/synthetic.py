"""Synthetic task generators with *controllable difficulty*.

The paper's experiments need tasks where M_S is genuinely weaker than M_L so
that deferral has headroom (paper assumption: M_S strictly less capable).
Everything is generated deterministically from PRNG keys — no downloads.

Tasks:
  * classification — C-class task with an "easy" linear subspace and a
    "hard" parity/interaction subspace: small MLPs master the former,
    larger MLPs also capture the latter (mirrors CIFAR easy/hard split).
  * lm_qa — closed-form QA sequences [BOS, op, a, b, c, SEP, ans]: `copy`
    is learnable by tiny models; `add`/`mul` (modular arithmetic) need
    capacity (mirrors ARC-e vs ARC-c difficulty split).
  * captions — VLM-style: stub patch embeddings encode a scene (class +
    attribute); the decoder emits a short "caption" token sequence; a
    programmatic factuality score replaces the paper's Gemini judge.
  * lm_stream — Zipf-Markov token stream for the 100M-scale train driver.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# Reserved token ids shared by all synthetic vocabularies
PAD, TOK_REDUCE_CONF, TOK_ANSWER_N, TOK_N, BOS, SEP = 0, 1, 2, 3, 4, 5
SYMBOL_BASE = 6


# ---------------------------------------------------------------------------
# Classification (paper §4.1 analogue)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ClassificationData:
    x: np.ndarray          # [N, d]
    y: np.ndarray          # [N]
    is_hard: np.ndarray    # [N] bool — ground-truth difficulty (diagnostics)


def make_classification(key, n: int, n_classes: int = 16,
                        d_easy: int = 16, factors_per_bit: int = 3,
                        hard_frac: float = 0.45,
                        easy_margin: float = 3.0,
                        noise: float = 1.0,
                        task_seed: int = 1234) -> ClassificationData:
    """Easy examples: class mean separated by `easy_margin` in the linear
    subspace. Hard examples: linear subspace is pure noise; the class is
    encoded as a PRODUCT-PARITY code — bit j of the class is the sign of
    the product of `factors_per_bit` hard dims. With 3 factors, small MLPs
    on small sample budgets memorize (becoming overconfidently wrong on
    test data — the regime where cascades/Gatekeeper matter) while larger
    MLPs with more data learn it exactly (verified in tests/benchmarks).

    TASK parameters (class means) come from `task_seed`, SAMPLES from
    `key` — train/val/test splits drawn with different keys share one task.
    """
    n_bits = int(np.ceil(np.log2(n_classes)))
    d_hard = n_bits * factors_per_bit
    tkey = jax.random.PRNGKey(task_seed)
    means = jax.random.normal(tkey, (n_classes, d_easy)) * easy_margin

    k1, k2, k4, k5, k6 = jax.random.split(key, 5)
    y = jax.random.randint(k1, (n,), 0, n_classes)
    hard = jax.random.uniform(k2, (n,)) < hard_frac
    x_easy = means[y] + jax.random.normal(k4, (n, d_easy)) * noise
    x_easy = jnp.where(hard[:, None],
                       jax.random.normal(k5, (n, d_easy)) * noise, x_easy)
    bits = (y[:, None] >> jnp.arange(n_bits)[None, :]) & 1      # [n, bits]
    s = jnp.sign(jax.random.normal(k6, (n, n_bits, factors_per_bit)))
    s = s + (s == 0)                                             # no zeros
    prod = jnp.prod(s[:, :, :-1], axis=-1)
    s = s.at[:, :, -1].set(prod * (2 * bits - 1))                # product=bit
    mag = jnp.abs(jax.random.normal(jax.random.fold_in(k6, 1),
                                    (n, n_bits, factors_per_bit))) + 0.5
    x_hard = (s * mag).reshape(n, d_hard)
    x_hard = jnp.where(hard[:, None], x_hard,
                       jax.random.normal(jax.random.fold_in(k6, 2),
                                         (n, d_hard)))
    x = jnp.concatenate([x_easy, x_hard], axis=-1)
    return ClassificationData(np.asarray(x, np.float32), np.asarray(y),
                              np.asarray(hard))


# ---------------------------------------------------------------------------
# Closed-form QA sequences (paper §4.2 analogue)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QAData:
    tokens: np.ndarray       # [N, T] int32, next-token targets = tokens[:,1:]
    answer_pos: int          # index of the answer token
    loss_mask: np.ndarray    # [N, T-1] — 1 where next-token loss applies
    op: np.ndarray           # [N] 0=copy 1=add 2=mul (difficulty)
    n_symbols: int
    vocab: int

    @property
    def inputs(self):
        return self.tokens[:, :-1]

    @property
    def targets(self):
        return self.tokens[:, 1:]


def make_qa(key, n: int, n_symbols: int = 16,
            op_probs=(0.4, 0.3, 0.3)) -> QAData:
    """Sequences: [BOS, op_tok, a, b, c, SEP, ans, PAD].

    ops: copy -> ans=a; add -> ans=(a+b) mod K; mul -> ans=(a*b+c) mod K.
    """
    K = n_symbols
    k1, k2 = jax.random.split(key)
    op = jax.random.choice(k1, 3, (n,), p=jnp.asarray(op_probs))
    abc = jax.random.randint(k2, (n, 3), 0, K)
    a, b, c = abc[:, 0], abc[:, 1], abc[:, 2]
    ans = jnp.where(op == 0, a,
                    jnp.where(op == 1, (a + b) % K, (a * b + c) % K))
    op_tok = SYMBOL_BASE + K + op                 # 3 op tokens after symbols
    toks = jnp.stack([
        jnp.full((n,), BOS), op_tok, SYMBOL_BASE + a, SYMBOL_BASE + b,
        SYMBOL_BASE + c, jnp.full((n,), SEP), SYMBOL_BASE + ans,
        jnp.full((n,), PAD)], axis=1).astype(jnp.int32)
    T = toks.shape[1]
    answer_pos = 6
    mask = np.zeros((n, T - 1), np.float32)
    mask[:, answer_pos - 1] = 1.0                  # predict ans from SEP
    return QAData(np.asarray(toks), answer_pos, mask, np.asarray(op),
                  K, SYMBOL_BASE + K + 3)


# ---------------------------------------------------------------------------
# VLM captions (paper §4.3 analogue)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CaptionData:
    patches: np.ndarray      # [N, P, d_model] stub vision-frontend output
    tokens: np.ndarray       # [N, T] caption token sequence (BOS ... )
    classes: np.ndarray      # [N] latent scene class
    attrs: np.ndarray        # [N] latent attribute
    vocab: int

    @property
    def inputs(self):
        return self.tokens[:, :-1]

    @property
    def targets(self):
        return self.tokens[:, 1:]


def make_captions(key, n: int, n_patches: int = 8, d_model: int = 64,
                  n_classes: int = 12, n_attrs: int = 6,
                  hard_frac: float = 0.4,
                  task_seed: int = 1234) -> CaptionData:
    """Patch embeddings = class embedding + attribute embedding + noise.
    Caption = [BOS, class_tok, attr_tok, SEP]. "Hard" scenes get extra noise
    so the attribute becomes ambiguous for low-capacity decoders.

    TASK parameters (class/attr embeddings) come from `task_seed`; SAMPLES
    from `key` — splits drawn with different keys share one task.
    """
    tkey = jax.random.PRNGKey(task_seed)
    cls_emb = jax.random.normal(tkey, (n_classes, d_model))
    attr_emb = jax.random.normal(jax.random.fold_in(tkey, 1),
                                 (n_attrs, d_model))
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    cls = jax.random.randint(k1, (n,), 0, n_classes)
    attr = jax.random.randint(k2, (n,), 0, n_attrs)
    hard = jax.random.uniform(k3, (n,)) < hard_frac
    noise_scale = jnp.where(hard, 5.0, 0.3)[:, None, None]
    patches = (cls_emb[cls][:, None, :] + 0.5 * attr_emb[attr][:, None, :]
               + jax.random.normal(k5, (n, n_patches, d_model)) * noise_scale)
    cls_tok = SYMBOL_BASE + cls
    attr_tok = SYMBOL_BASE + n_classes + attr
    toks = jnp.stack([jnp.full((n,), BOS), cls_tok, attr_tok,
                      jnp.full((n,), SEP)], axis=1).astype(jnp.int32)
    return CaptionData(np.asarray(patches, np.float32), np.asarray(toks),
                       np.asarray(cls), np.asarray(attr),
                       SYMBOL_BASE + n_classes + n_attrs)


def caption_factuality(pred_tokens: np.ndarray, data: CaptionData) -> np.ndarray:
    """Programmatic stand-in for the paper's Gemini factuality judge:
    graded score in [0,1] — 0.7 for the correct class token + 0.3 for the
    correct attribute token (captions are 'semantically equivalent' when
    they name the right scene; the attribute refines it)."""
    cls_ok = (pred_tokens[:, 0] == SYMBOL_BASE + data.classes)
    attr_ok = (pred_tokens[:, 1] == SYMBOL_BASE + data.vocab * 0
               + SYMBOL_BASE + 0)  # placeholder, replaced below
    n_classes = int(data.classes.max()) + 1
    attr_ok = (pred_tokens[:, 1] == SYMBOL_BASE + n_classes + data.attrs)
    return 0.7 * cls_ok.astype(np.float64) + 0.3 * attr_ok.astype(np.float64)


# ---------------------------------------------------------------------------
# Token stream for the large-scale train driver
# ---------------------------------------------------------------------------

def make_ragged_lm_stream(key, n_seqs: int, len_min: int, len_max: int,
                          vocab: int):
    """Ragged serving workload: `n_seqs` prompts whose lengths are drawn
    uniformly from [len_min, len_max] (inclusive), token content from the
    same Zipf-Markov stream as `make_lm_stream`. Returns a list of 1-D
    int32 arrays (mixed lengths — feed to `serving.make_requests`)."""
    if not 1 <= len_min <= len_max:
        raise ValueError("need 1 <= len_min <= len_max")
    base = make_lm_stream(key, n_seqs, len_max, vocab)
    rng = np.random.default_rng(
        int(jax.random.randint(jax.random.fold_in(key, 1), (), 0,
                               2**31 - 1)))
    lens = rng.integers(len_min, len_max + 1, size=n_seqs)
    return [base[i, :lens[i]].astype(np.int32) for i in range(n_seqs)]


def make_lm_stream(key, n_seqs: int, seq_len: int, vocab: int,
                   order: int = 2) -> np.ndarray:
    """Zipf-initialized order-`order` Markov chain token stream: cheap to
    sample, non-trivial to model (bigram structure + Zipf unigram mix)."""
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    V = vocab
    zipf = 1.0 / np.arange(1, V + 1)
    zipf /= zipf.sum()
    # hidden-state mixer: token ~ p(t | t-1) built from a small state machine
    n_states = 64
    state_next = rng.integers(0, n_states, size=(n_states, 8))
    state_emit = rng.permutation(V)[:n_states * 8].reshape(n_states, 8) \
        if V >= n_states * 8 else rng.integers(0, V, size=(n_states, 8))
    out = np.empty((n_seqs, seq_len), np.int32)
    state = rng.integers(0, n_states, size=n_seqs)
    for t in range(seq_len):
        branch = rng.integers(0, 8, size=n_seqs)
        zipf_mask = rng.random(n_seqs) < 0.15
        tok = state_emit[state, branch]
        tok[zipf_mask] = rng.choice(V, size=zipf_mask.sum(), p=zipf)
        out[:, t] = tok
        state = state_next[state, branch]
    return out
