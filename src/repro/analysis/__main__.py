"""CLI: ``python -m repro.analysis --paths src tests benchmarks``.

Exit codes: 0 = clean (modulo baseline), 1 = new findings or file
errors, 2 = usage error. ``--write-baseline`` regenerates the baseline
from the current findings (then hand-edit each entry's justification —
see docs/analysis.md for the ratchet workflow).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.core import load_baseline, run_analysis
from repro.analysis.registry import ALL_RULES, get_rules


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Repo-specific static analysis (trace-safety, "
                    "lock discipline, determinism, Pallas contracts).")
    ap.add_argument("--paths", nargs="+", default=["src"],
                    help="files or directories to analyze")
    ap.add_argument("--root", default=".",
                    help="repo root paths are relative to (and baseline "
                         "paths are recorded against)")
    ap.add_argument("--baseline", default="analysis_baseline.json",
                    help="grandfathered-findings file ('' to disable)")
    ap.add_argument("--rules", default=None,
                    help=f"comma-separated rule families to run "
                         f"(default all: {','.join(ALL_RULES)})")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable JSON report on stdout")
    ap.add_argument("--verbose", action="store_true",
                    help="also list baselined findings")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite --baseline from current findings "
                         "(keeps existing justifications)")
    args = ap.parse_args(argv)

    try:
        rules = get_rules(args.rules.split(",") if args.rules else None)
    except KeyError as e:
        ap.error(str(e))
    baseline = load_baseline(args.baseline or None)
    report = run_analysis(args.paths, root=args.root, baseline=baseline,
                          rules=rules)

    if args.write_baseline:
        if not args.baseline:
            ap.error("--write-baseline needs --baseline")
        # keep hand-written justifications for findings that persist
        just = {(str(e.get("rule")), str(e.get("code")), str(e.get("path")),
                 str(e.get("context")), str(e.get("snippet"))):
                str(e.get("justification", ""))
                for e in baseline.entries}
        from repro.analysis.core import Baseline
        fresh = Baseline.from_findings(report.findings)
        for e in fresh.entries:
            key = (e["rule"], e["code"], e["path"], e["context"],
                   e["snippet"])
            if just.get(key):
                e["justification"] = just[key]
        fresh.dump(args.baseline)
        print(f"wrote {len(fresh.entries)} entries to {args.baseline} "
              f"(review every 'TODO: justify')")
        return 0

    if args.as_json:
        print(json.dumps(report.to_json(), indent=2))
    else:
        print(report.render(verbose=args.verbose))
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
