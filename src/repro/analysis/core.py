"""Rule engine: findings, suppressions, baseline, and the runner.

Identity model
--------------
A finding's *identity* deliberately excludes the line number:

    (rule, code, path, context, snippet)

``context`` is the dotted lexical scope (``Class.method`` or
``func.<locals>.inner``) and ``snippet`` the stripped source line. That
makes baseline entries survive unrelated edits above them — the
baseline only "expires" when the flagged line itself (or its enclosing
scope) changes, which is exactly when a human should re-justify it.

Suppression
-----------
A trailing ``# repro: ignore[...]`` comment on the flagged physical
line silences it::

    cs = jax.device_get(x)   # repro: ignore[trace-safety]
    h = hash(key)            # repro: ignore[DM001]

The bracket token matches either the rule family name or the specific
finding code; a bare ``# repro: ignore`` silences every rule on the
line (use sparingly — it also hides future rules).

Baseline
--------
``analysis_baseline.json`` holds grandfathered findings so the gate
starts green and *ratchets*: new findings fail, removing code removes
its entries (stale entries are reported so they get pruned). Every
entry carries a one-line ``justification`` — the baseline doubles as
the registry of deliberate exceptions (e.g. the engine's intended
per-sync ``device_get``).
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import os
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

BASELINE_VERSION = 1

_SUPPRESS_RE = re.compile(r"#\s*repro:\s*ignore(?:\[([^\]]*)\])?")
_GUARDED_RE = re.compile(r"#\s*guarded_by:\s*([A-Za-z_][\w.]*)")
_PRAGMA_DETERMINISTIC_RE = re.compile(r"#\s*repro:\s*deterministic-module")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a concrete source location."""

    rule: str          # rule family, e.g. "trace-safety"
    code: str          # specific check id, e.g. "TS001"
    path: str          # repo-relative posix path
    line: int          # 1-based line number (display only)
    context: str       # dotted lexical scope of the flagged node
    message: str
    snippet: str       # stripped source text of the flagged line

    @property
    def key(self) -> Tuple[str, str, str, str, str]:
        """Line-number-free identity used for baseline matching."""
        return (self.rule, self.code, self.path, self.context, self.snippet)

    def to_json(self) -> Dict[str, object]:
        return {"rule": self.rule, "code": self.code, "path": self.path,
                "line": self.line, "context": self.context,
                "message": self.message, "snippet": self.snippet}

    def render(self) -> str:
        ctx = f" [{self.context}]" if self.context else ""
        return (f"{self.path}:{self.line}: {self.code} ({self.rule}) "
                f"{self.message}{ctx}")


class Baseline:
    """Grandfathered findings, keyed by line-number-free identity."""

    def __init__(self, entries: Optional[List[Dict[str, object]]] = None,
                 path: Optional[str] = None):
        self.path = path
        self.entries = list(entries or [])
        self._keys: Set[Tuple[str, ...]] = {
            (str(e.get("rule", "")), str(e.get("code", "")),
             str(e.get("path", "")), str(e.get("context", "")),
             str(e.get("snippet", "")))
            for e in self.entries}

    def matches(self, finding: Finding) -> bool:
        return finding.key in self._keys

    def stale_entries(self, findings: Sequence[Finding]) -> List[Dict]:
        """Entries matching nothing in this run — candidates to prune
        (the ratchet's downward direction)."""
        live = {f.key for f in findings}
        return [e for e in self.entries
                if (str(e.get("rule", "")), str(e.get("code", "")),
                    str(e.get("path", "")), str(e.get("context", "")),
                    str(e.get("snippet", ""))) not in live]

    @staticmethod
    def from_findings(findings: Sequence[Finding],
                      justification: str = "TODO: justify") -> "Baseline":
        seen: Set[Tuple[str, ...]] = set()
        entries = []
        for f in sorted(findings, key=lambda f: (f.path, f.line, f.code)):
            if f.key in seen:
                continue
            seen.add(f.key)
            entries.append({"rule": f.rule, "code": f.code, "path": f.path,
                            "context": f.context, "snippet": f.snippet,
                            "justification": justification})
        return Baseline(entries)

    def dump(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump({"version": BASELINE_VERSION,
                       "entries": self.entries}, fh, indent=2,
                      sort_keys=False)
            fh.write("\n")


def load_baseline(path: Optional[str]) -> Baseline:
    if path is None or not os.path.exists(path):
        return Baseline(path=path)
    with open(path) as fh:
        data = json.load(fh)
    version = data.get("version")
    if version != BASELINE_VERSION:
        raise ValueError(f"{path}: unsupported baseline version {version!r} "
                         f"(expected {BASELINE_VERSION})")
    return Baseline(data.get("entries", []), path=path)


class SourceModule:
    """One parsed source file plus the comment-derived side tables every
    rule needs (suppressions, ``guarded_by`` annotations, pragmas)."""

    def __init__(self, path: str, rel_path: str, text: str):
        self.path = path
        self.rel_path = rel_path.replace(os.sep, "/")
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)
        # line -> set of suppression tokens ("*" = suppress everything)
        self.suppressions: Dict[int, Set[str]] = {}
        # line -> lock expression string from a guarded-by annotation
        self.guarded_by: Dict[int, str] = {}
        self.deterministic_pragma = False
        for i, comment in self._comments(text):
            m = _SUPPRESS_RE.search(comment)
            if m:
                raw = m.group(1)
                if raw is None or not raw.strip():
                    self.suppressions[i] = {"*"}
                else:
                    self.suppressions[i] = {t.strip() for t in raw.split(",")
                                            if t.strip()}
            m = _GUARDED_RE.search(comment)
            if m:
                self.guarded_by[i] = m.group(1)
            if _PRAGMA_DETERMINISTIC_RE.search(comment):
                self.deterministic_pragma = True

    @staticmethod
    def _comments(text: str) -> List[Tuple[int, str]]:
        """(line, comment text) for real COMMENT tokens only — a
        ``# guarded_by:`` example inside a docstring is not an
        annotation."""
        out: List[Tuple[int, str]] = []
        try:
            for tok in tokenize.generate_tokens(io.StringIO(text).readline):
                if tok.type == tokenize.COMMENT:
                    out.append((tok.start[0], tok.string))
        except (tokenize.TokenError, IndentationError):
            pass
        return out

    def suppressed(self, line: int, rule: str, code: str) -> bool:
        tokens = self.suppressions.get(line)
        if not tokens:
            return False
        return "*" in tokens or rule in tokens or code in tokens

    def snippet(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def finding(self, rule: str, code: str, node: ast.AST, context: str,
                message: str) -> Optional[Finding]:
        """Build a Finding for `node` unless its line is suppressed."""
        line = getattr(node, "lineno", 1)
        if self.suppressed(line, rule, code):
            return None
        return Finding(rule=rule, code=code, path=self.rel_path, line=line,
                       context=context, message=message,
                       snippet=self.snippet(line))


@dataclasses.dataclass
class AnalysisReport:
    """Everything one run produced, split against the baseline."""

    findings: List[Finding]            # every unsuppressed finding
    new: List[Finding]                 # not covered by the baseline
    baselined: List[Finding]           # covered by the baseline
    stale_baseline: List[Dict]         # baseline entries matching nothing
    errors: List[str]                  # unparseable files etc.
    n_files: int = 0

    @property
    def exit_code(self) -> int:
        return 1 if (self.new or self.errors) else 0

    def to_json(self) -> Dict[str, object]:
        return {
            "n_files": self.n_files,
            "n_findings": len(self.findings),
            "new": [f.to_json() for f in self.new],
            "baselined": [f.to_json() for f in self.baselined],
            "stale_baseline": self.stale_baseline,
            "errors": self.errors,
        }

    def render(self, verbose: bool = False) -> str:
        out: List[str] = []
        for f in sorted(self.new, key=lambda f: (f.path, f.line, f.code)):
            out.append(f.render())
        for e in self.errors:
            out.append(f"error: {e}")
        if verbose and self.baselined:
            out.append(f"-- {len(self.baselined)} baselined finding(s):")
            for f in sorted(self.baselined,
                            key=lambda f: (f.path, f.line, f.code)):
                out.append(f"   {f.render()}")
        if self.stale_baseline:
            out.append(f"-- {len(self.stale_baseline)} stale baseline "
                       f"entr{'y' if len(self.stale_baseline) == 1 else 'ies'}"
                       f" (matched nothing — prune from the baseline):")
            for e in self.stale_baseline:
                out.append(f"   {e.get('path')}: {e.get('code')} "
                           f"{e.get('snippet', '')!r}")
        status = "clean" if not self.new and not self.errors else "FAIL"
        out.append(f"repro.analysis: {self.n_files} files, "
                   f"{len(self.findings)} finding(s) "
                   f"({len(self.new)} new, {len(self.baselined)} baselined)"
                   f" -> {status}")
        return "\n".join(out)


_SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", ".pytest_cache",
              "node_modules", ".venv", "venv"}


def collect_files(paths: Sequence[str], root: str = ".") -> List[str]:
    """Expand path arguments (files or directories) into a sorted list
    of .py files, repo-relative to `root`."""
    out: List[str] = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(full):
            out.append(full)
            continue
        for dirpath, dirnames, filenames in os.walk(full):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in _SKIP_DIRS
                                 and not d.startswith("."))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    return sorted(set(out))


def run_analysis(paths: Sequence[str], *, root: str = ".",
                 baseline: Optional[Baseline] = None,
                 rules: Optional[Iterable] = None) -> AnalysisReport:
    """Run `rules` (default: all registered) over every .py file under
    `paths`, split findings against `baseline`."""
    from repro.analysis.registry import get_rules
    rules = list(rules) if rules is not None else get_rules()
    baseline = baseline or Baseline()
    findings: List[Finding] = []
    errors: List[str] = []
    files = collect_files(paths, root=root)
    for full in files:
        rel = os.path.relpath(full, root)
        try:
            with open(full, encoding="utf-8") as fh:
                text = fh.read()
            module = SourceModule(full, rel, text)
        except (SyntaxError, UnicodeDecodeError, OSError) as e:
            errors.append(f"{rel}: {e}")
            continue
        for rule in rules:
            try:
                findings.extend(f for f in rule.check(module)
                                if f is not None)
            except Exception as e:  # a rule crash is an analyzer bug:
                # surface it as a failing finding, never a silent skip
                errors.append(f"{rel}: rule {rule.name!r} crashed: {e!r}")
    new = [f for f in findings if not baseline.matches(f)]
    baselined = [f for f in findings if baseline.matches(f)]
    return AnalysisReport(findings=findings, new=new, baselined=baselined,
                          stale_baseline=baseline.stale_entries(findings),
                          errors=errors, n_files=len(files))


# --------------------------------------------------------------------------
# shared AST helpers used by several rules
# --------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """'jax.lax.fori_loop' for nested Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def qualname_of(stack: Sequence[ast.AST]) -> str:
    """Dotted context from a stack of enclosing Class/Function nodes."""
    parts: List[str] = []
    for node in stack:
        if isinstance(node, ast.ClassDef):
            parts.append(node.name)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            parts.append(node.name)
        elif isinstance(node, ast.Lambda):
            parts.append("<lambda>")
    return ".".join(parts)


def iter_scopes(tree: ast.Module):
    """Yield (node, stack) for every function/class definition, where
    `stack` is the chain of enclosing definitions including `node`."""
    def walk(node: ast.AST, stack: List[ast.AST]):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef, ast.Lambda)):
                sub = stack + [child]
                yield child, sub
                yield from walk(child, sub)
            else:
                yield from walk(child, stack)
    yield from walk(tree, [])


def positional_params(fn) -> List[str]:
    """Positional parameter names of a FunctionDef/Lambda (excludes
    keyword-only params — the repo convention binds static config
    keyword-only via functools.partial)."""
    a = fn.args
    names = [p.arg for p in getattr(a, "posonlyargs", [])]
    names += [p.arg for p in a.args]
    return names


def unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:
        return "<unparseable>"
