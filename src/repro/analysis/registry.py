"""Rule registry: the four rule families, instantiable by name."""
from __future__ import annotations

from typing import Iterable, List, Optional

from repro.analysis.determinism import DeterminismRule
from repro.analysis.lock_discipline import LockDisciplineRule
from repro.analysis.pallas_contracts import PallasContractsRule
from repro.analysis.trace_safety import TraceSafetyRule

ALL_RULES = {
    TraceSafetyRule.name: TraceSafetyRule,
    LockDisciplineRule.name: LockDisciplineRule,
    DeterminismRule.name: DeterminismRule,
    PallasContractsRule.name: PallasContractsRule,
}


def get_rules(names: Optional[Iterable[str]] = None) -> List[object]:
    """Instantiate rules by family name (default: all four)."""
    if names is None:
        return [cls() for cls in ALL_RULES.values()]
    out = []
    for name in names:
        cls = ALL_RULES.get(name)
        if cls is None:
            raise KeyError(f"unknown rule {name!r}; "
                           f"known: {', '.join(sorted(ALL_RULES))}")
        out.append(cls())
    return out
