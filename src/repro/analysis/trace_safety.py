"""trace-safety: host-sync hazards inside jit/Pallas-traced regions.

The serving engine's performance story depends on *where* host<->device
synchronization happens: the decode loop does exactly one deliberate
``jax.device_get`` per sync interval, and nothing inside a traced
region (``jax.jit``, ``pl.pallas_call`` kernels, ``lax.fori_loop`` /
``scan`` / ``cond`` bodies) may force a transfer or branch on a traced
value — that either crashes at trace time (``TracerBoolConversion``)
or, worse, silently bakes one calibration of a value into the compiled
function.

Mechanics
---------
1. Build a per-module *traced-region call graph*: functions passed to
   trace-inducing callables (``jax.jit(f)``, ``pl.pallas_call(k)``,
   ``lax.fori_loop(_, _, body, _)`` ...) or decorated with them are
   roots; anything they call (bare names resolved lexically,
   ``self.method`` resolved within the class) or define inside
   (``@pl.when(...)`` bodies, closures) is traced too. Resolution is
   within-module — cross-module traced helpers need their own roots or
   a suppression.
2. Inside traced functions, run a small forward taint pass. Taint is
   only *seeded* where parameter provenance is known: functions passed
   directly to a trace entry (and defs nested inside them — ``scan`` /
   ``fori_loop`` bodies, closures) take traced positional arguments;
   transitively-called helpers often receive static shape/config ints,
   so they get no seeds (TS001 still applies inside them). Seeds
   exclude: ``self``/``cls``; names listed in ``static_argnames`` /
   positions in ``static_argnums`` on the jit call or decorator;
   parameters with literal defaults (``x=None``, ``flag=False``); and
   the repo's static-config parameter names (``cfg``, ``config``,
   ``ctx``, ``mesh`` — config dataclasses are threaded positionally
   but are hashable statics, never traced). ``.shape`` / ``.dtype`` /
   ``.ndim`` / ``.size`` projections and ``len()`` / ``isinstance()``
   style structure queries are static at trace time and launder taint.

Checks
------
* TS001 — ``jax.device_get`` / ``jax.block_until_ready`` called inside
  a traced region (always wrong: forces a transfer at trace time).
* TS002 — host coercion of a traced value: ``.item()`` / ``.tolist()``
  / ``float()`` / ``int()`` / ``bool()`` on a tainted expression.
* TS003 — ``np.*`` called on a traced value (NumPy silently calls
  ``__array__`` and materializes the tracer).
* TS004 — Python ``if`` / ``while`` branching on a traced value
  (``x is None`` identity tests are static and exempt).
* TS005 — host-sync *audit*: every ``jax.device_get`` /
  ``jax.block_until_ready`` call site in ``src/repro/serving/`` host
  code must be deliberate — new sites fail until baselined with a
  justification or removed. This is how "one device_get per sync"
  stays a property instead of a memory.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import (Finding, SourceModule, dotted_name,
                                 positional_params, qualname_of, unparse)

RULE = "trace-safety"

# dotted callable -> index/indices of function-valued arguments
_TRACE_ENTRY_ARGS: Dict[str, Tuple[int, ...]] = {
    "jax.jit": (0,), "jit": (0,), "jax.pjit": (0,), "pjit": (0,),
    "jax.vmap": (0,), "jax.pmap": (0,), "jax.grad": (0,),
    "jax.value_and_grad": (0,), "jax.checkpoint": (0,), "jax.remat": (0,),
    "jax.shard_map": (0,), "shard_map": (0,),
    "pl.pallas_call": (0,), "pallas_call": (0,),
    "jax.lax.fori_loop": (2,), "lax.fori_loop": (2,),
    "jax.lax.scan": (0,), "lax.scan": (0,),
    "jax.lax.while_loop": (0, 1), "lax.while_loop": (0, 1),
    "jax.lax.cond": (1, 2), "lax.cond": (1, 2),
    "jax.lax.switch": (1,), "lax.switch": (1,),
    "jax.lax.map": (0,), "lax.map": (0,),
}

_SYNC_CALLS = {"jax.device_get", "device_get",
               "jax.block_until_ready", "block_until_ready"}

_HOST_COERCIONS = {"item", "tolist", "numpy", "__array__"}

# attribute projections of a traced array that are static at trace time
_STATIC_ATTRS = {"shape", "dtype", "ndim", "size"}

# structure/introspection builtins whose results are static under trace
_STATIC_CALLS = {"len", "isinstance", "hasattr", "getattr", "type"}

# repo convention: config dataclasses are passed positionally under
# these names but are hashable statics (jit static_argnames / closed
# over), never traced values
_STATIC_PARAM_NAMES = {"cfg", "config", "ctx", "mesh"}

_FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _unwrap_partial(node: ast.AST) -> Optional[ast.AST]:
    """functools.partial(F, ...) -> F (else None)."""
    if isinstance(node, ast.Call):
        name = dotted_name(node.func)
        if name in ("functools.partial", "partial") and node.args:
            return node.args[0]
    return None


class _ModuleIndex:
    """Lexical-scope name resolution for function definitions."""

    def __init__(self, tree: ast.Module):
        self.parent: Dict[ast.AST, Optional[ast.AST]] = {tree: None}
        self.stack_of: Dict[ast.AST, List[ast.AST]] = {}
        # scope node -> {name: FunctionDef} for its immediate child defs
        self.local_defs: Dict[ast.AST, Dict[str, ast.AST]] = {}
        # class node -> {method name: FunctionDef}
        self.methods: Dict[ast.AST, Dict[str, ast.AST]] = {}
        self.functions: List[ast.AST] = []
        self._walk(tree, [])

    def _walk(self, node: ast.AST, stack: List[ast.AST]) -> None:
        scope = stack[-1] if stack else None
        for child in ast.iter_child_nodes(node):
            if isinstance(child, _FuncNode + (ast.ClassDef,)):
                self.parent[child] = scope
                sub = stack + [child]
                self.stack_of[child] = sub
                if not isinstance(child, ast.ClassDef):
                    self.functions.append(child)
                name = getattr(child, "name", None)
                if name is not None:
                    owner = scope
                    if isinstance(scope, ast.ClassDef):
                        self.methods.setdefault(scope, {})[name] = child
                        # class bodies are not lexical scopes: register
                        # the def one level further out too
                        owner = self.parent.get(scope)
                    key = owner if owner is not None else None
                    self.local_defs.setdefault(key, {})[name] = child
                self._walk(child, sub)
            else:
                self._walk(child, stack)

    def resolve(self, expr: ast.AST,
                stack: Sequence[ast.AST]) -> Optional[ast.AST]:
        """Resolve a callable expression to a FunctionDef/Lambda in this
        module, through functools.partial wrappers. Names that resolve
        to classes (constructor calls) yield None."""
        inner = _unwrap_partial(expr)
        if inner is not None:
            expr = inner
        if isinstance(expr, ast.Lambda):
            return expr
        fn = None
        if isinstance(expr, ast.Name):
            # innermost enclosing function scope outward, then module
            for scope in [s for s in reversed(list(stack))
                          if not isinstance(s, ast.ClassDef)] + [None]:
                fn = self.local_defs.get(scope, {}).get(expr.id)
                if fn is not None:
                    break
        elif (isinstance(expr, ast.Attribute)
                and isinstance(expr.value, ast.Name)
                and expr.value.id in ("self", "cls")):
            for scope in reversed(list(stack)):
                if isinstance(scope, ast.ClassDef):
                    fn = self.methods.get(scope, {}).get(expr.attr)
                    break
        return fn if isinstance(fn, _FuncNode) else None


def _entry_callees(call: ast.Call) -> List[ast.AST]:
    """Function-valued arguments of a trace-inducing call, else []."""
    name = dotted_name(call.func)
    if name is None:
        return []
    idxs = _TRACE_ENTRY_ARGS.get(name)
    if idxs is None:
        return []
    out: List[ast.AST] = []
    for i in idxs:
        if i < len(call.args):
            arg = call.args[i]
            if isinstance(arg, (ast.List, ast.Tuple)):  # lax.switch
                out.extend(arg.elts)
            else:
                out.append(arg)
    return out


def _decorated_entry(fn: ast.AST) -> Tuple[bool, Optional[ast.Call]]:
    """(is traced root?, decorator Call carrying static_arg* kwargs)."""
    for dec in getattr(fn, "decorator_list", []):
        name = dotted_name(dec)
        if name in _TRACE_ENTRY_ARGS:
            return True, None
        if isinstance(dec, ast.Call):
            dname = dotted_name(dec.func)
            if dname in _TRACE_ENTRY_ARGS:
                return True, dec
            # @partial(jax.jit, static_argnames=...)
            if dname in ("functools.partial", "partial") and dec.args:
                if dotted_name(dec.args[0]) in _TRACE_ENTRY_ARGS:
                    return True, dec
            # @pl.when(cond) decorating an inline kernel branch
            if dname in ("pl.when", "when"):
                return True, None
    return False, None


def _static_param_names(entry: Optional[ast.Call], fn: ast.AST) -> Set[str]:
    """Parameters declared static via `static_argnames`/`static_argnums`
    on the jit call or decorator that roots `fn`."""
    out: Set[str] = set()
    if entry is None:
        return out
    pos = positional_params(fn)
    for k in entry.keywords:
        if k.arg == "static_argnames":
            for c in ast.walk(k.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, str):
                    out.add(c.value)
        elif k.arg == "static_argnums":
            for c in ast.walk(k.value):
                if (isinstance(c, ast.Constant)
                        and isinstance(c.value, int)
                        and 0 <= c.value < len(pos)):
                    out.add(pos[c.value])
    return out


def _find_traced(index: _ModuleIndex,
                 tree: ast.Module) -> Dict[ast.AST, Tuple[bool, Set[str]]]:
    """Every function node that executes under a trace in this module,
    mapped to ``(seed_taint?, static param names)``.

    Taint is seeded only where argument provenance is certain: direct
    roots (passed to / decorated with a trace entry) take traced
    positional args, and defs nested inside a seeded function are loop
    bodies / closures over the same traced values. Transitive callees
    frequently take static shape ints, so they keep the TS001 sync
    check but get no seeds rather than guessed ones."""
    traced: Dict[ast.AST, Tuple[bool, Set[str]]] = {}
    pending: List[ast.AST] = []

    def add(fn: Optional[ast.AST], seeded: bool,
            static: Set[str] = frozenset()) -> None:
        if fn is None or not isinstance(fn, _FuncNode):
            return
        cur = traced.get(fn)
        if cur is None:
            traced[fn] = (seeded, set(static))
            pending.append(fn)
        elif seeded and not cur[0]:
            traced[fn] = (True, set(static) | cur[1])
            pending.append(fn)      # re-walk to upgrade nested defs

    # roots: decorated defs and callees of trace-inducing calls anywhere
    for fn in index.functions:
        is_root, entry = _decorated_entry(fn)
        if is_root:
            add(fn, True, _static_param_names(entry, fn))
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            stack = _enclosing_stack(index, node, tree)
            for callee in _entry_callees(node):
                fn = index.resolve(callee, stack)
                if fn is not None:
                    add(fn, True, _static_param_names(node, fn))

    # propagate through calls and lexical nesting
    while pending:
        fn = pending.pop()
        seeded = traced[fn][0]
        stack = index.stack_of.get(fn, [])
        for node in ast.walk(fn):
            if node is not fn and isinstance(node, _FuncNode):
                add(node, seeded)   # closures/loop bodies trace too
            if isinstance(node, ast.Call):
                add(index.resolve(node.func, stack), False)
    return traced


def _enclosing_stack(index: _ModuleIndex, node: ast.AST,
                     tree: ast.Module) -> List[ast.AST]:
    """Best-effort scope stack for an arbitrary node: nearest function
    whose source span contains the node."""
    line = getattr(node, "lineno", None)
    if line is None:
        return []
    best: List[ast.AST] = []
    for fn in index.functions:
        end = getattr(fn, "end_lineno", fn.lineno)
        if fn.lineno <= line <= end:
            stack = index.stack_of[fn]
            if len(stack) > len(best):
                best = list(stack)
    if not best:
        for cls, stack in index.stack_of.items():
            if isinstance(cls, ast.ClassDef):
                end = getattr(cls, "end_lineno", cls.lineno)
                if cls.lineno <= line <= end and len(stack) > len(best):
                    best = list(stack)
    return best


# --------------------------------------------------------------------------
# taint within one traced function
# --------------------------------------------------------------------------

def _literal_default(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) or (
        isinstance(node, ast.UnaryOp)
        and isinstance(node.operand, ast.Constant))


def _seed_taint(fn: ast.AST, static: Set[str] = frozenset()) -> Set[str]:
    """Positional params are traced values; `self`/`cls`, declared
    statics (static_argnames/nums), params with literal defaults, and
    the repo's static-config parameter names are not."""
    names = positional_params(fn)
    a = fn.args
    with_default = set()
    pos = list(getattr(a, "posonlyargs", [])) + list(a.args)
    for param, default in zip(reversed(pos), reversed(a.defaults)):
        if _literal_default(default):
            with_default.add(param.arg)
    return {n for n in names
            if n not in ("self", "cls")
            and n not in static
            and n not in with_default
            and n not in _STATIC_PARAM_NAMES}


def _names_in(expr: ast.AST) -> Set[str]:
    """Names referenced by `expr`, ignoring static `.shape`-style
    projections, static structure calls (`len`/`isinstance`/...), and
    nested function bodies."""
    out: Set[str] = set()

    def walk(node: ast.AST) -> None:
        if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
            return
        if (isinstance(node, ast.Call)
                and dotted_name(node.func) in _STATIC_CALLS):
            return
        if isinstance(node, _FuncNode):
            return
        if isinstance(node, ast.Name):
            out.add(node.id)
        for child in ast.iter_child_nodes(node):
            walk(child)

    walk(expr)
    return out


def _target_names(target: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(target):
        if isinstance(node, ast.Name):
            out.add(node.id)
    return out


def _propagate_taint(fn: ast.AST, tainted: Set[str]) -> Set[str]:
    """Forward may-taint over simple assignments, to a fixpoint."""
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for _ in range(10):
        changed = False
        for node in ast.walk(ast.Module(body=body, type_ignores=[])):
            value = targets = None
            if isinstance(node, ast.Assign):
                value, targets = node.value, node.targets
            elif isinstance(node, ast.AugAssign):
                value, targets = node.value, [node.target]
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                value, targets = node.value, [node.target]
            elif isinstance(node, ast.NamedExpr):
                value, targets = node.value, [node.target]
            elif isinstance(node, ast.For):
                value, targets = node.iter, [node.target]
            if value is None:
                continue
            if _names_in(value) & tainted:
                for t in targets:
                    new = _target_names(t) - tainted
                    if new:
                        tainted |= new
                        changed = True
        if not changed:
            break
    return tainted


def _is_identity_test(test: ast.AST) -> bool:
    """`x is None` / `x is not None` (and `and`/`or` of those) are
    static structure checks, not traced branching."""
    if isinstance(test, ast.BoolOp):
        return all(_is_identity_test(v) for v in test.values)
    return (isinstance(test, ast.Compare)
            and all(isinstance(op, (ast.Is, ast.IsNot))
                    for op in test.ops))


class TraceSafetyRule:
    name = RULE

    # TS005 scope: host code in the serving hot path
    AUDIT_PREFIXES = ("src/repro/serving/",)

    def check(self, module: SourceModule) -> Iterator[Optional[Finding]]:
        index = _ModuleIndex(module.tree)
        traced = _find_traced(index, module.tree)

        sync_in_traced: Set[int] = set()
        for fn, (seeded, static) in traced.items():
            context = qualname_of(index.stack_of.get(fn, [fn]))
            yield from self._check_traced_fn(module, fn, context,
                                             sync_in_traced, seeded,
                                             static)

        if module.rel_path.startswith(self.AUDIT_PREFIXES):
            yield from self._audit_host_syncs(module, index,
                                              sync_in_traced)

    def _check_traced_fn(self, module: SourceModule, fn: ast.AST,
                         context: str, sync_in_traced: Set[int],
                         seeded: bool, static: Set[str]
                         ) -> Iterator[Optional[Finding]]:
        seeds = _seed_taint(fn, static) if seeded else set()
        tainted = _propagate_taint(fn, seeds)
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, _FuncNode):
                    # nested defs are traced in their own right (they are
                    # members of `traced`), with their own taint seeds
                    continue
                if isinstance(node, ast.Call):
                    name = dotted_name(node.func)
                    if name in _SYNC_CALLS:
                        sync_in_traced.add(node.lineno)
                        yield module.finding(
                            RULE, "TS001", node, context,
                            f"`{name}` inside a jit/Pallas-traced region "
                            f"forces a host sync at trace time")
                    elif (isinstance(node.func, ast.Attribute)
                          and node.func.attr in _HOST_COERCIONS
                          and _names_in(node.func.value) & tainted):
                        yield module.finding(
                            RULE, "TS002", node, context,
                            f"`.{node.func.attr}()` on traced value "
                            f"`{unparse(node.func.value)}` materializes "
                            f"the tracer on host")
                    elif (name in ("float", "int", "bool") and node.args
                          and _names_in(node.args[0]) & tainted):
                        yield module.finding(
                            RULE, "TS002", node, context,
                            f"`{name}()` coercion of traced value "
                            f"`{unparse(node.args[0])}` inside a traced "
                            f"region")
                    elif (name is not None
                          and name.split(".")[0] in ("np", "numpy")
                          and any(_names_in(a) & tainted
                                  for a in node.args)):
                        yield module.finding(
                            RULE, "TS003", node, context,
                            f"`{name}` on a traced value runs NumPy on a "
                            f"tracer (host round-trip or trace error)")
                elif isinstance(node, (ast.If, ast.While)):
                    if (_names_in(node.test) & tainted
                            and not _is_identity_test(node.test)):
                        kw = ("while" if isinstance(node, ast.While)
                              else "if")
                        yield module.finding(
                            RULE, "TS004", node, context,
                            f"Python `{kw}` on traced value "
                            f"`{unparse(node.test)}` — use `lax.cond`/"
                            f"`jnp.where` (or bind it static)")

    def _audit_host_syncs(self, module: SourceModule, index: _ModuleIndex,
                          sync_in_traced: Set[int]
                          ) -> Iterator[Optional[Finding]]:
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            if node.lineno in sync_in_traced:
                continue                    # already a TS001
            name = dotted_name(node.func)
            if name in _SYNC_CALLS:
                stack = _enclosing_stack(index, node, module.tree)
                yield module.finding(
                    RULE, "TS005", node, qualname_of(stack),
                    f"deliberate host sync `{name}` in serving hot path — "
                    f"every site must be baselined with a justification "
                    f"(one device_get per sync discipline)")
