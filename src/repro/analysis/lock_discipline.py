"""lock-discipline: ``# guarded_by:`` annotations, actually checked.

The threaded serving modules (``large_backend``, ``remote/client``,
``remote/pool``, ``remote/server``, ``obs/metrics``) share mutable
state between an engine thread and worker/handler/scrape threads. The
convention::

    self._inflight: Dict[int, np.ndarray] = {}   # guarded_by: self._lock

declares that every read or write of ``self._inflight`` (in any method
of the class, or a subclass in the same module) must happen lexically
inside ``with self._lock:``. Methods that are *documented* to be
called with the lock already held annotate the ``def`` line instead::

    def _absorb_outq(self) -> None:   # guarded_by: self._lock

``__init__`` is exempt (the object is not shared yet). Lock-held state
does NOT propagate into nested ``def``/``lambda`` bodies — they run
later, on whatever thread calls them (this is exactly how unguarded
metric-scrape callbacks sneak in).

Checks
------
* LD001 — guarded attribute accessed outside a ``with <lock>:`` scope.
* LD002 — malformed annotation: a ``guarded_by`` comment on a line
  with no ``self.<attr>`` assignment (typo -> silently unchecked).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.core import Finding, SourceModule, unparse

RULE = "lock-discipline"

_FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef)


def _self_attr_targets(node: ast.AST) -> List[str]:
    """Attribute names of `self.X` assignment targets in `node`."""
    out: List[str] = []
    targets: List[ast.AST] = []
    if isinstance(node, ast.Assign):
        targets = node.targets
    elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
        targets = [node.target]
    for t in targets:
        for sub in ast.walk(t):
            if (isinstance(sub, ast.Attribute)
                    and isinstance(sub.value, ast.Name)
                    and sub.value.id == "self"):
                out.append(sub.attr)
    return out


class _ClassInfo:
    def __init__(self, node: ast.ClassDef):
        self.node = node
        self.guarded: Dict[str, str] = {}     # attr -> lock expr
        self.bases: List[str] = [b.id for b in node.bases
                                 if isinstance(b, ast.Name)]


def _collect_classes(module: SourceModule
                     ) -> Tuple[Dict[str, _ClassInfo], List[int]]:
    """Per-class guarded-attr maps (inheritance merged within the
    module) + lines carrying a guarded_by comment that bound nothing."""
    classes: Dict[str, _ClassInfo] = {}
    bound_lines: Set[int] = set()
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        info = _ClassInfo(node)
        for sub in ast.walk(node):
            line = getattr(sub, "lineno", None)
            if line in module.guarded_by:
                attrs = _self_attr_targets(sub)
                if attrs:
                    bound_lines.add(line)
                    for a in attrs:
                        info.guarded[a] = module.guarded_by[line]
            if isinstance(sub, _FuncNode) and sub.lineno in module.guarded_by:
                bound_lines.add(sub.lineno)   # def-line annotation
        classes[node.name] = info
    # merge annotations down the (same-module) inheritance chain
    for _ in range(len(classes)):
        changed = False
        for info in classes.values():
            for base in info.bases:
                parent = classes.get(base)
                if parent is None:
                    continue
                for attr, lock in parent.guarded.items():
                    if attr not in info.guarded:
                        info.guarded[attr] = lock
                        changed = True
        if not changed:
            break
    orphans = [line for line in module.guarded_by
               if line not in bound_lines]
    return classes, orphans


class LockDisciplineRule:
    name = RULE

    def check(self, module: SourceModule) -> Iterator[Optional[Finding]]:
        if not module.guarded_by:
            return
        classes, orphans = _collect_classes(module)
        for line in sorted(orphans):
            node = ast.parse("x", mode="eval").body  # placeholder w/ lineno
            node.lineno = line
            yield module.finding(
                RULE, "LD002", node, "",
                "guarded_by comment binds no `self.<attr>` assignment or "
                "`def` on this line — annotation is silently unchecked")
        for info in classes.values():
            if not info.guarded:
                continue
            for item in info.node.body:
                if isinstance(item, _FuncNode):
                    yield from self._check_method(module, info, item)

    def _check_method(self, module: SourceModule, info: _ClassInfo,
                      fn: ast.FunctionDef) -> Iterator[Optional[Finding]]:
        if fn.name == "__init__":
            return
        context = f"{info.node.name}.{fn.name}"
        held: Set[str] = set()
        if fn.lineno in module.guarded_by:
            held.add(module.guarded_by[fn.lineno])
        yield from self._visit(module, info, fn.body, held, context,
                               deferred=False)

    def _visit(self, module: SourceModule, info: _ClassInfo,
               body: List[ast.stmt], held: Set[str], context: str,
               deferred: bool) -> Iterator[Optional[Finding]]:
        for stmt in body:
            yield from self._visit_node(module, info, stmt, held, context,
                                        deferred)

    def _visit_node(self, module: SourceModule, info: _ClassInfo,
                    node: ast.AST, held: Set[str], context: str,
                    deferred: bool) -> Iterator[Optional[Finding]]:
        if isinstance(node, ast.With):
            newly = set()
            for item in node.items:
                expr = unparse(item.context_expr)
                if expr in info.guarded.values():
                    newly.add(expr)
            inner = held | newly
            for item in node.items:
                yield from self._visit_node(module, info, item.context_expr,
                                            held, context, deferred)
            for stmt in node.body:
                yield from self._visit_node(module, info, stmt, inner,
                                            context, deferred)
            return
        if isinstance(node, _FuncNode + (ast.Lambda,)):
            # deferred execution: the lock is NOT held when this runs
            inner_held: Set[str] = set()
            if (isinstance(node, _FuncNode)
                    and node.lineno in module.guarded_by):
                inner_held.add(module.guarded_by[node.lineno])
            name = getattr(node, "name", "<lambda>")
            inner_body = (node.body if isinstance(node.body, list)
                          else [node.body])
            for stmt in inner_body:
                yield from self._visit_node(module, info, stmt, inner_held,
                                            f"{context}.{name}",
                                            deferred=True)
            return
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
                and node.attr in info.guarded):
            lock = info.guarded[node.attr]
            if lock not in held:
                where = ("in deferred callback, "
                         if deferred else "")
                yield module.finding(
                    RULE, "LD001", node, context,
                    f"`self.{node.attr}` is guarded_by `{lock}` but "
                    f"accessed {where}outside a `with {lock}:` scope")
            return  # don't descend into self.<attr> again
        for child in ast.iter_child_nodes(node):
            yield from self._visit_node(module, info, child, held, context,
                                        deferred)
