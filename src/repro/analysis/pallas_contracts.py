"""pallas-contracts: structural checks at every ``pl.pallas_call`` site.

A Pallas kernel's contract with its call site is positional and
silent: the kernel signature must line up with
``num_scalar_prefetch + in_specs + outputs + scratch_shapes`` in that
exact order, every BlockSpec index map takes one parameter per grid
axis (plus one ref per scalar-prefetch operand), and
``input_output_aliases`` indexes raw call operands. Getting any of
these wrong is a shape error deep inside Mosaic at best and silent
garbage at worst — and interpret-mode CPU tests exercise exactly one
(grid, spec) instantiation, so arity rot hides until a TPU run.

Checks (sites whose structure can't be resolved statically — e.g. a
grid built by a helper — are skipped, not guessed):

* PL001 — kernel positional-parameter count !=
  ``num_scalar_prefetch + len(in_specs) + n_outputs +
  len(scratch_shapes)`` (``functools.partial``-bound statics are
  expected keyword-only and don't count).
* PL002 — a BlockSpec index-map lambda whose arity is not
  ``len(grid) + num_scalar_prefetch``.
* PL003 — ``input_output_aliases`` key outside the operand range or
  value outside the output range.
* PL004 — online-softmax scratch (``pltpu.VMEM``) that is not fp32, in
  ``kernels/paged_attention.py`` / ``kernels/flash_attention.py``:
  accumulating ``(m, l, acc)`` in the input dtype loses the flash
  recurrence's stability guarantee (bf16 accumulation diverges from
  the dense oracle past ~1k tokens).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterator, List, Optional

from repro.analysis.core import (Finding, SourceModule, dotted_name,
                                 positional_params, qualname_of, unparse)
from repro.analysis.trace_safety import (_enclosing_stack, _ModuleIndex,
                                         _unwrap_partial)

RULE = "pallas-contracts"

# modules whose VMEM scratch carries flash-attention online-softmax
# state and therefore must be fp32
_FP32_SCRATCH_MODULES = ("kernels/paged_attention.py",
                         "kernels/flash_attention.py")


@dataclasses.dataclass
class _SiteSpec:
    """Statically-resolved structure of one pallas_call site; None
    fields mean "could not resolve — skip dependent checks"."""

    num_prefetch: int = 0
    grid_rank: Optional[int] = None
    in_specs: Optional[List[ast.AST]] = None
    out_specs: Optional[List[ast.AST]] = None
    scratch_shapes: Optional[List[ast.AST]] = None
    n_out: Optional[int] = None
    aliases: Optional[ast.Dict] = None


def _as_elements(node: Optional[ast.AST]) -> Optional[List[ast.AST]]:
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return None


def _resolve_name(index: _ModuleIndex, tree: ast.Module, at: ast.AST,
                  expr: ast.AST) -> ast.AST:
    """Follow a Name back to its latest single-target assignment in the
    enclosing function (textually before `at`)."""
    if not isinstance(expr, ast.Name):
        return expr
    stack = _enclosing_stack(index, at, tree)
    scopes = [s for s in reversed(stack)
              if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))]
    scopes.append(tree)
    for scope in scopes:
        best = None
        for node in ast.walk(scope):
            if (isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id == expr.id
                    and node.lineno < at.lineno):
                if best is None or node.lineno > best.lineno:
                    best = node
        if best is not None:
            return best.value
    return expr


def _n_outputs(out_shape: Optional[ast.AST]) -> Optional[int]:
    if out_shape is None:
        return None
    if isinstance(out_shape, (ast.Tuple, ast.List)):
        return len(out_shape.elts)
    if isinstance(out_shape, ast.Call):
        name = dotted_name(out_shape.func) or ""
        if name.endswith("ShapeDtypeStruct"):
            return 1
    return None


def _index_map_of(spec: ast.AST) -> Optional[ast.Lambda]:
    """The index-map lambda of a `pl.BlockSpec(shape, lambda...)` node."""
    if not isinstance(spec, ast.Call):
        return None
    name = dotted_name(spec.func) or ""
    if not name.endswith("BlockSpec"):
        return None
    candidates = list(spec.args[1:]) + [kw.value for kw in spec.keywords
                                        if kw.arg == "index_map"]
    for c in candidates:
        if isinstance(c, ast.Lambda):
            return c
    return None


class PallasContractsRule:
    name = RULE

    def check(self, module: SourceModule) -> Iterator[Optional[Finding]]:
        index = _ModuleIndex(module.tree)
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name not in ("pl.pallas_call", "pallas_call"):
                continue
            stack = _enclosing_stack(index, node, module.tree)
            context = qualname_of(stack)
            yield from self._check_site(module, index, node, context)

    def _check_site(self, module: SourceModule, index: _ModuleIndex,
                    call: ast.Call, context: str
                    ) -> Iterator[Optional[Finding]]:
        tree = module.tree
        kw = {k.arg: k.value for k in call.keywords if k.arg}
        spec = _SiteSpec()

        grid_spec = kw.get("grid_spec")
        if grid_spec is not None:
            gs = _resolve_name(index, tree, call, grid_spec)
            if isinstance(gs, ast.Call):
                gkw = {k.arg: k.value for k in gs.keywords if k.arg}
                npf = gkw.get("num_scalar_prefetch")
                if isinstance(npf, ast.Constant) and isinstance(npf.value,
                                                                int):
                    spec.num_prefetch = npf.value
                self._fill_shape(spec, gkw, index, tree, call)
        else:
            self._fill_shape(spec, kw, index, tree, call)
        spec.n_out = _n_outputs(
            _resolve_name(index, tree, call, kw["out_shape"])
            if "out_shape" in kw else None)
        aliases = kw.get("input_output_aliases")
        if isinstance(aliases, ast.Dict):
            spec.aliases = aliases

        # PL001: kernel arity vs site structure
        kernel = call.args[0] if call.args else None
        fn = index.resolve(kernel, _enclosing_stack(index, call, tree)) \
            if kernel is not None else None
        if (fn is not None and spec.in_specs is not None
                and spec.n_out is not None):
            n_scratch = len(spec.scratch_shapes or [])
            expected = (spec.num_prefetch + len(spec.in_specs)
                        + spec.n_out + n_scratch)
            inner = _unwrap_partial(kernel)
            bound = len(kernel.args) - 1 if inner is not None else 0
            got = len(positional_params(fn)) - bound
            if got != expected:
                yield module.finding(
                    RULE, "PL001", call, context,
                    f"kernel `{getattr(fn, 'name', '<lambda>')}` takes "
                    f"{got} positional refs but the call site supplies "
                    f"{expected} ({spec.num_prefetch} prefetch + "
                    f"{len(spec.in_specs)} in + {spec.n_out} out + "
                    f"{n_scratch} scratch)")

        # PL002: index-map lambda arity
        if spec.grid_rank is not None:
            want = spec.grid_rank + spec.num_prefetch
            for s in (spec.in_specs or []) + (spec.out_specs or []):
                lam = _index_map_of(s)
                if lam is None:
                    continue
                got = len(positional_params(lam))
                if got != want:
                    yield module.finding(
                        RULE, "PL002", lam, context,
                        f"BlockSpec index map takes {got} params, expected "
                        f"{want} (grid rank {spec.grid_rank} + "
                        f"{spec.num_prefetch} scalar-prefetch refs)")

        # PL003: input_output_aliases ranges
        if spec.aliases is not None and spec.in_specs is not None:
            n_operands = spec.num_prefetch + len(spec.in_specs)
            for k, v in zip(spec.aliases.keys, spec.aliases.values):
                if (isinstance(k, ast.Constant) and isinstance(k.value, int)
                        and not 0 <= k.value < n_operands):
                    yield module.finding(
                        RULE, "PL003", k, context,
                        f"input_output_aliases key {k.value} out of range "
                        f"for {n_operands} call operands "
                        f"(prefetch + inputs, 0-based)")
                if (isinstance(v, ast.Constant) and isinstance(v.value, int)
                        and spec.n_out is not None
                        and not 0 <= v.value < spec.n_out):
                    yield module.finding(
                        RULE, "PL003", v, context,
                        f"input_output_aliases value {v.value} out of "
                        f"range for {spec.n_out} output(s)")

        # PL004: fp32 online-softmax scratch
        if (module.rel_path.endswith(_FP32_SCRATCH_MODULES)
                and spec.scratch_shapes is not None):
            for s in spec.scratch_shapes:
                if not (isinstance(s, ast.Call)
                        and (dotted_name(s.func) or "").endswith("VMEM")):
                    continue
                dtype = (s.args[1] if len(s.args) > 1 else None)
                for k in s.keywords:
                    if k.arg == "dtype":
                        dtype = k.value
                if dtype is not None and not unparse(dtype).endswith(
                        "float32"):
                    yield module.finding(
                        RULE, "PL004", s, context,
                        f"online-softmax scratch must be fp32, got "
                        f"`{unparse(dtype)}` — low-precision (m, l, acc) "
                        f"accumulation breaks dense-oracle parity")

    @staticmethod
    def _fill_shape(spec: _SiteSpec, kw, index, tree, call) -> None:
        grid = kw.get("grid")
        if grid is not None:
            g = _resolve_name(index, tree, call, grid)
            if isinstance(g, (ast.Tuple, ast.List)):
                spec.grid_rank = len(g.elts)
            elif isinstance(g, ast.Constant) and isinstance(g.value, int):
                spec.grid_rank = 1
        for field, name in (("in_specs", "in_specs"),
                            ("scratch_shapes", "scratch_shapes")):
            v = kw.get(name)
            if v is not None:
                setattr(spec, field,
                        _as_elements(_resolve_name(index, tree, call, v)))
        outs = kw.get("out_specs")
        if outs is not None:
            outs = _resolve_name(index, tree, call, outs)
            spec.out_specs = _as_elements(outs) or [outs]
