"""Repo-specific static analysis: machine-check the invariants the
serving stack established by hand.

Off-the-shelf linters see syntax; this pass sees the repo's contracts:

  * ``trace-safety``    — host-sync hazards inside jit/Pallas-traced
    regions, and the engine's "one deliberate ``device_get`` per sync"
    discipline (every host-sync call site in ``serving/`` must be
    baselined with a justification).
  * ``lock-discipline`` — ``# guarded_by: self._lock`` attribute
    annotations, checked against actual ``with self._lock:`` scopes in
    the threaded modules.
  * ``determinism``     — ``time.time``/``random``/builtin ``hash()``
    banned from code that decides dispatch order, victim selection, or
    wire encoding (the crc32-instead-of-``hash()`` class of bug).
  * ``pallas-contracts`` — at each ``pallas_call`` site: kernel arity
    vs grid/BlockSpec structure, index-map lambda arity,
    ``input_output_aliases`` index validity, fp32 online-softmax
    scratch.

Run it the way CI does::

    PYTHONPATH=src python -m repro.analysis --paths src tests benchmarks

Findings are suppressed per line with ``# repro: ignore[rule-or-code]``
or grandfathered in ``analysis_baseline.json`` (see docs/analysis.md
for the ratchet workflow). The framework is stdlib-only (``ast`` +
``json``) so the CI job needs no dependencies.
"""
from repro.analysis.core import (AnalysisReport, Baseline, Finding,
                                 SourceModule, collect_files, load_baseline,
                                 run_analysis)
from repro.analysis.registry import ALL_RULES, get_rules

__all__ = [
    "ALL_RULES",
    "AnalysisReport",
    "Baseline",
    "Finding",
    "SourceModule",
    "collect_files",
    "get_rules",
    "load_baseline",
    "run_analysis",
]
