"""determinism: ban ambient nondeterminism where ordering is a contract.

Three hand-written fixes established that dispatch order, victim
selection, and wire encoding must be pure functions of request state:

  * PR 1 replaced builtin ``hash()`` (salted per process via
    ``PYTHONHASHSEED``) with ``zlib.crc32`` for parameter-init path
    hashing — two processes now build bit-identical params.
  * PR 8's semantic-agreement signal derives its sampling keys from
    ``crc32(prompt)``, so the score is replay-stable.
  * PR 9's pressure policies pick the *deterministic* youngest victim
    (max admit_seq, tie max rid), never "whatever iteration order".

This rule makes that a property of the listed modules rather than a
review habit: inside determinism-critical modules (the default scope
below, plus any file carrying a ``# repro: deterministic-module``
pragma), flag

* DM001 — builtin ``hash()`` (process-salted for str/bytes; use
  ``zlib.crc32`` / ``hashlib``).
* DM002 — ambient RNG: ``random.*``, legacy global ``np.random.*``
  (seedless ``default_rng()`` included), ``os.urandom``, ``uuid.*``,
  ``secrets.*``. Seeded ``np.random.default_rng(seed)`` and the
  functional ``jax.random.*`` API are fine.
* DM003 — wall-clock reads: ``time.time`` / ``time.time_ns`` /
  ``datetime.*now`` / ``utcnow``. Use the caller-supplied timestamp or
  ``time.perf_counter`` (monotonic, never encoded on the wire).
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional, Tuple

from repro.analysis.core import (Finding, SourceModule, dotted_name,
                                 iter_scopes, qualname_of)

RULE = "determinism"

# path suffixes of modules that decide dispatch order, victim
# selection, or wire encoding — the determinism-critical set
DEFAULT_SCOPE: Tuple[str, ...] = (
    "src/repro/serving/scheduler.py",
    "src/repro/serving/pressure.py",
    "src/repro/serving/paged_pool.py",
    "src/repro/serving/cache_pool.py",
    "src/repro/serving/remote/wire.py",
    "src/repro/core/deferral.py",
    "src/repro/core/cascade_spec.py",
    "src/repro/models/common.py",
)

_WALLCLOCK = {"time.time", "time.time_ns", "time.monotonic_ns",
              "datetime.now", "datetime.datetime.now",
              "datetime.utcnow", "datetime.datetime.utcnow"}

_AMBIENT_RNG_PREFIXES = ("random.", "np.random.", "numpy.random.",
                         "uuid.", "secrets.")
_AMBIENT_RNG_EXACT = {"os.urandom"}


def _in_scope(module: SourceModule) -> bool:
    if module.deterministic_pragma:
        return True
    path = module.rel_path
    return any(path.endswith(suffix) for suffix in DEFAULT_SCOPE)


class DeterminismRule:
    name = RULE

    def check(self, module: SourceModule) -> Iterator[Optional[Finding]]:
        if not _in_scope(module):
            return
        # context lookup: function spans -> qualname
        spans = []
        for node, stack in iter_scopes(module.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                spans.append((node.lineno,
                              getattr(node, "end_lineno", node.lineno),
                              qualname_of(stack)))

        def context_of(line: int) -> str:
            best = ""
            best_span = None
            for lo, hi, name in spans:
                if lo <= line <= hi and (best_span is None
                                         or hi - lo < best_span):
                    best, best_span = name, hi - lo
            return best

        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name is None:
                continue
            ctx = context_of(node.lineno)
            if name == "hash":
                yield module.finding(
                    RULE, "DM001", node, ctx,
                    "builtin hash() is salted per process — use "
                    "zlib.crc32/hashlib for cross-process-stable keys")
            elif name in _AMBIENT_RNG_EXACT or (
                    name.startswith(_AMBIENT_RNG_PREFIXES)
                    and not self._seeded_rng(name, node)):
                yield module.finding(
                    RULE, "DM002", node, ctx,
                    f"ambient RNG `{name}` in determinism-critical module "
                    f"— derive randomness from request state (crc32) or "
                    f"a seeded generator")
            elif name in _WALLCLOCK:
                yield module.finding(
                    RULE, "DM003", node, ctx,
                    f"wall-clock `{name}` must not influence dispatch "
                    f"order or wire encoding — take the timestamp as an "
                    f"argument or use time.perf_counter")

    @staticmethod
    def _seeded_rng(name: str, node: ast.Call) -> bool:
        """`np.random.default_rng(seed)` with an explicit seed is fine;
        seedless `default_rng()` draws OS entropy."""
        return (name.endswith(".default_rng")
                and bool(node.args or node.keywords))
