"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These mirror repro.core math but are kept dependency-free so kernel tests
compare against a single obvious implementation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def gatekeeper_loss_ref(x: jnp.ndarray, table: jnp.ndarray,
                        targets: jnp.ndarray, alpha: float,
                        valid: jnp.ndarray):
    """Per-token Gatekeeper terms from final hidden states.

    x [T, d], table [V, d], targets [T], valid [T] in {0,1}.
    Returns dict with per-token ce, kl, correct, and the scalar loss
    (normalized by sum(valid), paper eqs. 1-5).
    """
    logits = jnp.einsum("td,vd->tv", x, table).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    V = table.shape[0]
    ce = -jnp.take_along_axis(logp, targets[:, None], axis=-1)[:, 0]
    ent = -(jnp.exp(logp) * logp).sum(-1)
    kl = jnp.log(float(V)) - ent
    correct = (logits.argmax(-1) == targets).astype(jnp.float32)
    v = valid.astype(jnp.float32)
    denom = jnp.maximum(v.sum(), 1.0)
    l_corr = (ce * correct * v).sum() / denom
    l_incorr = (kl * (1 - correct) * v).sum() / denom
    loss = alpha * l_corr + (1 - alpha) * l_incorr
    return {"ce": ce, "kl": kl, "correct": correct, "entropy": ent,
            "loss": loss, "l_corr": l_corr, "l_incorr": l_incorr}


def deferral_entropy_ref(logits: jnp.ndarray):
    """(neg_entropy [T], max_prob [T], argmax [T]) from logits [T, V]."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    p = jnp.exp(logp)
    neg_ent = (p * logp).sum(-1)
    return neg_ent, p.max(-1), jnp.argmax(logits, -1).astype(jnp.int32)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """Plain softmax attention. q [B,T,H,hd]; k,v [B,S,KV,hd] (GQA)."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    scale = scale or 1.0 / np.sqrt(hd)
    g = H // KV
    qg = q.reshape(B, T, KV, g, hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, T, H, hd)
