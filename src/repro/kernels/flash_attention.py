"""Flash (block-wise online-softmax) attention Pallas kernel (TPU target).

Prefill hot spot of the cascade's small model: causal (optionally
sliding-window) GQA attention with (128, 128) q/kv tiles, fp32 online
softmax accumulators in VMEM, never materializing [T, S] scores in HBM.

Grid: (batch, heads, q_blocks, kv_blocks) — kv innermost; the kv loop
carries (m, l, acc) scratch; the final kv step normalizes and writes the
output tile. GQA: kv head index = q head // group.

TPU adaptation vs CUDA flash-attention: tile sizes follow MXU 128-lane
alignment; block-level causal skipping is expressed via masking here (a
production grid would prune fully-masked kv blocks with a custom index
map — measured in EXPERIMENTS.md §Perf as a compute-term lever).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, n_kb: int, qb: int, kb: int, causal: bool, window: int,
            scale: float, seq_q: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)          # [qb, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # [kb, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
    kpos = ki * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
    mask = (qpos < seq_q) & (kpos < seq_k)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG)

    bm = s.max(axis=1)
    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, bm)
    alpha = jnp.exp(m_old - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kb - 1)
    def _final():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    scale: float | None = None,
                    qb: int = 128, kb: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q [B,T,H,hd]; k,v [B,S,KV,hd] (H % KV == 0). Returns [B,T,H,hd]."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    assert H % KV == 0
    group = H // KV
    scale = scale or 1.0 / np.sqrt(hd)
    qb = min(qb, T)
    kb = min(kb, S)
    n_qb = (T + qb - 1) // qb
    n_kb = (S + kb - 1) // kb
    Tp, Sp = n_qb * qb, n_kb * kb
    if Tp != T:
        q = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))

    kernel = functools.partial(_kernel, n_kb=n_kb, qb=qb, kb=kb,
                               causal=causal, window=window, scale=scale,
                               seq_q=T, seq_k=S)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, qb, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, kb, 1, hd),
                         lambda b, h, qi, ki: (b, ki, h // group, 0)),
            pl.BlockSpec((1, kb, 1, hd),
                         lambda b, h, qi, ki: (b, ki, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, 1, hd),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Tp, H, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((qb,), jnp.float32),
                        pltpu.VMEM((qb,), jnp.float32),
                        pltpu.VMEM((qb, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
    return out[:, :T]
