"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU set
REPRO_PALLAS_INTERPRET=0 (or pass interpret=False) for compiled Mosaic.
"""
from __future__ import annotations

import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import deferral_entropy as _de
from repro.kernels import flash_attention as _fa
from repro.kernels import gatekeeper_loss as _gk


def _default_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"


def _pad_tokens(x, tb):
    T = x.shape[0]
    Tp = ((T + tb - 1) // tb) * tb
    if Tp == T:
        return x, T
    pad = [(0, Tp - T)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad), T


@partial(jax.jit, static_argnames=("alpha", "interpret", "tb", "vb", "db"))
def gatekeeper_loss_fused(x, table, targets, valid=None, *, alpha: float = 0.5,
                          interpret: Optional[bool] = None,
                          tb: int = 128, vb: int = 512, db: int = 512):
    """Scalar Gatekeeper loss + per-token aux, via the fused Pallas kernel.

    x [T, d] final hidden states; table [V, d]; targets [T]."""
    interpret = _default_interpret() if interpret is None else interpret
    xp, T = _pad_tokens(x, tb)
    tp, _ = _pad_tokens(targets, tb)
    ce, kl, corr, ent = _gk.gatekeeper_loss_tokens(
        xp, table, tp, tb=tb, vb=vb, db=db, interpret=interpret)
    ce, kl, corr, ent = (a[:T] for a in (ce, kl, corr, ent))
    v = jnp.ones((T,), jnp.float32) if valid is None else valid.astype(jnp.float32)
    denom = jnp.maximum(v.sum(), 1.0)
    l_corr = (ce * corr * v).sum() / denom
    l_incorr = (kl * (1 - corr) * v).sum() / denom
    loss = alpha * l_corr + (1 - alpha) * l_incorr
    return loss, {"ce": ce, "kl": kl, "correct": corr, "entropy": ent,
                  "l_corr": l_corr, "l_incorr": l_incorr}


@partial(jax.jit, static_argnames=("interpret", "tb", "vb"))
def deferral_signal(logits, *, interpret: Optional[bool] = None,
                    tb: int = 128, vb: int = 2048):
    """(neg_entropy, max_prob, argmax) per row of logits [T, V] (eqs. 7-8)."""
    interpret = _default_interpret() if interpret is None else interpret
    lp, T = _pad_tokens(logits, tb)
    nent, mprob, amax = _de.deferral_entropy(lp, tb=tb, vb=vb,
                                             interpret=interpret)
    return nent[:T], mprob[:T], amax[:T]


@partial(jax.jit, static_argnames=("causal", "window", "interpret", "qb", "kb"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    interpret: Optional[bool] = None,
                    qb: int = 128, kb: int = 128):
    """Block-wise online-softmax GQA attention (see flash_attention.py)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               qb=qb, kb=kb, interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(q, k, v, logw, u, state0, *, chunk: int = 64,
        interpret: Optional[bool] = None):
    """RWKV6 chunked recurrence (see wkv_scan.py). The [K,V] state stays
    in VMEM across chunk steps; oracle: models/ssm.linear_attention_scan
    (mode="rwkv")."""
    from repro.kernels import wkv_scan as _wkv
    interpret = _default_interpret() if interpret is None else interpret
    return _wkv.wkv_scan(q, k, v, logw, u, state0, chunk=chunk,
                         interpret=interpret)
