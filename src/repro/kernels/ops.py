"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU set
REPRO_PALLAS_INTERPRET=0 (or pass interpret=False) for compiled Mosaic.
"""
from __future__ import annotations

import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import deferral_entropy as _de
from repro.kernels import flash_attention as _fa
from repro.kernels import gatekeeper_loss as _gk
from repro.kernels import paged_attention as _pa


def _default_interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"


def paged_kernel_enabled(override: Optional[bool] = None) -> bool:
    """Should the paged decode paths use the Pallas kernels?

    Resolution order: explicit `override` (engine config / function arg)
    > REPRO_PAGED_KERNEL env var > backend default (on for TPU, off for
    CPU — interpret-mode kernels are Python-speed, so the XLA gather
    fallback stays the CPU default; set REPRO_PAGED_KERNEL=1 to force
    the kernel path, e.g. for interpret-mode parity runs)."""
    if override is not None:
        return bool(override)
    env = os.environ.get("REPRO_PAGED_KERNEL")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "tpu"


def _pad_tokens(x, tb):
    T = x.shape[0]
    Tp = ((T + tb - 1) // tb) * tb
    if Tp == T:
        return x, T
    pad = [(0, Tp - T)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad), T


@partial(jax.jit, static_argnames=("alpha", "interpret", "tb", "vb", "db"))
def gatekeeper_loss_fused(x, table, targets, valid=None, *, alpha: float = 0.5,
                          interpret: Optional[bool] = None,
                          tb: int = 128, vb: int = 512, db: int = 512):
    """Scalar Gatekeeper loss + per-token aux, via the fused Pallas kernel.

    x [T, d] final hidden states; table [V, d]; targets [T]."""
    interpret = _default_interpret() if interpret is None else interpret
    xp, T = _pad_tokens(x, tb)
    tp, _ = _pad_tokens(targets, tb)
    ce, kl, corr, ent = _gk.gatekeeper_loss_tokens(
        xp, table, tp, tb=tb, vb=vb, db=db, interpret=interpret)
    ce, kl, corr, ent = (a[:T] for a in (ce, kl, corr, ent))
    v = jnp.ones((T,), jnp.float32) if valid is None else valid.astype(jnp.float32)
    denom = jnp.maximum(v.sum(), 1.0)
    l_corr = (ce * corr * v).sum() / denom
    l_incorr = (kl * (1 - corr) * v).sum() / denom
    loss = alpha * l_corr + (1 - alpha) * l_incorr
    return loss, {"ce": ce, "kl": kl, "correct": corr, "entropy": ent,
                  "l_corr": l_corr, "l_incorr": l_incorr}


@partial(jax.jit, static_argnames=("interpret", "tb", "vb"))
def deferral_signal(logits, *, interpret: Optional[bool] = None,
                    tb: int = 128, vb: int = 2048):
    """(neg_entropy, max_prob, argmax) per row of logits [T, V] (eqs. 7-8)."""
    interpret = _default_interpret() if interpret is None else interpret
    lp, T = _pad_tokens(logits, tb)
    nent, mprob, amax = _de.deferral_entropy(lp, tb=tb, vb=vb,
                                             interpret=interpret)
    return nent[:T], mprob[:T], amax[:T]


@partial(jax.jit, static_argnames=("causal", "window", "interpret", "qb", "kb"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    interpret: Optional[bool] = None,
                    qb: int = 128, kb: int = 128):
    """Block-wise online-softmax GQA attention (see flash_attention.py)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               qb=qb, kb=kb, interpret=interpret)


@partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_flash_decode_gqa(q, k_pages, v_pages, tables, positions, *,
                           scale: Optional[float] = None,
                           interpret: Optional[bool] = None):
    """One-token GQA attention directly against the block-paged cache
    (see paged_attention.py). q [B,1,H,hd]; k/v_pages [N, bs, KV, hd];
    tables [B, M]; positions [B]. No dense gather is materialized."""
    interpret = _default_interpret() if interpret is None else interpret
    return _pa.paged_flash_decode_gqa(q, k_pages, v_pages, tables,
                                      positions, scale=scale,
                                      interpret=interpret)


@partial(jax.jit, static_argnames=("scale", "eps", "interpret"))
def paged_flash_decode_mla(q_abs, q_rope, ckv_pages, kr_pages, kv_norm,
                           tables, positions, *, scale: float,
                           eps: float = 1e-6,
                           interpret: Optional[bool] = None):
    """Weight-absorbed MLA decode against the paged compressed cache;
    returns the latent context [B,1,H,kv_lora] (caller applies W_uv/W_o)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _pa.paged_flash_decode_mla(q_abs, q_rope, ckv_pages, kr_pages,
                                      kv_norm, tables, positions,
                                      scale=scale, eps=eps,
                                      interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def paged_write_token(leaf, tables, positions, values, *,
                      interpret: Optional[bool] = None):
    """Single-token paged scatter through the page table (in-kernel
    replacement for the XLA `_paged_write` on the decode hot path)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _pa.paged_write_token(leaf, tables, positions, values,
                                 interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(q, k, v, logw, u, state0, *, chunk: int = 64,
        interpret: Optional[bool] = None):
    """RWKV6 chunked recurrence (see wkv_scan.py). The [K,V] state stays
    in VMEM across chunk steps; oracle: models/ssm.linear_attention_scan
    (mode="rwkv")."""
    from repro.kernels import wkv_scan as _wkv
    interpret = _default_interpret() if interpret is None else interpret
    return _wkv.wkv_scan(q, k, v, logw, u, state0, chunk=chunk,
                         interpret=interpret)
