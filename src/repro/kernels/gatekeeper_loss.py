"""Fused Gatekeeper loss Pallas kernel (TPU target).

Computes, in ONE pass over the vocabulary and fused with the unembedding
matmul, the per-token quantities of eqs. (2)-(5):

    ce_t   = logsumexp(l_t) - l_t[target]
    kl_t   = log V - H(p_t)
    corr_t = argmax(l_t) == target

without materializing [T, V] logits in HBM. Entropy is accumulated online:
with running max m, s = Σ e^{l-m}, w = Σ e^{l-m}·l we have
H = (m + log s) - w/s — so one streaming pass suffices (the two-pass XLA
fallback lives in repro/launch/steps.py).

Grid: (token_blocks, vocab_blocks, d_blocks); d innermost accumulates the
logits tile on the MXU; the vocab step folds the finished tile into the
online accumulators; the last vocab step writes per-token outputs.

Block shapes are 128-lane aligned for the MXU/VPU; VMEM footprint
(TB=128, VB=512, DB=512, fp32):
  x 256 KiB + table 1 MiB + logits scratch 256 KiB + row stats ~3 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(x_ref, tbl_ref, tgt_ref, ce_ref, kl_ref, corr_ref, ent_ref,
            logits_ref, m_ref, s_ref, w_ref, amax_ref, aidx_ref, tl_ref,
            *, n_vb: int, n_db: int, vb_size: int, vocab: int):
    vb = pl.program_id(1)
    db = pl.program_id(2)

    # ---- accumulate logits tile over d blocks (MXU) ----
    @pl.when(db == 0)
    def _():
        logits_ref[...] = jnp.zeros_like(logits_ref)
    logits_ref[...] += jnp.dot(x_ref[...], tbl_ref[...].T,
                               preferred_element_type=jnp.float32)

    @pl.when(db == n_db - 1)
    def _fold():
        # ---- online row update with the finished [TB, VB] tile ----
        @pl.when(vb == 0)
        def _():
            m_ref[...] = jnp.full_like(m_ref, NEG)
            s_ref[...] = jnp.zeros_like(s_ref)
            w_ref[...] = jnp.zeros_like(w_ref)
            amax_ref[...] = jnp.full_like(amax_ref, NEG)
            aidx_ref[...] = jnp.zeros_like(aidx_ref)
            tl_ref[...] = jnp.zeros_like(tl_ref)

        logits = logits_ref[...]                     # [TB, VB] fp32
        col = vb * vb_size + jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 1)
        valid_col = col < vocab                      # tail padding guard
        logits = jnp.where(valid_col, logits, NEG)

        bm = logits.max(axis=1)                      # block max
        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, bm)
        scale = jnp.exp(m_old - m_new)
        p = jnp.exp(logits - m_new[:, None])
        p = jnp.where(valid_col, p, 0.0)
        s_ref[...] = s_ref[...] * scale + p.sum(axis=1)
        w_ref[...] = w_ref[...] * scale + (p * logits).sum(axis=1)
        m_ref[...] = m_new

        bidx = jnp.argmax(logits, axis=1).astype(jnp.int32)
        better = bm > amax_ref[...]
        amax_ref[...] = jnp.where(better, bm, amax_ref[...])
        aidx_ref[...] = jnp.where(better, bidx + vb * vb_size, aidx_ref[...])

        tgt = tgt_ref[...]
        loc = tgt - vb * vb_size
        in_blk = (loc >= 0) & (loc < vb_size)
        sel = (col == (vb * vb_size + jnp.clip(loc, 0, vb_size - 1))[:, None])
        got = jnp.where(sel, logits, 0.0).sum(axis=1)
        tl_ref[...] = jnp.where(in_blk, got, tl_ref[...])

        @pl.when(vb == n_vb - 1)
        def _final():
            lse = m_ref[...] + jnp.log(s_ref[...])
            ent = lse - w_ref[...] / s_ref[...]
            ce_ref[...] = lse - tl_ref[...]
            kl_ref[...] = np.log(float(vocab)) - ent
            ent_ref[...] = ent
            corr_ref[...] = (aidx_ref[...] == tgt_ref[...]).astype(jnp.float32)


def gatekeeper_loss_tokens(x: jnp.ndarray, table: jnp.ndarray,
                           targets: jnp.ndarray, *,
                           tb: int = 128, vb: int = 512, db: int = 512,
                           interpret: bool = False):
    """Per-token (ce, kl, correct, entropy) from hidden states.

    x [T, d] (T padded to tb), table [V, d], targets [T] int32.
    """
    T, d = x.shape
    V = table.shape[0]
    assert T % tb == 0, (T, tb)
    db = min(db, d)
    while d % db != 0:
        db //= 2
    vb = min(vb, V)
    n_vb = (V + vb - 1) // vb
    Vpad = n_vb * vb
    if Vpad != V:
        table = jnp.pad(table, ((0, Vpad - V), (0, 0)))
    n_db = d // db

    grid = (T // tb, n_vb, n_db)
    kernel = functools.partial(_kernel, n_vb=n_vb, n_db=n_db, vb_size=vb,
                               vocab=V)
    out_shapes = [jax.ShapeDtypeStruct((T,), jnp.float32) for _ in range(4)]
    f32 = jnp.float32
    ce, kl, corr, ent = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, db), lambda t, v, k: (t, k)),
            pl.BlockSpec((vb, db), lambda t, v, k: (v, k)),
            pl.BlockSpec((tb,), lambda t, v, k: (t,)),
        ],
        out_specs=[pl.BlockSpec((tb,), lambda t, v, k: (t,))] * 4,
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((tb, vb), f32),     # logits tile
            pltpu.VMEM((tb,), f32),        # m
            pltpu.VMEM((tb,), f32),        # s
            pltpu.VMEM((tb,), f32),        # w
            pltpu.VMEM((tb,), f32),        # amax val
            pltpu.VMEM((tb,), jnp.int32),  # amax idx
            pltpu.VMEM((tb,), f32),        # target logit
        ],
        interpret=interpret,
    )(x.astype(jnp.float32), table.astype(jnp.float32),
      targets.astype(jnp.int32))
    return ce, kl, corr, ent
