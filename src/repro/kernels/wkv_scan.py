"""Chunked linear-recurrence (RWKV6 WKV / Mamba2 SSD) Pallas TPU kernel.

The roofline table shows rwkv6-3b and zamba2's Mamba2 blocks are
memory-bound in training/prefill and their per-token state read/write
dominates decode: the recurrence

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)      (RWKV6: exclusive+bonus)
    y_t = q_t^T S_t                                 (Mamba2: inclusive)

is evaluated chunk-parallel (models/ssm.linear_attention_chunked); this
kernel fuses one (batch*head) stream's whole scan into a single program:
the [K, V] state lives in VMEM scratch across chunk grid steps, so HBM
traffic is exactly q/k/v/w in + y out — no per-chunk state round-trips.

Grid: (BH, n_chunks) with the chunk axis innermost (sequential); the
decay algebra matches the pure-JAX chunked path: everything is
exp(cum_t - cum_s) with t >= s, never a positive exponent.

TPU tiling note: K = V = 64 for rwkv6-3b; on real hardware two heads
would be fused per program to fill the 128-lane dimension (the oracle
semantics are per-head, so that is a pure layout change). Validated in
interpret mode against models/ssm.linear_attention_scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
            y_ref, sout_ref, state_ref, *, n_chunks: int, chunk: int,
            mode: str):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    q = q_ref[0].astype(jnp.float32)          # [c, K]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # [c, V]
    lw = lw_ref[0].astype(jnp.float32)        # [c, K] (bcast if scalar)
    u = u_ref[0].astype(jnp.float32)          # [K]
    S = state_ref[...]                        # [K, V]

    cum = jnp.cumsum(lw, axis=0)              # inclusive
    cum_ex = cum - lw                         # exclusive
    # rwkv reads S BEFORE the current token (exclusive); mamba after
    out_cum = cum if mode == "mamba" else cum_ex
    # inter-chunk: q decayed from chunk start against carried state
    y = jnp.dot(q * jnp.exp(out_cum), S, preferred_element_type=jnp.float32)
    # intra-chunk decay matrix A[t,s] = exp(out_cum_t - cum_s), t (>=|>) s
    diff = out_cum[:, None, :] - cum[None, :, :]           # [c, c, K]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool),
                   k=0 if mode == "mamba" else -1)
    amat = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("tk,sk,tsk->ts", q, k, amat)
    y = y + jnp.dot(scores, v, preferred_element_type=jnp.float32)
    if mode == "rwkv":
        # bonus (current token through diag(u))
        y = y + ((q * u[None, :] * k).sum(axis=1))[:, None] * v
    y_ref[0] = y.astype(y_ref.dtype)

    # state carry: S' = exp(cum_last) * S + sum_s exp(cum_last - cum_s) k v
    last = cum[-1, :]                          # [K]
    kdec = k * jnp.exp(last[None, :] - cum)
    state_ref[...] = (jnp.exp(last)[:, None] * S
                      + jnp.dot(kdec.T, v,
                                preferred_element_type=jnp.float32))

    @pl.when(ci == n_chunks - 1)
    def _fin():
        sout_ref[0] = state_ref[...].astype(sout_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret", "mode"))
def wkv_scan(q, k, v, logw, u, state0, *, chunk: int = 64,
             interpret: bool = False, mode: str = "rwkv"):
    """Chunked linear-recurrence kernel. q,k [B,T,H,K]; v [B,T,H,V];
    logw broadcastable to [B,T,H,K]; u [H,K] (ignored for mode="mamba");
    state0 [B,H,K,V]. mode: "rwkv" (exclusive + diag(u) bonus, RWKV6) or
    "mamba" (inclusive, Mamba2/SSD scalar-decay broadcast over K).
    Returns (y [B,T,H,V], state [B,H,K,V])."""
    B, T, H, K = q.shape
    V = v.shape[-1]
    while T % chunk:
        chunk //= 2
    nc = T // chunk
    BH = B * H

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(BH, T, x.shape[-1])

    qf, kf, vf = flat(q), flat(k), flat(v)
    lwf = flat(jnp.broadcast_to(logw, (B, T, H, K)))
    uf = jnp.broadcast_to(u[None], (B, H, K)).reshape(BH, K)
    s0 = state0.reshape(BH, K, V)

    seq_spec = lambda d: pl.BlockSpec((1, chunk, d),
                                      lambda bh, i: (bh, i, 0))
    y, sout = pl.pallas_call(
        functools.partial(_kernel, n_chunks=nc, chunk=chunk, mode=mode),
        grid=(BH, nc),
        in_specs=[seq_spec(K), seq_spec(K), seq_spec(V), seq_spec(K),
                  pl.BlockSpec((1, K), lambda bh, i: (bh, 0)),
                  pl.BlockSpec((1, K, V), lambda bh, i: (bh, 0, 0))],
        out_specs=[seq_spec(V),
                   pl.BlockSpec((1, K, V), lambda bh, i: (bh, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((BH, T, V), jnp.float32),
                   jax.ShapeDtypeStruct((BH, K, V), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, lwf, uf, s0)
    y = y.reshape(B, H, T, V).transpose(0, 2, 1, 3)
    return y, sout.reshape(B, H, K, V)
