"""Paged flash-decode Pallas kernels (TPU target) + in-kernel paged write.

Decode hot spot of the paged serving backend: one-token attention computed
DIRECTLY against the block-paged KV cache. The XLA fallback in
`models/attention.py` materializes a dense `[B, M*block_size, ...]` view of
every row's pages (`gather_blocks`) before attending — O(B * view) HBM
traffic per decoded token regardless of how much of the view is valid.
These kernels never build that view:

  * the kv grid dimension walks each row's page table via scalar prefetch
    (`PrefetchScalarGridSpec`): step (b, j) DMAs exactly ONE
    `[block_size, ...]` physical block, `tables[b, j]`, into VMEM;
  * unmapped pages (table entry 0 = the shared trash block) and pages
    entirely beyond the row's `idx <= pos` prefix are early-masked — the
    online-softmax state is simply not updated, so trash contents can
    never contribute (rows whose table is all zeros produce exact zeros);
  * fp32 (m, l, acc) online-softmax scratch lives in VMEM across the kv
    walk; the final kv step normalizes and writes the output tile.

Two variants share the dataflow:
  * GQA  — paged K/V `[n_blocks+1, block_size, KV, hd]`; kv head = q head
    // group, computed in-kernel on the `[KV, group]` score layout.
  * MLA  — weight-absorbed decode against the COMPRESSED cache
    (`ckv` `[*, kv_lora]` + shared rope key `[*, rope_dim]`): the kernel
    applies the kv rms-norm per block in fp32 and returns the latent
    context `[B, 1, H, kv_lora]`; the caller applies W_uv / W_o.

`paged_write_token` is the companion single-token scatter: grid (B,), the
output BlockSpec selects the physical block holding each row's `pos`
through the page table, and the kernel rewrites only `pos % block_size`
(input/output aliased, so no dense copy of the leaf). Rows whose target
block is unmapped write into the trash block — same contract as the XLA
`_paged_write` they replace. Mapped blocks are pairwise disjoint across
rows (pool invariant), so block revisits only ever hit the trash block.

Oracle: the dense-gather paths in `models/attention.py` (parity pinned by
tests/test_paged_kernel.py). Wrappers with interpret-mode defaults live in
kernels/ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


# ---------------------------------------------------------------------------
# GQA paged flash-decode
# ---------------------------------------------------------------------------

def _gqa_kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                m_ref, l_ref, acc_ref, *, n_mb: int, bs: int, group: int,
                scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    page = tbl_ref[b, j]
    pos = pos_ref[b]
    # early-mask: skip unmapped/trash pages and pages fully beyond the
    # row's valid prefix — their DMA'd block never touches the softmax
    valid_block = (page > 0) & (j * bs <= pos)

    @pl.when(valid_block)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)              # [H, hd]
        k = k_ref[0].astype(jnp.float32)                 # [bs, KV, hd]
        v = v_ref[0].astype(jnp.float32)
        KV = k.shape[1]
        qg = q.reshape(KV, group, q.shape[-1])
        s = jnp.einsum("kgh,skh->kgs", qg, k,
                       preferred_element_type=jnp.float32) * scale
        idx = j * bs + jax.lax.broadcasted_iota(jnp.int32,
                                                (KV, group, bs), 2)
        mask = idx <= pos                                # per-row validity
        s = jnp.where(mask, s, NEG)
        bm = s.max(axis=-1)
        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, bm)
        alpha = jnp.exp(m_old - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum(
            "kgs,skh->kgh", p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_mb - 1)
    def _final():
        H, hd = q_ref.shape[2], q_ref.shape[3]
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[..., None]
                       ).reshape(H, hd).astype(o_ref.dtype)


def paged_flash_decode_gqa(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, tables: jnp.ndarray,
                           positions: jnp.ndarray, *,
                           scale: float | None = None,
                           interpret: bool = False) -> jnp.ndarray:
    """q [B,1,H,hd]; k/v_pages [n_blocks+1, block_size, KV, hd];
    tables [B, M] int32 (0 = unmapped); positions [B] int32. Returns
    [B,1,H,hd] — masked-softmax attention over each row's valid prefix,
    identical (up to fp32 online-softmax rounding) to the dense-gather
    path. Fully-unmapped rows return exact zeros."""
    B, T, H, hd = q.shape
    assert T == 1
    bs, KV = k_pages.shape[1], k_pages.shape[2]
    M = tables.shape[1]
    group = H // KV
    scale = scale or 1.0 / np.sqrt(hd)
    kernel = functools.partial(_gqa_kernel, n_mb=M, bs=bs, group=group,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, M),
        in_specs=[
            pl.BlockSpec((1, 1, H, hd), lambda b, j, t, p: (b, 0, 0, 0)),
            pl.BlockSpec((1, bs, KV, hd),
                         lambda b, j, t, p: (t[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, KV, hd),
                         lambda b, j, t, p: (t[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, H, hd), lambda b, j, t, p: (b, 0, 0, 0)),
        scratch_shapes=[pltpu.VMEM((KV, group), jnp.float32),
                        pltpu.VMEM((KV, group), jnp.float32),
                        pltpu.VMEM((KV, group, hd), jnp.float32)],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1, H, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), positions.astype(jnp.int32), q,
      k_pages, v_pages)


# ---------------------------------------------------------------------------
# MLA paged flash-decode (weight-absorbed, compressed cache)
# ---------------------------------------------------------------------------

def _mla_kernel(tbl_ref, pos_ref, qa_ref, qr_ref, ckv_ref, kr_ref, w_ref,
                o_ref, m_ref, l_ref, acc_ref, *, n_mb: int, bs: int,
                scale: float, eps: float):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    page = tbl_ref[b, j]
    pos = pos_ref[b]
    valid_block = (page > 0) & (j * bs <= pos)

    @pl.when(valid_block)
    def _():
        ckv = ckv_ref[0].astype(jnp.float32)             # [bs, r]
        var = jnp.mean(ckv * ckv, axis=-1, keepdims=True)
        ckv_n = ckv * jax.lax.rsqrt(var + eps) * (
            1.0 + w_ref[...].astype(jnp.float32))        # kv rms-norm
        kr = kr_ref[0].astype(jnp.float32)               # [bs, dr]
        qa = qa_ref[0, 0].astype(jnp.float32)            # [H, r] (absorbed)
        qr = qr_ref[0, 0].astype(jnp.float32)            # [H, dr]
        s = (jnp.dot(qa, ckv_n.T, preferred_element_type=jnp.float32)
             + jnp.dot(qr, kr.T, preferred_element_type=jnp.float32)) * scale
        H = s.shape[0]
        idx = j * bs + jax.lax.broadcasted_iota(jnp.int32, (H, bs), 1)
        mask = idx <= pos
        s = jnp.where(mask, s, NEG)
        bm = s.max(axis=-1)
        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, bm)
        alpha = jnp.exp(m_old - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, ckv_n, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_mb - 1)
    def _final():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def paged_flash_decode_mla(q_abs: jnp.ndarray, q_rope: jnp.ndarray,
                           ckv_pages: jnp.ndarray, kr_pages: jnp.ndarray,
                           kv_norm: jnp.ndarray, tables: jnp.ndarray,
                           positions: jnp.ndarray, *, scale: float,
                           eps: float = 1e-6,
                           interpret: bool = False) -> jnp.ndarray:
    """Weight-absorbed MLA decode against the paged compressed cache.

    q_abs [B,1,H,r] (q_nope absorbed through W_uk); q_rope [B,1,H,dr];
    ckv_pages [n_blocks+1, bs, r]; kr_pages [n_blocks+1, bs, dr];
    kv_norm [r]. Returns the latent context [B,1,H,r] in fp32 — the
    caller applies W_uv and W_o (scores AND values stay O(kv_lora))."""
    B, T, H, r = q_abs.shape
    assert T == 1
    dr = q_rope.shape[-1]
    bs = ckv_pages.shape[1]
    M = tables.shape[1]
    kernel = functools.partial(_mla_kernel, n_mb=M, bs=bs, scale=scale,
                               eps=eps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, M),
        in_specs=[
            pl.BlockSpec((1, 1, H, r), lambda b, j, t, p: (b, 0, 0, 0)),
            pl.BlockSpec((1, 1, H, dr), lambda b, j, t, p: (b, 0, 0, 0)),
            pl.BlockSpec((1, bs, r), lambda b, j, t, p: (t[b, j], 0, 0)),
            pl.BlockSpec((1, bs, dr), lambda b, j, t, p: (t[b, j], 0, 0)),
            pl.BlockSpec((r,), lambda b, j, t, p: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, H, r), lambda b, j, t, p: (b, 0, 0, 0)),
        scratch_shapes=[pltpu.VMEM((H,), jnp.float32),
                        pltpu.VMEM((H,), jnp.float32),
                        pltpu.VMEM((H, r), jnp.float32)],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1, H, r), jnp.float32),
        interpret=interpret,
    )(tables.astype(jnp.int32), positions.astype(jnp.int32),
      q_abs, q_rope, ckv_pages, kr_pages, kv_norm)


# ---------------------------------------------------------------------------
# In-kernel single-token paged write
# ---------------------------------------------------------------------------

def _write_kernel(phys_ref, pos_ref, val_ref, leaf_ref, out_ref, *, bs: int):
    b = pl.program_id(0)
    out_ref[...] = leaf_ref[...]
    out_ref[0, pos_ref[b] % bs] = val_ref[0].astype(out_ref.dtype)


def paged_write_token(leaf: jnp.ndarray, tables: jnp.ndarray,
                      positions: jnp.ndarray, values: jnp.ndarray, *,
                      interpret: bool = False) -> jnp.ndarray:
    """Scatter one token per row through the page table.

    leaf [n_blocks+1, block_size, ...]; tables [B, M]; positions [B];
    values [B, ...]. Row b writes into block `tables[b, pos//bs]` at
    offset `pos % bs`; unmapped targets land in the trash block (id 0) —
    identical contract to the XLA `_paged_write` scatter. The leaf is
    input/output aliased: only the touched blocks move through VMEM."""
    N, bs = leaf.shape[:2]
    rest = leaf.shape[2:]
    B, M = tables.shape
    F = int(np.prod(rest)) if rest else 1
    leaf2 = leaf.reshape(N, bs, F)
    vals2 = values.reshape(B, F)
    blk = jnp.clip(positions.astype(jnp.int32) // bs, 0, M - 1)
    phys = jnp.take_along_axis(tables.astype(jnp.int32),
                               blk[:, None], axis=1)[:, 0]     # [B]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, F), lambda b, ph, p: (b, 0)),
            pl.BlockSpec((1, bs, F), lambda b, ph, p: (ph[b], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, F), lambda b, ph, p: (ph[b], 0, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_write_kernel, bs=bs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(leaf2.shape, leaf.dtype),
        input_output_aliases={3: 0},
        interpret=interpret,
    )(phys, positions.astype(jnp.int32), vals2, leaf2)
    return out.reshape(leaf.shape)
