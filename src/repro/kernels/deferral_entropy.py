"""Fused deferral-signal Pallas kernel (TPU target).

Serving-time gate of eqs. (7)-(8): from decode logits [T, V] compute, in one
streaming pass over vocab blocks, (neg_entropy, max_prob, argmax) per token —
the cascade's deferral signal — without a second HBM pass over the logits.

Grid: (token_blocks, vocab_blocks), vocab innermost with online
max/sumexp/weighted-sum accumulators (H = lse - w/s, see gatekeeper_loss.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(logits_ref, nent_ref, mprob_ref, amax_ref,
            m_ref, s_ref, w_ref, av_ref, ai_ref,
            *, n_vb: int, vb_size: int, vocab: int):
    vb = pl.program_id(1)

    @pl.when(vb == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)
        w_ref[...] = jnp.zeros_like(w_ref)
        av_ref[...] = jnp.full_like(av_ref, NEG)
        ai_ref[...] = jnp.zeros_like(ai_ref)

    logits = logits_ref[...].astype(jnp.float32)
    col = vb * vb_size + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(col < vocab, logits, NEG)

    bm = logits.max(axis=1)
    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, bm)
    scale = jnp.exp(m_old - m_new)
    p = jnp.exp(logits - m_new[:, None])
    p = jnp.where(col < vocab, p, 0.0)
    s_ref[...] = s_ref[...] * scale + p.sum(axis=1)
    w_ref[...] = w_ref[...] * scale + (p * logits).sum(axis=1)
    m_ref[...] = m_new

    bidx = jnp.argmax(logits, axis=1).astype(jnp.int32)
    better = bm > av_ref[...]
    av_ref[...] = jnp.where(better, bm, av_ref[...])
    ai_ref[...] = jnp.where(better, bidx + vb * vb_size, ai_ref[...])

    @pl.when(vb == n_vb - 1)
    def _final():
        lse = m_ref[...] + jnp.log(s_ref[...])
        ent = lse - w_ref[...] / s_ref[...]
        nent_ref[...] = -ent
        mprob_ref[...] = jnp.exp(av_ref[...] - lse)
        amax_ref[...] = ai_ref[...]


def deferral_entropy(logits: jnp.ndarray, *, tb: int = 128, vb: int = 2048,
                     interpret: bool = False):
    """(neg_entropy [T], max_prob [T], argmax [T]) from logits [T, V].
    T must be a multiple of tb; vocab tail is padded/masked internally."""
    T, V = logits.shape
    assert T % tb == 0, (T, tb)
    vb = min(vb, V)
    n_vb = (V + vb - 1) // vb
    Vpad = n_vb * vb
    if Vpad != V:
        logits = jnp.pad(logits, ((0, 0), (0, Vpad - V)))
    kernel = functools.partial(_kernel, n_vb=n_vb, vb_size=vb, vocab=V)
    f32 = jnp.float32
    nent, mprob, amax = pl.pallas_call(
        kernel,
        grid=(T // tb, n_vb),
        in_specs=[pl.BlockSpec((tb, vb), lambda t, v: (t, v))],
        out_specs=[pl.BlockSpec((tb,), lambda t, v: (t,))] * 3,
        out_shape=[jax.ShapeDtypeStruct((T,), f32),
                   jax.ShapeDtypeStruct((T,), f32),
                   jax.ShapeDtypeStruct((T,), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((tb,), f32), pltpu.VMEM((tb,), f32),
                        pltpu.VMEM((tb,), f32), pltpu.VMEM((tb,), f32),
                        pltpu.VMEM((tb,), jnp.int32)],
        interpret=interpret,
    )(logits)
    return nent, mprob, amax
