"""Request lifecycle + arrival queue for the continuous-batching cascade.

A `Request` moves through:

    PENDING  — arrived (visible once `now >= arrival_time`), waiting in the
               FIFO `ArrivalQueue` for a free M_S slot
    RUNNING  — admitted into a KV-pool slot; decoding on M_S with the
               per-step eq.-8 negative-entropy confidence accumulated on
               device
    DEFERRED — evicted from M_S (either in-flight, when the running mean
               confidence drops below tau - margin after `min_tokens`, or
               at end of decode when the final mean is below tau); about
               to be handed to the M_L backend
    DEFERRED_PENDING — submitted to the M_L backend (see
               `serving.large_backend`); regeneration is in flight —
               possibly concurrently with M_S decode — until the engine
               polls the completed tokens back
    DONE     — final tokens attached (M_S output for kept requests, M_L
               output for deferred ones)

Timestamps are seconds relative to the engine's run start so telemetry can
derive queueing delay, service time, and end-to-end latency per request.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Deque, List, Optional

import numpy as np

PENDING = "pending"
RUNNING = "running"
DEFERRED = "deferred"
DEFERRED_PENDING = "deferred_pending"
DONE = "done"


@dataclasses.dataclass
class Request:
    """One serving request. `prompt` is the request's own token vector —
    requests in the same run may carry different lengths (ragged
    admission); the engine reads `prompt_len` per request rather than
    taking a run-wide length argument."""
    rid: int
    prompt: np.ndarray                 # [prompt_len] int32 (per-request len)
    max_new: int
    arrival_time: float = 0.0          # seconds from run start
    state: str = PENDING
    slot: Optional[int] = None
    tier: int = 0                      # cascade tier that owns (and, at
                                       # DONE, served) this request

    # outputs
    tokens: Optional[np.ndarray] = None        # final (post-cascade) tokens
    small_tokens: Optional[np.ndarray] = None  # M_S tokens actually decoded
    confidence: float = float("nan")   # running mean neg-entropy at retire
    n_small_steps: int = 0             # M_S tokens decoded before retire
    deferred: bool = False
    early_exited: bool = False         # evicted before max_new (in-flight)
    shared_prefix_tokens: int = 0      # prompt tokens mapped from the
                                       # prefix registry (never prefilled)
    conf_trace: Optional[List[float]] = None  # per-token (per-sync-chunk)
                                       # eq.-8 confidence record; populated
                                       # only when span tracing is on and
                                       # attached to the decode span
    # lifecycle timestamps (seconds from run start; nan until reached)
    t_admit: float = float("nan")
    t_prefill_done: float = float("nan")  # decode seeded (prefill span end)
    t_retire: float = float("nan")     # left M_S (finished or evicted)
    t_submit_large: float = float("nan")  # handed to the M_L backend
    t_done: float = float("nan")       # final tokens available

    @property
    def deferral_wait_ms(self) -> float:
        """Milliseconds from M_S retirement to final M_L tokens (nan for
        requests that never deferred)."""
        if not self.deferred:
            return float("nan")
        return (self.t_done - self.t_retire) * 1e3

    @property
    def prompt_len(self) -> int:
        """This request's own prompt length (ragged workloads: differs
        per request)."""
        return int(self.prompt.shape[0])

    @property
    def saved_steps(self) -> int:
        """M_S decode steps skipped by in-flight deferral."""
        return self.max_new - self.n_small_steps if self.early_exited else 0


class ArrivalQueue:
    """Arrival-ordered FIFO with delayed visibility.

    Requests sit in a min-heap keyed on `arrival_time` until the virtual
    clock passes them, then move to a FIFO of admissible requests. Ties in
    arrival time preserve submission order (heap key includes rid).
    """

    def __init__(self, requests: Optional[List[Request]] = None):
        self._future: list = []
        self._ready: Deque[Request] = deque()
        for r in requests or ():
            self.push(r)

    def push(self, req: Request) -> None:
        heapq.heappush(self._future, (req.arrival_time, req.rid, req))

    def release(self, now: float) -> int:
        """Move every request with arrival_time <= now into the ready FIFO.
        Returns how many became visible."""
        n = 0
        while self._future and self._future[0][0] <= now:
            self._ready.append(heapq.heappop(self._future)[2])
            n += 1
        return n

    def pop_ready(self) -> Optional[Request]:
        return self._ready.popleft() if self._ready else None

    def peek_ready(self) -> Optional[Request]:
        """Head of the ready FIFO without removing it (admission gating:
        the scheduler checks block capacity before committing a pop)."""
        return self._ready[0] if self._ready else None

    @property
    def n_ready(self) -> int:
        return len(self._ready)

    @property
    def next_arrival(self) -> Optional[float]:
        return self._future[0][0] if self._future else None

    def __len__(self) -> int:
        return len(self._future) + len(self._ready)


def make_requests(prompts, max_new: int,
                  arrivals: Optional[np.ndarray] = None) -> List[Request]:
    """One Request per prompt. `prompts` is either a uniform [N, T] int
    matrix or a sequence of 1-D token vectors with *different* lengths
    (ragged workloads). `arrivals` are per-request offsets in seconds from
    run start (default: all arrive at t=0)."""
    n = len(prompts)
    if arrivals is None:
        arrivals = np.zeros(n)
    return [Request(rid=i, prompt=np.asarray(prompts[i], np.int32),
                    max_new=max_new, arrival_time=float(arrivals[i]))
            for i in range(n)]


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """Cumulative arrival offsets (seconds) of a Poisson process with
    `rate` requests/s."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))
