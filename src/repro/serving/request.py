"""Request lifecycle + arrival queue for the continuous-batching cascade.

A `Request` moves through:

    PENDING  — arrived (visible once `now >= arrival_time`), waiting in the
               FIFO `ArrivalQueue` for a free M_S slot
    RUNNING  — admitted into a KV-pool slot; decoding on M_S with the
               per-step eq.-8 negative-entropy confidence accumulated on
               device
    PREEMPTED — evicted from its slot under block pressure (oversubscribed
               paged pool) with its decode state saved for bit-exact
               resume; back in the `ArrivalQueue`, where its ORIGINAL
               arrival time puts it ahead of every never-admitted arrival
               (age-priority pop — repeated preemption cannot starve it)
    DEFERRED — evicted from M_S (in-flight, when the running mean
               confidence drops below tau - margin after `min_tokens`; at
               end of decode when the final mean is below tau; or under
               block pressure with the defer-on-OOM policy,
               `deferred_reason == "oom"`); about to be handed to the M_L
               backend
    DEFERRED_PENDING — submitted to the M_L backend (see
               `serving.large_backend`); regeneration is in flight —
               possibly concurrently with M_S decode — until the engine
               polls the completed tokens back
    DONE     — final tokens attached (M_S output for kept requests, M_L
               output for deferred ones)

Two terminal states exist for requests the engine SHEDS instead of
serving (admission overload control — they end with an EMPTY token
vector and surface in telemetry, metrics, and the audit log):

    REJECTED — shed because the bounded ready queue (`max_queue`)
               overflowed (newest-first) or the shed pressure policy
               victimized it mid-flight
    EXPIRED  — shed because its deadline passed while still queued

Timestamps are seconds relative to the engine's run start so telemetry can
derive queueing delay, service time, and end-to-end latency per request.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Dict, List, Optional

import numpy as np

PENDING = "pending"
RUNNING = "running"
PREEMPTED = "preempted"
DEFERRED = "deferred"
DEFERRED_PENDING = "deferred_pending"
DONE = "done"
REJECTED = "rejected"
EXPIRED = "expired"

# terminal states a request can end a run in
TERMINAL_STATES = (DONE, REJECTED, EXPIRED)


@dataclasses.dataclass
class Request:
    """One serving request. `prompt` is the request's own token vector —
    requests in the same run may carry different lengths (ragged
    admission); the engine reads `prompt_len` per request rather than
    taking a run-wide length argument."""
    rid: int
    prompt: np.ndarray                 # [prompt_len] int32 (per-request len)
    max_new: int
    arrival_time: float = 0.0          # seconds from run start
    state: str = PENDING
    slot: Optional[int] = None
    tier: int = 0                      # cascade tier that owns (and, at
                                       # DONE, served) this request

    # admission overload control
    deadline: Optional[float] = None   # absolute (run-relative) seconds;
                                       # queued past it -> EXPIRED
    # pressure bookkeeping
    n_preempted: int = 0               # times evicted under block pressure
    admit_seq: int = -1                # global admission sequence number
                                       # (victim selection: youngest first)
    deferred_reason: Optional[str] = None  # "oom" when deferred by block
                                       # pressure; None for the confidence
                                       # gate
    resume: Optional[Dict[str, Any]] = None  # saved decode state of a
                                       # preempted request (device rows +
                                       # the token context whose KV must
                                       # be re-established); None once
                                       # consumed by re-admission

    # outputs
    tokens: Optional[np.ndarray] = None        # final (post-cascade) tokens
    small_tokens: Optional[np.ndarray] = None  # M_S tokens actually decoded
    confidence: float = float("nan")   # running mean neg-entropy at retire
    n_small_steps: int = 0             # M_S tokens decoded before retire
    deferred: bool = False
    early_exited: bool = False         # evicted before max_new (in-flight)
    shared_prefix_tokens: int = 0      # prompt tokens mapped from the
                                       # prefix registry (never prefilled)
    conf_trace: Optional[List[float]] = None  # per-token (per-sync-chunk)
                                       # eq.-8 confidence record; populated
                                       # only when span tracing is on and
                                       # attached to the decode span
    # lifecycle timestamps (seconds from run start; nan until reached)
    t_admit: float = float("nan")
    t_prefill_done: float = float("nan")  # decode seeded (prefill span end)
    t_retire: float = float("nan")     # left M_S (finished or evicted)
    t_submit_large: float = float("nan")  # handed to the M_L backend
    t_done: float = float("nan")       # final tokens available

    @property
    def deferral_wait_ms(self) -> float:
        """Milliseconds from M_S retirement to final M_L tokens (nan for
        requests that never deferred)."""
        if not self.deferred:
            return float("nan")
        return (self.t_done - self.t_retire) * 1e3

    @property
    def prompt_len(self) -> int:
        """This request's own prompt length (ragged workloads: differs
        per request)."""
        return int(self.prompt.shape[0])

    @property
    def saved_steps(self) -> int:
        """M_S decode steps skipped by in-flight deferral."""
        return self.max_new - self.n_small_steps if self.early_exited else 0

    @property
    def shed(self) -> bool:
        """True when overload control dropped this request (it ends with
        an empty token vector instead of a generation)."""
        return self.state in (REJECTED, EXPIRED)


class ArrivalQueue:
    """Arrival-ordered queue with delayed visibility, age-priority
    re-entry, and optional overload control.

    Requests sit in a min-heap keyed on `arrival_time` until the virtual
    clock passes them, then move to the READY heap of admissible
    requests — also keyed ``(arrival_time, rid)``, so pop order equals
    arrival order exactly as with the old FIFO. The heap (rather than a
    deque) is what makes `requeue` correct: a preempted request
    re-enters with its ORIGINAL arrival time, which is older than every
    never-admitted arrival still waiting, so it pops first and repeated
    preemption can never starve it behind fresh traffic.

    Overload control (both optional):
      * ``max_queue`` bounds the ready set; `shed_overflow` returns the
        NEWEST overflowing requests for the engine to reject.
      * per-request ``deadline`` + `expire(now)` returns ready requests
        whose deadline passed while queued.
    The queue only *selects* shed requests — marking them
    REJECTED/EXPIRED and surfacing telemetry is the engine's job.
    """

    def __init__(self, requests: Optional[List[Request]] = None,
                 max_queue: Optional[int] = None):
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_queue = max_queue
        self._future: list = []
        self._ready: list = []          # min-heap of (arrival_time, rid, req)
        for r in requests or ():
            self.push(r)

    def push(self, req: Request) -> None:
        heapq.heappush(self._future, (req.arrival_time, req.rid, req))

    def requeue(self, req: Request) -> None:
        """Re-enter a preempted request, keyed on its ORIGINAL arrival
        time (age-priority): it is older than anything still waiting, so
        it is next out."""
        heapq.heappush(self._ready, (req.arrival_time, req.rid, req))

    def release(self, now: float) -> int:
        """Move every request with arrival_time <= now into the ready
        heap. Returns how many became visible."""
        n = 0
        while self._future and self._future[0][0] <= now:
            heapq.heappush(self._ready, heapq.heappop(self._future))
            n += 1
        return n

    def pop_ready(self) -> Optional[Request]:
        return heapq.heappop(self._ready)[2] if self._ready else None

    def peek_ready(self) -> Optional[Request]:
        """Head of the ready heap without removing it (admission gating:
        the scheduler checks block capacity before committing a pop)."""
        return self._ready[0][2] if self._ready else None

    def shed_overflow(self) -> List[Request]:
        """Trim the ready set down to `max_queue` by removing the NEWEST
        entries (largest arrival key — the requests that would wait
        longest anyway). Returns the removed requests, oldest first."""
        if self.max_queue is None or len(self._ready) <= self.max_queue:
            return []
        keep = heapq.nsmallest(self.max_queue, self._ready)
        shed = sorted(set(map(id, self._ready)) - set(map(id, keep)))
        shed_entries = [e for e in self._ready if id(e) in shed]
        self._ready = keep
        heapq.heapify(self._ready)
        return [e[2] for e in sorted(shed_entries)]

    def expire(self, now: float) -> List[Request]:
        """Remove ready requests whose deadline passed while queued.
        Returns them oldest-first. Requests already admitted to a slot
        are never expired — work in flight is finished, not wasted."""
        dead = [e for e in self._ready
                if e[2].deadline is not None and e[2].deadline < now]
        if dead:
            alive = [e for e in self._ready
                     if not (e[2].deadline is not None
                             and e[2].deadline < now)]
            self._ready = alive
            heapq.heapify(self._ready)
        return [e[2] for e in sorted(dead)]

    @property
    def n_ready(self) -> int:
        return len(self._ready)

    @property
    def next_arrival(self) -> Optional[float]:
        return self._future[0][0] if self._future else None

    def __len__(self) -> int:
        return len(self._future) + len(self._ready)


def make_requests(prompts, max_new: int,
                  arrivals: Optional[np.ndarray] = None,
                  deadline_s: Optional[float] = None) -> List[Request]:
    """One Request per prompt. `prompts` is either a uniform [N, T] int
    matrix or a sequence of 1-D token vectors with *different* lengths
    (ragged workloads). `arrivals` are per-request offsets in seconds from
    run start (default: all arrive at t=0). `deadline_s` gives every
    request an absolute deadline of ``arrival_time + deadline_s``."""
    n = len(prompts)
    if arrivals is None:
        arrivals = np.zeros(n)
    return [Request(rid=i, prompt=np.asarray(prompts[i], np.int32),
                    max_new=max_new, arrival_time=float(arrivals[i]),
                    deadline=(float(arrivals[i]) + deadline_s
                              if deadline_s is not None else None))
            for i in range(n)]


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """Cumulative arrival offsets (seconds) of a Poisson process with
    `rate` requests/s."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))
