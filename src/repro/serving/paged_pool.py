"""Block-paged KV-cache pool for continuous batching with ragged prompts.

`SlotCachePool` reserves a worst-case `max_len` row per slot, so a short
request pays for the longest request's memory and mixed-length traffic
caps batch size. `PagedCachePool` instead stores the cache in fixed-size
**blocks** of `block_size` tokens shared by all slots:

  * every attention-cache leaf becomes ``[n_blocks + 1, block_size, ...]``
    (physical block 0 is a shared **trash block** that is never allocated
    — unmapped page-table entries and writes from inactive decode rows
    land there harmlessly);
  * each slot owns a row of the **page table** ``[n_slots, M]`` mapping
    its logical block ``m`` (tokens ``[m*block_size, (m+1)*block_size)``)
    to a physical block id, 0 meaning unmapped;
  * blocks are mapped on demand as a request's prefill/decode frontier
    advances and returned to the free list at retirement.

Admission control is **reservation-based**: admitting a request reserves
its worst-case block count ``ceil((prompt_len + max_new - 1)/block_size)``
(its prompt plus every decode token it may produce), but blocks are only
*mapped* lazily. The invariant ``free >= reserved`` guarantees that
`ensure_mapped` never fails mid-flight, so no preemption path is needed;
the per-request worst case is still far below the slot pool's global
worst case on ragged traffic, which is the memory win this pool exists
for.

Invariants (pinned by tests/test_serving_paged.py):
  * mapped blocks are pairwise disjoint across slots and never include 0;
  * mapped + free is always exactly {1..n_blocks};
  * len(free) >= total outstanding reservation;
  * a slot's table row is all-zero whenever the slot is free.

Families whose cache carries state without a ``cache_seq`` axis (RWKV,
Mamba) or with a sliding-window ring shorter than the sequence cannot be
paged; construction raises with a clear message.
"""
from __future__ import annotations

import math
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.models import transformer as tfm
from repro.models.attention import gather_blocks
from repro.serving.cache_pool import _is_abstract


def next_pow2(n: int) -> int:
    """Smallest power of two >= max(n, 1) — the shared bucketing policy
    for jit-shape control (active-prefix table slicing, batched prefill
    dispatch width): O(log n) distinct shapes ever compile."""
    p = 1
    while p < n:
        p *= 2
    return p


def validate_pageable(cfg: ModelConfig, max_len: int) -> None:
    """Raise NotImplementedError unless every cache leaf is a linear
    attention cache (has a full-length ``cache_seq`` axis)."""
    abstract = tfm.init_cache(cfg, 1, max_len, abstract=True)
    for leaf in jax.tree.leaves(abstract, is_leaf=_is_abstract):
        axes = leaf.logical_axes
        if "cache_seq" not in axes:
            raise NotImplementedError(
                f"paged KV cache requires attention caches only; leaf with "
                f"axes {axes} (recurrent state?) cannot be paged — use the "
                f"slot backend for family {cfg.family!r}")
        if leaf.shape[axes.index("cache_seq")] != max_len:
            raise NotImplementedError(
                f"paged KV cache does not support windowed/ring caches "
                f"(leaf seq {leaf.shape[axes.index('cache_seq')]} != "
                f"max_len {max_len}); use the slot backend")


def gather_pages(cache: Any, tables: jnp.ndarray, block_axes: Any) -> Any:
    """Tree-wide page-table gather: paged cache -> dense per-slot view
    ``[..., n_slots_in_tables, M*block_size, ...]``. Host-side test/debug
    helper; the jitted paths gather leaf-wise inside attention."""
    def one(leaf, ax):
        if ax == 0:
            return gather_blocks(leaf, tables)
        assert ax == 1, "block axis beyond [layers] leading dim unsupported"
        return jax.vmap(lambda l: gather_blocks(l, tables))(leaf)
    return jax.tree.map(one, cache, block_axes)


class PagedCachePool:
    """Block-paged per-slot cache + slot/block/reservation bookkeeping.

    Device state: ``.cache`` (paged leaves, replaced functionally after
    each jitted step) and ``.tables_device()`` (the int32 page table the
    jitted programs index through). Host state: free lists, per-slot
    mapped/reserved counts, lifetime counters.

    The **slot** API (`alloc`/`release`/`n_free`/`in_use`) matches
    `SlotCachePool`, so `SlotScheduler` drives either pool; the **block**
    API (`can_reserve`/`reserve`/`ensure_mapped`) is what makes admission
    ragged-aware.
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, n_blocks: int,
                 block_size: int, max_len: int, dtype=None):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if block_size < 1 or n_blocks < 1:
            raise ValueError("block_size and n_blocks must be >= 1")
        validate_pageable(cfg, max_len)
        self.cfg = cfg
        self.n_slots = n_slots
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.max_len = max_len
        # logical blocks a single slot may address (covers max_len plus
        # one block of slack for padded-chunk clamping)
        self.max_blocks = math.ceil(max_len / block_size) + 1
        # physical storage: init_cache with batch = blocks gives exactly
        # the paged layout [n_blocks+1, block_size, ...] per leaf
        # (block 0 = trash)
        self.cache = tfm.init_cache(cfg, n_blocks + 1, block_size,
                                    dtype=dtype or cfg.cdtype())
        abstract = tfm.init_cache(cfg, n_blocks + 1, block_size,
                                  abstract=True)
        def _axes(a):
            b = a.logical_axes.index("batch")
            s = a.logical_axes.index("cache_seq")
            assert s == b + 1, "paged gather assumes [block, block_size] adjacency"
            return b
        self.block_axes = jax.tree.map(_axes, abstract, is_leaf=_is_abstract)

        # host bookkeeping
        self.tables = np.zeros((n_slots, self.max_blocks), np.int32)
        self.n_mapped = np.zeros(n_slots, np.int64)
        self._owed = np.zeros(n_slots, np.int64)     # reserved, not yet mapped
        self._reserved_total = 0
        self._free_blocks: List[int] = list(range(n_blocks, 0, -1))
        self._free_slots: List[int] = list(range(n_slots - 1, -1, -1))
        self._in_use: set = set()
        self.generations = [0] * n_slots
        self.peak_mapped = 0                          # high-water block usage
        self._tables_dev = jnp.asarray(self.tables)
        self._tables_prefix_cache: dict = {}
        self._tables_dirty = False

    # -- capacity / accounting --------------------------------------------
    def blocks_for(self, n_tokens: int) -> int:
        """Physical blocks needed to store `n_tokens` cache entries."""
        return max(0, math.ceil(n_tokens / self.block_size))

    @property
    def n_free_blocks(self) -> int:
        return len(self._free_blocks)

    @property
    def n_mapped_total(self) -> int:
        return int(self.n_mapped.sum())

    def footprint_bytes(self) -> int:
        """Device bytes held by the paged cache (all physical blocks)."""
        return sum(l.nbytes for l in jax.tree.leaves(self.cache))

    # -- slot bookkeeping (SlotCachePool-compatible) ----------------------
    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def in_use(self) -> frozenset:
        return frozenset(self._in_use)

    def alloc(self) -> int:
        """Lowest-numbered free slot (deterministic placement)."""
        if not self._free_slots:
            raise RuntimeError("cache pool exhausted")
        slot = self._free_slots.pop()
        self._in_use.add(slot)
        self.generations[slot] += 1
        return slot

    def release(self, slot: int) -> None:
        """Free the slot: unmap its blocks, drop its outstanding
        reservation, and zero its table row (so stale decode writes from
        the retired tenant land in the trash block)."""
        if slot not in self._in_use:
            raise RuntimeError(f"releasing slot {slot} that is not in use")
        self._in_use.remove(slot)
        self._free_slots.append(slot)
        self._free_slots.sort(reverse=True)
        for m in range(int(self.n_mapped[slot])):
            self._free_blocks.append(int(self.tables[slot, m]))
        self._free_blocks.sort(reverse=True)
        self._reserved_total -= int(self._owed[slot])
        self._owed[slot] = 0
        self.n_mapped[slot] = 0
        self.tables[slot] = 0
        self._tables_dirty = True

    # -- block reservation / mapping --------------------------------------
    def can_reserve(self, n_tokens: int) -> bool:
        """True if a request needing `n_tokens` total cache entries can be
        admitted without ever starving an already-admitted request."""
        return (len(self._free_blocks) - self._reserved_total
                >= self.blocks_for(n_tokens))

    def reserve(self, slot: int, n_tokens: int) -> None:
        """Reserve the slot's worst-case block count. Must hold
        `can_reserve(n_tokens)`; blocks are mapped later by
        `ensure_mapped`."""
        need = self.blocks_for(n_tokens)
        if len(self._free_blocks) - self._reserved_total < need:
            raise RuntimeError("paged pool over-reserved: admission must "
                               "check can_reserve() first")
        self._owed[slot] = need
        self._reserved_total += need

    def ensure_mapped(self, slot: int, n_tokens: int) -> int:
        """Map blocks until the slot covers `n_tokens` logical cache
        entries. Never fails for demands within the slot's reservation
        (the free list always holds >= reserved blocks). Returns the
        number of newly mapped blocks."""
        need = self.blocks_for(n_tokens)
        newly = 0
        while int(self.n_mapped[slot]) < need:
            if not self._free_blocks:
                raise RuntimeError("paged pool out of blocks — reservation "
                                   "invariant violated")
            blk = self._free_blocks.pop()
            m = int(self.n_mapped[slot])
            self.tables[slot, m] = blk
            self.n_mapped[slot] += 1
            if self._owed[slot] > 0:
                self._owed[slot] -= 1
                self._reserved_total -= 1
            newly += 1
        if newly:
            self._tables_dirty = True
            self.peak_mapped = max(self.peak_mapped, self.n_mapped_total)
        return newly

    def active_prefix_blocks(self, n_tokens: int) -> int:
        """Logical blocks needed to cover `n_tokens` cache entries,
        bucketed UP to a power of two (and clamped to `max_blocks`) so
        table-prefix slicing compiles only O(log max_blocks) shapes.
        The decode paths gather/walk only this prefix instead of all
        `max_blocks` table entries — the active-prefix tightening."""
        return min(next_pow2(self.blocks_for(n_tokens)), self.max_blocks)

    def tables_device(self, prefix: Optional[int] = None) -> jnp.ndarray:
        """Device copy of the page table, refreshed only when the host
        table changed since the last call. `prefix` returns only the
        first `prefix` logical-block columns (see
        `active_prefix_blocks`); each distinct prefix is cached until
        the next table mutation."""
        if self._tables_dirty:
            self._tables_dev = jnp.asarray(self.tables)
            self._tables_prefix_cache = {}
            self._tables_dirty = False
        if prefix is None or prefix >= self.max_blocks:
            return self._tables_dev
        got = self._tables_prefix_cache.get(prefix)
        if got is None:
            got = jnp.asarray(self.tables[:, :prefix])
            self._tables_prefix_cache[prefix] = got
        return got

    # -- invariants (tests) ------------------------------------------------
    def check_invariants(self) -> None:
        mapped = [int(self.tables[s, m]) for s in range(self.n_slots)
                  for m in range(int(self.n_mapped[s]))]
        assert 0 not in mapped, "trash block mapped"
        assert len(mapped) == len(set(mapped)), "block double-mapped"
        assert set(mapped) | set(self._free_blocks) == set(
            range(1, self.n_blocks + 1)), "blocks leaked"
        assert len(self._free_blocks) >= self._reserved_total >= 0, \
            "reservation exceeds free blocks"
        for s in range(self.n_slots):
            if s not in self._in_use:
                assert (self.tables[s] == 0).all(), \
                    f"free slot {s} holds mapped blocks"
        assert len(self._in_use) + len(self._free_slots) == self.n_slots
