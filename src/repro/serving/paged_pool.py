"""Block-paged KV-cache pool for continuous batching with ragged prompts.

`SlotCachePool` reserves a worst-case `max_len` row per slot, so a short
request pays for the longest request's memory and mixed-length traffic
caps batch size. `PagedCachePool` instead stores the cache in fixed-size
**blocks** of `block_size` tokens shared by all slots:

  * every attention-cache leaf becomes ``[n_blocks + 1, block_size, ...]``
    (physical block 0 is a shared **trash block** that is never allocated
    — unmapped page-table entries and writes from inactive decode rows
    land there harmlessly);
  * each slot owns a row of the **page table** ``[n_slots, M]`` mapping
    its logical block ``m`` (tokens ``[m*block_size, (m+1)*block_size)``)
    to a physical block id, 0 meaning unmapped;
  * blocks are mapped on demand as a request's prefill/decode frontier
    advances and returned to the free list at retirement.

Admission control is **reservation-based**: admitting a request reserves
its worst-case block count ``ceil((prompt_len + max_new - 1)/block_size)``
(its prompt plus every decode token it may produce), but blocks are only
*mapped* lazily. With ``oversubscribe == 1`` (the default) the invariant
``free >= reserved`` guarantees that `ensure_mapped` never fails
mid-flight, so no preemption path is needed; the per-request worst case
is still far below the slot pool's global worst case on ragged traffic,
which is the memory win this pool exists for.

**Oversubscription.** ``oversubscribe > 1`` relaxes the reservation
invariant to a *virtual* budget: admission may reserve up to
``round(n_blocks * oversubscribe)`` blocks against only ``n_blocks``
physical ones, betting that most requests retire before their worst
case. The generalized invariant is ``physical_in_use + reserved_total
<= virtual_blocks`` (algebraically identical to ``free >= reserved``
at factor 1). The price: `ensure_mapped` / `cow_clone` can now hit
genuine physical exhaustion mid-flight, surfaced as the typed
:class:`BlockPressure` exception — the engine's `PressurePolicy`
(serving/pressure.py) answers it by preempting, deferring, or shedding
a victim. Without oversubscription exhaustion is still a hard
RuntimeError (a bookkeeping bug, not pressure).

**Host swap tier.** ``swap_blocks > 0`` gives evicted cached prefix
blocks a second life: when `_pop_free` must evict a zero-ref registered
block, its contents are first copied to a bounded host-RAM store (LRU
over chain keys, capacity ``swap_blocks``). `share_prefix` consults the
store after the device registry misses and swaps matching blocks back
in (`_swap_in`: allocate + host->device copy + re-register), so the
prefix cache survives pressure instead of being recomputed. Swap keys
and device registry keys are always disjoint.

**Prefix sharing (copy-on-write).** Physical blocks carry reference
counts, so one block may appear in several slots' tables. A **prefix
registry** keys each fully-prefilled prompt block on the hash chain of
its token ids (`prefix_block_keys`); `share_prefix` maps the longest
registered prefix of a new request's prompt straight into its table —
those tokens are never prefilled again. Shared blocks are read-only:
any write first goes through `ensure_writable`, which `cow_clone`s a
block whose refcount exceeds one into a fresh private copy, so the
paged write paths (`models.attention._paged_write` and the Pallas
`paged_write_token` kernel) keep their "writable blocks are pairwise
disjoint across rows" contract — `check_write_disjoint` asserts it per
dispatch. Reservation accounting covers the CoW worst case (sharing
gives the matched blocks' reservation back, withholding one block of
slack exactly when a fully-shared prompt could clone its tail), so
`ensure_mapped` and `cow_clone` stay infallible for admitted requests.
Released blocks whose refcount hits zero keep their registry entry
("cached"): their content is intact until reallocated, so a later
same-prefix request can resurrect them even after the donor retired.
Allocation prefers unregistered free blocks (lowest id first) and
evicts cached ones — also lowest id first — only when it must.

Invariants (pinned by tests/test_serving_paged.py and
tests/test_serving_prefix.py):
  * per-slot table rows never repeat a block and never map block 0;
  * every block's refcount equals the number of table rows of in-use
    slots that map it; blocks with refcount 0 are exactly the free set;
  * refcounted + free is always exactly {1..n_blocks};
  * len(free) >= total outstanding reservation;
  * registry entries point at blocks that are mapped or cached-free,
    bijectively with the reverse map;
  * a slot's table row is all-zero whenever the slot is free.

Families whose cache carries state without a ``cache_seq`` axis (RWKV,
Mamba) or with a sliding-window ring shorter than the sequence cannot be
paged; construction raises with a clear message.
"""
from __future__ import annotations

import hashlib
import heapq
import math
from collections import OrderedDict
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.models import transformer as tfm
from repro.models.attention import gather_blocks
from repro.serving.cache_pool import _is_abstract


class BlockPressure(RuntimeError):
    """Physical block exhaustion under oversubscription.

    Raised by allocation paths (`ensure_mapped` -> `_take_free_block`,
    `cow_clone`, swap-in) when the pool is oversubscribed and no
    physical block is free — an expected, recoverable condition the
    engine answers with its pressure policy (preempt / defer-on-OOM /
    shed a victim, then retry). Never raised at ``oversubscribe == 1``,
    where the reservation invariant makes allocation infallible and
    exhaustion stays a hard RuntimeError."""


def next_pow2(n: int) -> int:
    """Smallest power of two >= max(n, 1) — the shared bucketing policy
    for jit-shape control (active-prefix table slicing, batched prefill
    dispatch width): O(log n) distinct shapes ever compile."""
    p = 1
    while p < n:
        p *= 2
    return p


def prefix_block_keys(tokens: np.ndarray, block_size: int) -> List[bytes]:
    """Hash-chain keys of every FULL block of `tokens`.

    Key m digests (key of block m-1, tokens of block m), so equal keys
    mean equal whole prefixes, not just equal blocks — the registry can
    match block-granular longest prefixes without storing token arrays.
    Only fully-populated blocks get keys: a partial tail block is still
    written by its owner's prefill/decode and must never be shared."""
    out: List[bytes] = []
    prev = b""
    toks = np.ascontiguousarray(tokens, np.int32)
    for m in range(len(toks) // block_size):
        h = hashlib.blake2b(prev, digest_size=16)
        h.update(toks[m * block_size:(m + 1) * block_size].tobytes())
        prev = h.digest()
        out.append(prev)
    return out


def validate_pageable(cfg: ModelConfig, max_len: int) -> None:
    """Raise NotImplementedError unless every cache leaf is a linear
    attention cache (has a full-length ``cache_seq`` axis)."""
    abstract = tfm.init_cache(cfg, 1, max_len, abstract=True)
    for leaf in jax.tree.leaves(abstract, is_leaf=_is_abstract):
        axes = leaf.logical_axes
        if "cache_seq" not in axes:
            raise NotImplementedError(
                f"paged KV cache requires attention caches only; leaf with "
                f"axes {axes} (recurrent state?) cannot be paged — use the "
                f"slot backend for family {cfg.family!r}")
        if leaf.shape[axes.index("cache_seq")] != max_len:
            raise NotImplementedError(
                f"paged KV cache does not support windowed/ring caches "
                f"(leaf seq {leaf.shape[axes.index('cache_seq')]} != "
                f"max_len {max_len}); use the slot backend")


def gather_pages(cache: Any, tables: jnp.ndarray, block_axes: Any) -> Any:
    """Tree-wide page-table gather: paged cache -> dense per-slot view
    ``[..., n_slots_in_tables, M*block_size, ...]``. Host-side test/debug
    helper; the jitted paths gather leaf-wise inside attention."""
    def one(leaf, ax):
        if ax == 0:
            return gather_blocks(leaf, tables)
        assert ax == 1, "block axis beyond [layers] leading dim unsupported"
        return jax.vmap(lambda l: gather_blocks(l, tables))(leaf)
    return jax.tree.map(one, cache, block_axes)


class PagedCachePool:
    """Block-paged per-slot cache + slot/block/reservation bookkeeping.

    Device state: ``.cache`` (paged leaves, replaced functionally after
    each jitted step) and ``.tables_device()`` (the int32 page table the
    jitted programs index through). Host state: free lists, per-slot
    mapped/reserved counts, per-block refcounts, the prefix registry,
    lifetime counters.

    The **slot** API (`alloc`/`release`/`n_free`/`in_use`) matches
    `SlotCachePool`, so `SlotScheduler` drives either pool; the **block**
    API (`can_reserve`/`reserve`/`ensure_mapped`) is what makes admission
    ragged-aware; the **sharing** API (`share_prefix`/`register_prefix`/
    `ensure_writable`/`cow_clone`/`check_write_disjoint`) is what lets
    slots alias read-only prompt blocks safely.
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, n_blocks: int,
                 block_size: int, max_len: int, dtype=None,
                 oversubscribe: float = 1.0, swap_blocks: int = 0):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if block_size < 1 or n_blocks < 1:
            raise ValueError("block_size and n_blocks must be >= 1")
        if oversubscribe < 1.0:
            raise ValueError(f"oversubscribe must be >= 1.0, "
                             f"got {oversubscribe}")
        if swap_blocks < 0:
            raise ValueError(f"swap_blocks must be >= 0, got {swap_blocks}")
        validate_pageable(cfg, max_len)
        self.cfg = cfg
        self.n_slots = n_slots
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.max_len = max_len
        # logical blocks a single slot may address (covers max_len plus
        # one block of slack for padded-chunk clamping)
        self.max_blocks = math.ceil(max_len / block_size) + 1
        # physical storage: init_cache with batch = blocks gives exactly
        # the paged layout [n_blocks+1, block_size, ...] per leaf
        # (block 0 = trash)
        self.cache = tfm.init_cache(cfg, n_blocks + 1, block_size,
                                    dtype=dtype or cfg.cdtype())
        abstract = tfm.init_cache(cfg, n_blocks + 1, block_size,
                                  abstract=True)
        def _axes(a):
            b = a.logical_axes.index("batch")
            s = a.logical_axes.index("cache_seq")
            assert s == b + 1, "paged gather assumes [block, block_size] adjacency"
            return b
        self.block_axes = jax.tree.map(_axes, abstract, is_leaf=_is_abstract)

        # host bookkeeping
        self.tables = np.zeros((n_slots, self.max_blocks), np.int32)
        self.n_mapped = np.zeros(n_slots, np.int64)
        self.ref = np.zeros(n_blocks + 1, np.int64)  # per-block refcount
        self._owed = np.zeros(n_slots, np.int64)     # reserved, not yet mapped
        self._reserved_total = 0
        # free blocks: two min-heaps with a lazy-deletion membership set —
        # plain (never registered) preferred over cached (registered: a
        # retired prefix whose content is still intact). Deterministic:
        # lowest id first within each class.
        self._free_plain: List[int] = list(range(1, n_blocks + 1))
        self._free_cached: List[int] = []
        self._free_set: set = set(self._free_plain)
        self._free_slots: List[int] = list(range(n_slots))  # min-heap
        self._in_use: set = set()
        # prefix registry: chain key -> physical block, plus reverse map
        self._prefix_registry: Dict[bytes, int] = {}
        self._registered_key: Dict[int, bytes] = {}
        self.generations = [0] * n_slots
        self.peak_mapped = 0           # high-water PHYSICAL blocks in use
        self.shared_blocks_total = 0   # lifetime blocks mapped via sharing
        self.cow_clones = 0            # lifetime copy-on-write clones
        # oversubscription: virtual reservation budget (== n_blocks at
        # factor 1, where the classic free >= reserved invariant holds)
        self.oversubscribe = float(oversubscribe)
        self.virtual_blocks = int(round(n_blocks * self.oversubscribe))
        # host swap tier: chain key -> host copy of the block's cache
        # leaves (insertion-ordered for LRU eviction), bounded capacity
        self.swap_blocks = int(swap_blocks)
        self._swap: "OrderedDict[bytes, Any]" = OrderedDict()
        self.swap_outs = 0             # lifetime device -> host spills
        self.swap_ins = 0              # lifetime host -> device restores
        self._tables_dev = jnp.asarray(self.tables)
        self._tables_prefix_cache: dict = {}
        self._tables_dirty = False

    # -- capacity / accounting --------------------------------------------
    def blocks_for(self, n_tokens: int) -> int:
        """Physical blocks needed to store `n_tokens` cache entries."""
        return max(0, math.ceil(n_tokens / self.block_size))

    @property
    def n_free_blocks(self) -> int:
        return len(self._free_set)

    @property
    def n_physical_in_use(self) -> int:
        """Physical blocks currently allocated (refcount > 0). Shared
        blocks count ONCE — this is the footprint number."""
        return self.n_blocks - len(self._free_set)

    @property
    def n_mapped_total(self) -> int:
        """Total table entries over in-use slots (shared blocks count
        once per slot mapping them)."""
        return int(self.n_mapped.sum())

    def footprint_bytes(self) -> int:
        """Device bytes held by the paged cache (all physical blocks)."""
        return sum(l.nbytes for l in jax.tree.leaves(self.cache))

    # -- slot bookkeeping (SlotCachePool-compatible) ----------------------
    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def in_use(self) -> frozenset:
        return frozenset(self._in_use)

    def alloc(self) -> int:
        """Lowest-numbered free slot (deterministic placement)."""
        if not self._free_slots:
            raise RuntimeError("cache pool exhausted")
        slot = heapq.heappop(self._free_slots)
        self._in_use.add(slot)
        self.generations[slot] += 1
        return slot

    def release(self, slot: int,
                expected_generation: Optional[int] = None) -> None:
        """Free the slot: decrement its blocks' refcounts — only blocks
        that hit zero return to the free list (a block still shared by
        another slot lives on; a zero-ref block that is REGISTERED keeps
        its registry entry and goes to the cached free heap, reusable by
        a later same-prefix request until evicted) — drop its
        outstanding reservation, and zero its table row (so stale decode
        writes from the retired tenant land in the trash block).

        Double release is LOUD, never silent: releasing a slot that is
        not in use raises with the slot id (instead of pushing its
        blocks onto the free heap twice and corrupting refcounts), and
        `expected_generation` (the value of ``generations[slot]`` the
        caller captured at alloc) catches the nastier stale-release case
        where the slot was already re-allocated to a new tenant."""
        if slot not in self._in_use:
            raise RuntimeError(
                f"double release of slot {slot}: slot is not in use "
                f"(already released or never allocated) — a second "
                f"release would corrupt the free-block heap")
        if (expected_generation is not None
                and expected_generation != self.generations[slot]):
            raise RuntimeError(
                f"stale release of slot {slot}: caller holds generation "
                f"{expected_generation} but the slot was re-allocated "
                f"(now generation {self.generations[slot]}) — releasing "
                f"would free the new tenant's blocks")
        self._in_use.remove(slot)
        heapq.heappush(self._free_slots, slot)
        for m in range(int(self.n_mapped[slot])):
            blk = int(self.tables[slot, m])
            self.ref[blk] -= 1
            if self.ref[blk] == 0:
                self._push_free(blk)
        self._reserved_total -= int(self._owed[slot])
        self._owed[slot] = 0
        self.n_mapped[slot] = 0
        self.tables[slot] = 0
        self._tables_dirty = True

    # -- free-block heaps (lazy deletion) ----------------------------------
    def _push_free(self, blk: int) -> None:
        heap = (self._free_cached if blk in self._registered_key
                else self._free_plain)
        heapq.heappush(heap, blk)
        self._free_set.add(blk)

    def _pop_free(self) -> int:
        """Lowest-id unregistered free block, else evict (deregister) the
        lowest-id cached one — spilling its contents to the host swap
        store first when one is configured. Caller owns the block (ref
        set to 1). Exhaustion raises `BlockPressure` when oversubscribed
        (recoverable: the engine's pressure policy frees a victim), a
        hard RuntimeError otherwise (the reservation invariant makes it
        a bookkeeping bug)."""
        for heap in (self._free_plain, self._free_cached):
            while heap:
                blk = heapq.heappop(heap)
                if blk not in self._free_set:
                    continue                    # stale lazy-deleted entry
                self._free_set.remove(blk)
                key = self._registered_key.pop(blk, None)
                if key is not None:             # evict the cached prefix
                    del self._prefix_registry[key]
                    if self.swap_blocks > 0:
                        self._swap_out(blk, key)
                self.ref[blk] = 1
                return blk
        if self.virtual_blocks > self.n_blocks:
            raise BlockPressure(
                f"paged pool out of physical blocks ({self.n_blocks} "
                f"in use, {self._reserved_total} still reserved) under "
                f"oversubscription x{self.oversubscribe:g}")
        raise RuntimeError("paged pool out of blocks — reservation "
                           "invariant violated")

    def _take_free_block(self, slot: int) -> int:
        """Allocate one fresh block for `slot`, charged against its
        reservation — or, beyond it, against UNRESERVED virtual headroom.
        The over-map case raises rather than silently draining blocks
        that other slots' reservations are counting on. Reservation
        accounting is only charged AFTER the pop succeeds, so a
        `BlockPressure` raise leaves the books untouched and the caller
        can retry the same demand after relieving pressure."""
        charged = self._owed[slot] > 0
        if not charged and (self.n_physical_in_use + 1
                            + self._reserved_total > self.virtual_blocks):
            raise RuntimeError(
                f"slot {slot} mapping beyond its reservation would leave "
                f"free ({len(self._free_set) - 1}) < reserved "
                f"({self._reserved_total}) — raise n_blocks or reserve "
                f"the slack explicitly")
        blk = self._pop_free()
        if charged:
            self._owed[slot] -= 1
            self._reserved_total -= 1
        self.peak_mapped = max(self.peak_mapped, self.n_physical_in_use)
        return blk

    # -- block reservation / mapping --------------------------------------
    def can_reserve(self, n_tokens: int) -> bool:
        """True if a request needing `n_tokens` total cache entries can be
        admitted within the (possibly oversubscribed) reservation budget:
        ``physical_in_use + reserved_total + need <= virtual_blocks``.
        At ``oversubscribe == 1`` this is exactly the classic
        ``free - reserved >= need`` check, under which an admitted
        request can never starve; beyond 1 it is a bet that `ensure_
        mapped` may lose (`BlockPressure`) and the engine must cover."""
        return (self.n_physical_in_use + self._reserved_total
                + self.blocks_for(n_tokens) <= self.virtual_blocks)

    def reserve(self, slot: int, n_tokens: int) -> None:
        """Reserve the slot's worst-case block count. Must hold
        `can_reserve(n_tokens)`; blocks are mapped later by
        `ensure_mapped` (or aliased by `share_prefix`, which returns the
        matched blocks' share of this reservation)."""
        need = self.blocks_for(n_tokens)
        if (self.n_physical_in_use + self._reserved_total + need
                > self.virtual_blocks):
            raise RuntimeError("paged pool over-reserved: admission must "
                               "check can_reserve() first")
        self._owed[slot] = need
        self._reserved_total += need

    def ensure_mapped(self, slot: int, n_tokens: int) -> int:
        """Map blocks until the slot covers `n_tokens` logical cache
        entries. At ``oversubscribe == 1`` this never fails for demands
        within the slot's reservation (the free list always holds >=
        reserved blocks); oversubscribed pools may raise `BlockPressure`
        mid-way — already-mapped progress is kept and the call is
        idempotent, so the caller retries the same demand after its
        pressure policy frees blocks. Returns the number of newly
        mapped blocks."""
        need = self.blocks_for(n_tokens)
        newly = 0
        while int(self.n_mapped[slot]) < need:
            blk = self._take_free_block(slot)
            m = int(self.n_mapped[slot])
            self.tables[slot, m] = blk
            self.n_mapped[slot] += 1
            newly += 1
        if newly:
            self._tables_dirty = True
        return newly

    # -- host swap tier -----------------------------------------------------
    def _swap_out(self, blk: int, key: bytes) -> None:
        """Spill an evicted cached block's contents to the host store
        before its device block is handed to a new owner. The store is
        an LRU over chain keys bounded by `swap_blocks`; the copy is a
        plain `np.asarray` pull of every cache leaf's block row (jax
        dispatches the device->host transfers asynchronously; the arrays
        materialize lazily on first host access)."""
        def one(leaf, ax):
            if ax == 0:
                return np.asarray(leaf[blk])
            return np.asarray(leaf[:, blk])
        self._swap[key] = jax.tree.map(one, self.cache, self.block_axes)
        self._swap.move_to_end(key)
        self.swap_outs += 1
        while len(self._swap) > self.swap_blocks:
            self._swap.popitem(last=False)      # LRU: drop the coldest

    def _swap_in(self, slot: int, key: bytes) -> int:
        """Restore a swapped-out prefix block: allocate a device block
        (charged to `slot`'s reservation like a fresh mapping — may
        raise `BlockPressure`), copy the host contents back, and
        re-register the chain key. Returns the new physical id with
        ref already set to 1."""
        blk = self._take_free_block(slot)        # BlockPressure-able
        host = self._swap.pop(key)

        def one(leaf, hv, ax):
            if ax == 0:
                return leaf.at[blk].set(hv)
            return leaf.at[:, blk].set(hv)
        self.cache = jax.tree.map(one, self.cache, host, self.block_axes)
        self._prefix_registry[key] = blk
        self._registered_key[blk] = key
        self.swap_ins += 1
        return blk

    def save_block_span(self, slot: int, lo: int, hi: int) -> list:
        """Host snapshot of the physical blocks covering token span
        [lo, hi) of `slot` (whole blocks; `lo` rounds down to a block
        boundary). The preemption path uses this for the DECODE-written
        region of a victim's cache: decode-written K/V is not bit-
        identical to a prefill recompute of the same positions (different
        reduction shapes), so those bytes must survive preemption
        verbatim — unlike prompt blocks, which chunked prefill recomputes
        bit-exactly. Returns an opaque list for `restore_block_span`."""
        if hi <= lo:
            return []
        saved = []
        for m in range(lo // self.block_size,
                       (hi - 1) // self.block_size + 1):
            blk = int(self.tables[slot, m])

            def one(leaf, ax, blk=blk):
                if ax == 0:
                    return np.asarray(leaf[blk])
                return np.asarray(leaf[:, blk])
            saved.append(jax.tree.map(one, self.cache, self.block_axes))
        return saved

    def restore_block_span(self, slot: int, lo: int, hi: int,
                           saved: list) -> None:
        """Write a `save_block_span` snapshot back over the SAME token
        span of `slot`'s (re-mapped) table. The span's blocks must be
        mapped and write-private — the resume path maps them fresh, so
        they are; restoring over a shared or registered block would
        corrupt another reader."""
        if hi <= lo:
            return
        ms = range(lo // self.block_size, (hi - 1) // self.block_size + 1)
        for m, host in zip(ms, saved):
            blk = int(self.tables[slot, m])
            assert blk > 0 and self.ref[blk] == 1 \
                and blk not in self._registered_key, \
                (f"restore_block_span: slot {slot} block {m} (phys {blk}) "
                 f"is not a private mapped block")

            def one(leaf, hv, ax, blk=blk):
                if ax == 0:
                    return leaf.at[blk].set(hv)
                return leaf.at[:, blk].set(hv)
            self.cache = jax.tree.map(one, self.cache, host,
                                      self.block_axes)

    # -- prefix sharing / copy-on-write ------------------------------------
    def share_prefix(self, slot: int, tokens: np.ndarray) -> int:
        """Map the longest registered prefix of `tokens` into `slot`'s
        table without prefilling it. Must run right after `reserve`,
        before any `ensure_mapped` for the slot (shared blocks occupy
        the leading table entries). Returns the number of prompt tokens
        covered (a multiple of `block_size`; the caller starts prefill
        at the first unshared token).

        Matched blocks that are still refcounted are aliased (ref+1);
        matched blocks sitting cached on the free list are resurrected
        (ref 0 -> 1, leaving the free list, charged to the slot's
        reservation like a fresh mapping); keys missing from the device
        registry but present in the host swap store are swapped back in
        (`_swap_in` — on `BlockPressure` matching simply stops at the
        blocks already recovered). Aliased blocks give their reservation
        back — minus ONE block of slack when the prompt is fully shared
        with an aliased tail, so the worst-case `cow_clone` (a
        fully-shared prompt recomputes its final token in place) can
        never fail. Partial shares restart prefill at a block boundary
        and never write shared blocks, so they keep no slack."""
        assert int(self.n_mapped[slot]) == 0, \
            "share_prefix needs an empty table row"
        keys = prefix_block_keys(tokens, self.block_size)
        shared = 0
        aliased = 0
        for m, key in enumerate(keys):
            blk = self._prefix_registry.get(key)
            if blk is None:
                if self.swap_blocks <= 0 or key not in self._swap:
                    break
                try:
                    blk = self._swap_in(slot, key)
                except BlockPressure:
                    break        # keep what we recovered; caller prefills
            elif self.ref[blk] == 0:
                # cached free block: resurrect (consumes a free block,
                # so it is charged like a fresh mapping — or, past the
                # reservation, only within virtual headroom)
                if self._owed[slot] <= 0 and (
                        self.n_physical_in_use + 1 + self._reserved_total
                        > self.virtual_blocks):
                    break
                self._free_set.remove(blk)
                if self._owed[slot] > 0:
                    self._owed[slot] -= 1
                    self._reserved_total -= 1
                self.ref[blk] = 1
            else:
                self.ref[blk] += 1
                aliased += 1
            self.tables[slot, m] = blk
            self.n_mapped[slot] += 1
            shared += 1
        if shared:
            # aliased blocks consumed no free block: return their owed
            # share. Only a FULLY-shared prompt can ever CoW-clone (its
            # final token is recomputed inside the last shared block; a
            # partial share restarts prefill at a block boundary, so no
            # write ever targets a shared block) — withhold one owed
            # block of slack exactly when that clone is possible: full
            # cover AND a tail block that is still aliased (ref > 1).
            give = aliased
            if (shared * self.block_size >= len(tokens)
                    and self.ref[int(self.tables[slot, shared - 1])] > 1):
                give -= 1
            give = min(max(give, 0), int(self._owed[slot]))
            self._owed[slot] -= give
            self._reserved_total -= give
            self.shared_blocks_total += shared
            self._tables_dirty = True
            self.peak_mapped = max(self.peak_mapped, self.n_physical_in_use)
        return shared * self.block_size

    def register_prefix(self, slot: int, tokens: np.ndarray) -> int:
        """Publish `slot`'s fully-prefilled prompt blocks so later
        requests can `share_prefix` them. Call once the prompt's K/V
        writes have all been dispatched (the engine does it at
        prefill-done). First registration of a key wins; blocks whose
        content chain is already registered (e.g. the donor's own shared
        prefix, or a CoW clone that rewrote identical values) are
        skipped. Returns how many entries were added."""
        n = 0
        for m, key in enumerate(prefix_block_keys(tokens, self.block_size)):
            if m >= int(self.n_mapped[slot]):
                break
            blk = int(self.tables[slot, m])
            if key in self._prefix_registry or blk in self._registered_key:
                continue
            self._prefix_registry[key] = blk
            self._registered_key[blk] = key
            # a device registration supersedes any stale host copy (swap
            # keys and registry keys stay disjoint)
            self._swap.pop(key, None)
            n += 1
        return n

    def cow_clone(self, slot: int, m: int) -> int:
        """Copy-on-write: replace `slot`'s logical block `m` — currently
        aliased by another slot — with a private copy of its contents.
        The clone is charged to the slot's reservation (see
        `share_prefix`'s CoW slack); the original keeps its refcount
        minus one and its registry entry. Returns the new physical id."""
        old = int(self.tables[slot, m])
        assert old > 0 and self.ref[old] > 1, \
            f"cow_clone: slot {slot} block {m} (phys {old}) is not shared"
        new = self._take_free_block(slot)
        self.ref[old] -= 1
        self.tables[slot, m] = new
        self._tables_dirty = True
        self.cow_clones += 1

        def copy(leaf, ax):
            if ax == 0:
                return leaf.at[new].set(leaf[old])
            return leaf.at[:, new].set(leaf[:, old])
        self.cache = jax.tree.map(copy, self.cache, self.block_axes)
        return new

    def ensure_writable(self, slot: int, lo: int, hi: int) -> int:
        """CoW-clone every mapped block of `slot` holding a logical
        position in [lo, hi) whose refcount exceeds one. Writes beyond
        the mapped frontier land in the trash block and need no clone.
        Call before ANY write dispatch targeting those positions —
        afterwards the slot's writable table entries are private.
        Returns the number of clones made."""
        if hi <= lo:
            return 0
        m_lo = lo // self.block_size
        m_hi = min((hi - 1) // self.block_size, int(self.n_mapped[slot]) - 1)
        n = 0
        for m in range(m_lo, m_hi + 1):
            blk = int(self.tables[slot, m])
            if blk > 0 and self.ref[blk] > 1:
                self.cow_clone(slot, m)
                n += 1
        return n

    def check_write_disjoint(self,
                             ranges: Iterable[Tuple[int, int, int]]) -> None:
        """Assert that the physical blocks writable by a single dispatch
        are pairwise disjoint across rows. `ranges` is (slot, lo, hi)
        token spans about to be written (one per dispatch row). Both
        paged write paths — the XLA `_paged_write` scatter and the
        input/output-aliased `paged_write_token` kernel — assume this;
        an aliased writable block means a missed `ensure_writable` and
        would silently corrupt a neighbor's cache. Trash-block targets
        (unmapped tail positions) are exempt."""
        owner: Dict[int, int] = {}
        for slot, lo, hi in ranges:
            if hi <= lo:
                continue
            m_hi = min((hi - 1) // self.block_size,
                       int(self.n_mapped[slot]) - 1)
            for m in range(lo // self.block_size, m_hi + 1):
                blk = int(self.tables[slot, m])
                if blk == 0:
                    continue
                prev = owner.get(blk)
                if prev is not None and prev != slot:
                    raise RuntimeError(
                        f"paged write aliasing: block {blk} is writable "
                        f"from slots {prev} and {slot} in one dispatch — "
                        f"CoW guard failed (ensure_writable not called?)")
                owner[blk] = slot

    # -- observability ------------------------------------------------------
    @property
    def n_shared_blocks(self) -> int:
        """Physical blocks currently aliased by more than one slot."""
        return int((self.ref > 1).sum())

    @property
    def n_cached_blocks(self) -> int:
        """Zero-ref blocks still holding a registered prefix (reusable
        by a later same-prefix request until evicted)."""
        return sum(1 for b in self._registered_key if b in self._free_set)

    @property
    def n_swapped_blocks(self) -> int:
        """Prefix blocks currently living only in the host swap store."""
        return len(self._swap)

    def register_metrics(self, reg) -> None:
        """Expose pool occupancy as pull-mode gauges on a
        `MetricsRegistry` — callbacks are evaluated only at scrape or
        render time, so the allocation hot paths pay nothing."""
        g = reg.gauge("serving_pool_blocks",
                      "paged KV pool physical blocks by state", ("kind",))
        g.labels(kind="total").set_fn(lambda: self.n_blocks)
        g.labels(kind="free").set_fn(lambda: len(self._free_set))
        g.labels(kind="reserved").set_fn(lambda: self._reserved_total)
        g.labels(kind="in_use").set_fn(lambda: self.n_physical_in_use)
        g.labels(kind="refcounted").set_fn(lambda: self.n_shared_blocks)
        g.labels(kind="cached").set_fn(lambda: self.n_cached_blocks)
        g.labels(kind="peak").set_fn(lambda: self.peak_mapped)
        g.labels(kind="virtual").set_fn(lambda: self.virtual_blocks)
        g.labels(kind="swapped").set_fn(lambda: self.n_swapped_blocks)
        reg.gauge("serving_pool_cow_clones_total",
                  "lifetime copy-on-write block clones",
                  fn=lambda: self.cow_clones)
        reg.gauge("serving_pool_shared_blocks_total",
                  "lifetime blocks mapped via prefix sharing",
                  fn=lambda: self.shared_blocks_total)
        s = reg.gauge("serving_pool_swap_total",
                      "lifetime host swap-tier transfers", ("dir",))
        s.labels(dir="out").set_fn(lambda: self.swap_outs)
        s.labels(dir="in").set_fn(lambda: self.swap_ins)

    def active_prefix_blocks(self, n_tokens: int) -> int:
        """Logical blocks needed to cover `n_tokens` cache entries,
        bucketed UP to a power of two (and clamped to `max_blocks`) so
        table-prefix slicing compiles only O(log max_blocks) shapes.
        The decode paths gather/walk only this prefix instead of all
        `max_blocks` table entries — the active-prefix tightening."""
        return min(next_pow2(self.blocks_for(n_tokens)), self.max_blocks)

    def tables_device(self, prefix: Optional[int] = None) -> jnp.ndarray:
        """Device copy of the page table, refreshed only when the host
        table changed since the last call. `prefix` returns only the
        first `prefix` logical-block columns (see
        `active_prefix_blocks`); each distinct prefix is cached until
        the next table mutation."""
        if self._tables_dirty:
            self._tables_dev = jnp.asarray(self.tables)
            self._tables_prefix_cache = {}
            self._tables_dirty = False
        if prefix is None or prefix >= self.max_blocks:
            return self._tables_dev
        got = self._tables_prefix_cache.get(prefix)
        if got is None:
            got = jnp.asarray(self.tables[:, :prefix])
            self._tables_prefix_cache[prefix] = got
        return got

    # -- invariants (tests) ------------------------------------------------
    def check_invariants(self) -> None:
        counts = np.zeros(self.n_blocks + 1, np.int64)
        for s in range(self.n_slots):
            row = [int(self.tables[s, m])
                   for m in range(int(self.n_mapped[s]))]
            assert 0 not in row, "trash block mapped"
            assert len(row) == len(set(row)), \
                f"slot {s} maps a block twice in its own row"
            for b in row:
                counts[b] += 1
        assert (counts[1:] == self.ref[1:]).all(), \
            "refcount drift: ref[] != table-row mapping counts"
        mapped = {b for b in range(1, self.n_blocks + 1) if self.ref[b] > 0}
        free = set(self._free_set)
        assert mapped.isdisjoint(free), "free block still referenced"
        assert mapped | free == set(range(1, self.n_blocks + 1)), \
            "blocks leaked"
        if self.virtual_blocks == self.n_blocks:
            assert len(free) >= self._reserved_total >= 0, \
                "reservation exceeds free blocks"
        else:
            assert self._reserved_total >= 0
        # the generalized (oversubscription-aware) reservation invariant;
        # reduces to the classic free >= reserved at factor 1
        assert (self.n_physical_in_use + self._reserved_total
                <= self.virtual_blocks), \
            "physical_in_use + reserved exceeds the virtual budget"
        for key, blk in self._prefix_registry.items():
            assert self._registered_key.get(blk) == key, \
                "registry / reverse-map mismatch"
            assert blk in mapped or blk in free  # always true, documents it
        assert len(self._registered_key) == len(self._prefix_registry)
        # host swap tier: bounded, key-disjoint from the device registry,
        # and resident-free — a swapped-out prefix owns NO physical block,
        # so no slot's table row (hence no decode/prefill read) can ever
        # touch one
        assert len(self._swap) <= max(self.swap_blocks, 0), \
            "swap store exceeds its capacity"
        assert not (set(self._swap) & set(self._prefix_registry)), \
            "chain key registered on device AND swapped to host"
        for s in range(self.n_slots):
            if s not in self._in_use:
                assert (self.tables[s] == 0).all(), \
                    f"free slot {s} holds mapped blocks"
        assert len(self._in_use) + len(self._free_slots) == self.n_slots
