"""`SocketBackend`: the `LargeBackend` protocol over a real socket.

The engine-facing contract is identical to the in-process backends
(`submit/poll/flush/drain/close`, `n_pending`, `batch_log`); transport
is the length-prefixed JSON RPC of `remote.wire` against one
`remote.server.MLServer`. Reliability machinery on top of the raw RPC:

  * **connect/request timeouts** — `connect_timeout` bounds the TCP
    connect + hello handshake, `request_timeout` bounds every RPC; a
    server that stops answering turns into a retry, not a hang.
  * **bounded exponential-backoff retry** — a failed RPC reconnects and
    resends up to `retries` times (`backoff * 2**attempt`, capped at
    `backoff_max`), then raises with the full context. Retried submits
    are deduplicated server-side by rid (the session id survives
    reconnects); retried polls are safe because results stay buffered
    server-side until acknowledged by the NEXT poll.
  * **per-request cancellation** — `close()` (the engine's shutdown
    path, including mid-run exceptions) best-effort cancels every
    in-flight rid on the server before saying goodbye, so an aborted
    run doesn't leave the server generating for nobody.

`batch_log` is reconstructed from result metadata (batches are cut
server-side), so engine stats (`ml_batches`, `ml_batch_occupancy`) work
unchanged. Retry/reconnect counters land in the metrics registry
(`serving_ml_rpc_retries_total`, `serving_ml_reconnects_total`).
"""
from __future__ import annotations

import os
import socket
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.large_backend import LargeResult
from repro.serving.remote import wire
from repro.serving.request import Request


class RemoteBackendError(RuntimeError):
    """The remote M_L tier failed in a way retry can't fix (protocol
    rejection, retries exhausted, all replicas dead)."""


def parse_address(addr: Any) -> Tuple[str, int]:
    """Accept ('host', port) tuples or 'host:port' strings."""
    if isinstance(addr, str):
        host, _, port = addr.rpartition(":")
        if not host or not port.isdigit():
            raise ValueError(f"address must be 'host:port', got {addr!r}")
        return host, int(port)
    host, port = addr
    return str(host), int(port)


class SocketBackend:
    """`LargeBackend` over a socket RPC connection to one `MLServer`."""

    name = "socket"

    # the engine's final-drain watchdog: no progress for this long while
    # deferrals are pending is a hard error, not an infinite spin
    drain_stall_timeout = 60.0

    def __init__(self, address, *,
                 connect_timeout: float = 2.0,
                 request_timeout: float = 30.0,
                 retries: int = 3,
                 backoff: float = 0.05,
                 backoff_max: float = 1.0,
                 registry=None):
        self.address = parse_address(address)
        self.connect_timeout = connect_timeout
        self.request_timeout = request_timeout
        self.retries = retries
        self.backoff = backoff
        self.backoff_max = backoff_max
        self._session = os.urandom(8).hex()
        self._lock = threading.RLock()
        self._sock: Optional[socket.socket] = None
        self._closed = False
        self._n_tickets = 0                # guarded_by: self._lock
        # rid -> prompt of every submitted-but-unreturned request (the
        # replica pool re-dispatches from this on ejection)
        self._inflight: Dict[int, np.ndarray] = {}  # guarded_by: self._lock
        # delivered-not-yet-acked / ever-delivered (dup guard)
        self._unacked: List[int] = []      # guarded_by: self._lock
        self._returned: set = set()        # guarded_by: self._lock
        self.batch_log: List[Dict[str, Any]] = []   # guarded_by: self._lock
        self._batches_seen: set = set()    # guarded_by: self._lock

        self._m_retries = self._m_reconnects = None
        if registry is not None:
            self._m_retries = registry.counter(
                "serving_ml_rpc_retries_total",
                "M_L socket RPCs retried after timeout/connection error")
            self._m_reconnects = registry.counter(
                "serving_ml_reconnects_total",
                "M_L socket reconnects (incl. the initial connect)")
            registry.gauge("serving_ml_queue_depth",
                           "requests submitted to the M_L backend and "
                           "not yet returned",
                           fn=lambda: self.n_pending)
        self._connect()

    # -- connection management ---------------------------------------------
    def _connect(self) -> None:
        """(Re)connect + hello handshake, with bounded backoff. The
        session id is stable across reconnects, so server state (rid
        dedupe, undelivered results) survives a flaky link."""
        last: Optional[BaseException] = None
        for attempt in range(self.retries + 1):
            try:
                s = socket.create_connection(self.address,
                                             timeout=self.connect_timeout)
                s.settimeout(self.request_timeout)
                wire.send_frame(s, wire.envelope("hello",
                                                 session=self._session))
                reply = wire.recv_frame(s)
                if reply is None:
                    raise wire.WireError("server closed during hello")
                wire.check_schema(reply)
                if reply["kind"] == "error":
                    raise RemoteBackendError(
                        f"M_L server at {self.address[0]}:"
                        f"{self.address[1]} rejected hello: "
                        f"{reply.get('error')}")
                self._sock = s
                if self._m_reconnects is not None:
                    self._m_reconnects.inc()
                return
            except RemoteBackendError:
                raise
            except (OSError, wire.WireError) as e:
                last = e
                self._drop_socket()
                if attempt < self.retries:
                    time.sleep(min(self.backoff * (2 ** attempt),
                                   self.backoff_max))
        raise ConnectionError(
            f"cannot reach M_L server at {self.address[0]}:"
            f"{self.address[1]} after {self.retries + 1} attempts "
            f"({last!r}) — is it running? Start one with: "
            f"python -m repro.launch.ml_server --port {self.address[1]}")

    def _drop_socket(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def _rpc(self, msg: Dict[str, Any], timeout: Optional[float] = None,
             attempts: Optional[int] = None) -> Dict[str, Any]:
        """One request/response exchange with reconnect-and-resend retry.
        Identical resends are safe: submits dedupe by rid server-side,
        polls re-deliver unacknowledged results. A server-sent `error`
        frame raises RemoteBackendError immediately (retry can't fix a
        protocol rejection)."""
        attempts = (self.retries + 1) if attempts is None else attempts
        last: Optional[BaseException] = None
        with self._lock:
            for attempt in range(attempts):
                try:
                    if self._sock is None:
                        self._connect()
                    self._sock.settimeout(timeout or self.request_timeout)
                    wire.send_frame(self._sock, msg)
                    reply = wire.recv_frame(self._sock)
                    if reply is None:
                        raise wire.WireError(
                            "server closed the connection mid-RPC")
                    wire.check_schema(reply)
                    if reply["kind"] == "error":
                        raise RemoteBackendError(
                            f"M_L server rejected {msg['kind']} "
                            f"(rid={reply.get('rid')}): "
                            f"{reply.get('error')}")
                    return reply
                except (RemoteBackendError, ConnectionError):
                    raise
                except (OSError, wire.WireError) as e:
                    last = e
                    self._drop_socket()
                    if self._m_retries is not None:
                        self._m_retries.inc()
                    if attempt < attempts - 1:
                        time.sleep(min(self.backoff * (2 ** attempt),
                                       self.backoff_max))
        raise RemoteBackendError(
            f"M_L RPC {msg.get('kind')!r} to {self.address[0]}:"
            f"{self.address[1]} failed after {attempts} attempts: {last!r}")

    # -- LargeBackend protocol ----------------------------------------------
    def submit(self, requests: List[Request]) -> int:
        if self._closed:
            raise RuntimeError("backend is closed")
        payload = [wire.encode_request(r.rid, r.prompt) for r in requests]
        with self._lock:
            for r in requests:
                self._inflight[r.rid] = np.asarray(r.prompt, np.int32)
            self._rpc(wire.envelope("submit", reqs=payload))
            self._n_tickets += 1
            return self._n_tickets

    def poll(self, timeout: Optional[float] = None) -> List[LargeResult]:
        """Completed regenerations so far. `timeout` asks the server to
        hold the poll open up to that long for the first result (one
        round trip either way)."""
        with self._lock:
            if not self._inflight:
                return []
            msg = wire.envelope("poll", ack=list(self._unacked),
                                wait=float(timeout or 0.0))
            reply = self._rpc(msg,
                              timeout=self.request_timeout
                              + float(timeout or 0.0))
            self._unacked = []
            out: List[LargeResult] = []
            for d in reply.get("results", ()):
                res = wire.decode_result(d)
                self._unacked.append(res.rid)
                if res.rid in self._returned or \
                        res.rid not in self._inflight:
                    continue                  # duplicate delivery
                self._returned.add(res.rid)
                del self._inflight[res.rid]
                self._log_batch(res)
                out.append(res)
            return out

    def flush(self) -> None:
        self._rpc(wire.envelope("flush"))

    def drain(self) -> List[LargeResult]:
        self.flush()
        out: List[LargeResult] = []
        t_last = time.perf_counter()
        while self.n_pending:
            got = self.poll(timeout=0.05)
            out.extend(got)
            if got:
                t_last = time.perf_counter()
            elif time.perf_counter() - t_last > self.drain_stall_timeout:
                raise RemoteBackendError(
                    f"M_L drain stalled: {self.n_pending} requests "
                    f"pending at {self.address[0]}:{self.address[1]} "
                    f"with no progress for {self.drain_stall_timeout}s")
        return out

    def close(self) -> None:
        """Engine shutdown: cancel whatever is still in flight on the
        server (best-effort — the server may already be gone), then
        close the connection."""
        if self._closed:
            return
        self._closed = True
        with self._lock:
            try:
                if self._inflight:
                    self._rpc(wire.envelope(
                        "cancel", rids=[int(r) for r in self._inflight]),
                        attempts=1)
                if self._sock is not None:
                    self._rpc(wire.envelope("bye"), attempts=1)
            except (RemoteBackendError, ConnectionError, OSError):
                pass
            self._drop_socket()

    @property
    def n_pending(self) -> int:
        # read from the pool's health/metrics paths while poll() mutates
        # _inflight on the engine thread — must snapshot under the lock
        with self._lock:
            return len(self._inflight)

    # -- replica-pool hooks --------------------------------------------------
    def healthy(self) -> bool:
        """One cheap health RPC, no retries — the pool's ejection
        decision wants fast failure, not patience."""
        try:
            reply = self._rpc(wire.envelope("health"), timeout=1.0,
                              attempts=1)
            return reply["kind"] == "ok"
        except (RemoteBackendError, ConnectionError, OSError):
            return False

    def take_inflight(self) -> List[Tuple[int, np.ndarray]]:
        """Hand back (and forget) every in-flight request — the pool
        re-dispatches these to surviving replicas on ejection."""
        with self._lock:
            out = [(rid, prompt) for rid, prompt in self._inflight.items()]
            self._inflight = {}
            return out

    def _log_batch(self, res: LargeResult) -> None:  # guarded_by: self._lock
        if res.batch_id not in self._batches_seen:
            self._batches_seen.add(res.batch_id)
            self.batch_log.append({
                "batch_id": res.batch_id, "n_real": res.n_real,
                "pad_to": res.pad_to, "reason": res.reason,
                "prompt_len": res.prompt_len})
