"""Distributed M_L tier: socket RPC server, client backend, replica pool.

`wire` pins the length-prefixed JSON protocol (schema-versioned);
`MLServer` is the server process (entrypoint: `repro.launch.ml_server`);
`SocketBackend` speaks the `LargeBackend` protocol over one connection;
`ReplicaPool` load-balances N replicas with health checks, ejection and
in-flight re-dispatch. See docs/serving.md ("Distributed M_L tier").
"""
from repro.serving.remote import wire
from repro.serving.remote.client import (RemoteBackendError, SocketBackend,
                                         parse_address)
from repro.serving.remote.pool import ReplicaPool
from repro.serving.remote.server import MLServer

SCHEMA_VERSION = wire.SCHEMA_VERSION

__all__ = [
    "MLServer",
    "ReplicaPool",
    "RemoteBackendError",
    "SCHEMA_VERSION",
    "SocketBackend",
    "parse_address",
    "wire",
]
