"""`ReplicaPool`: N M_L replicas behind one `LargeBackend`.

Each replica is a `SocketBackend` talking to its own `MLServer` process;
the pool presents the same `submit/poll/flush/drain/close` surface the
engine already speaks, adding:

  * **load balancing** — batch-aware: when `large_batch` is known, a
    prompt-length group *sticks* to one replica until `large_batch`
    requests have been routed there, then the next group opens on the
    least-loaded healthy replica. Sticky routing matters: the engine
    streams deferrals one at a time, and spreading them least-loaded
    would mean no replica's server-side group ever fills — every batch
    would wait out `max_wait` and 2 replicas would have *worse*
    deferral-wait tails than 1. With sticky routing each replica's
    group fills at the single-server rate and consecutive batches
    land on different replicas, overlapping their `generate` calls.
    Without `large_batch` the pool falls back to pure least-loaded.
    Either way batch shapes are cut server-side by each replica's
    `BatchPolicy`, exactly as with one server, so greedy outputs stay
    bit-exact.
  * **health checks + ejection** — every `health_interval` seconds a
    poll cycle health-probes all live replicas; a replica that fails
    its probe (or any RPC) is ejected and never contacted again.
  * **re-dispatch** — an ejected replica's in-flight requests
    (`SocketBackend.take_inflight`) are resubmitted to the survivors,
    so a replica dying mid-batch delays its deferrals instead of
    dropping them. Because an ejected replica is never polled again, a
    spuriously-ejected (alive) replica can waste work but can never
    deliver a duplicate result.

When the last replica dies with work still in flight the pool raises
`RemoteBackendError` — loud failure, not a silent hang; the engine's
drain watchdog turns that into a run abort with the pending count.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.large_backend import LargeResult
from repro.serving.remote.client import (RemoteBackendError, SocketBackend,
                                         parse_address)
from repro.serving.request import Request

_RPC_ERRORS = (RemoteBackendError, ConnectionError, OSError)


class ReplicaPool:
    """`LargeBackend` that load-balances across N `MLServer` replicas."""

    name = "pool"

    drain_stall_timeout = 60.0

    def __init__(self, addresses: Sequence[Any], *,
                 connect_timeout: float = 2.0,
                 request_timeout: float = 30.0,
                 retries: int = 3,
                 backoff: float = 0.05,
                 backoff_max: float = 1.0,
                 health_interval: float = 2.0,
                 max_new: Optional[int] = None,
                 large_batch: Optional[int] = None,
                 registry=None):
        if not addresses:
            raise ValueError("ReplicaPool needs at least one address")
        self.health_interval = health_interval
        self.max_new = max_new or 0        # for re-dispatched Requests
        self.large_batch = large_batch
        # prompt_len -> (replica idx, requests routed into the open
        # group); the sticky state behind batch-aware routing
        self._route: Dict[int, Tuple[int, int]] = {}  # guarded_by: self._lock
        self._lock = threading.RLock()
        self._flushed = False              # guarded_by: self._lock
        self._closed = False
        self._n_tickets = 0                # guarded_by: self._lock
        self._last_health = time.perf_counter()  # guarded_by: self._lock
        # replicas hold their own retry/timeout machinery; metrics are
        # registered pool-level (per-client registration would collide
        # on the single-backend gauge names)
        self.replicas: List[SocketBackend] = [
            SocketBackend(parse_address(a),
                          connect_timeout=connect_timeout,
                          request_timeout=request_timeout,
                          retries=retries, backoff=backoff,
                          backoff_max=backoff_max)
            for a in addresses]
        self._alive = [True] * len(self.replicas)  # guarded_by: self._lock

        self._m_ejections = self._m_health = self._m_redispatch = None
        if registry is not None:
            self._m_ejections = registry.counter(
                "serving_ml_replica_ejections_total",
                "M_L replicas ejected from the pool after a failed RPC "
                "or health check")
            self._m_health = registry.counter(
                "serving_ml_health_checks_total",
                "periodic M_L replica health probes issued")
            self._m_redispatch = registry.counter(
                "serving_ml_redispatched_requests_total",
                "in-flight requests re-dispatched off a dead replica")
            registry.gauge("serving_ml_queue_depth",
                           "requests submitted to the M_L backend and "
                           "not yet returned",
                           fn=lambda: self.n_pending)
            depth = registry.gauge(
                "serving_ml_replica_queue_depth",
                "per-replica requests in flight", labels=("replica",))
            for i, r in enumerate(self.replicas):
                depth.labels(replica=str(i)).set_fn(
                    lambda r=r: r.n_pending)

    # -- replica management --------------------------------------------------
    # (every helper below runs with the pool lock held by its caller)
    def _alive_replicas(self):  # guarded_by: self._lock
        return [(i, r) for i, r in enumerate(self.replicas)
                if self._alive[i]]

    def _eject(self, idx: int, why: str) -> None:  # guarded_by: self._lock
        """Remove a replica and re-dispatch its in-flight requests to the
        survivors. Raises when it held work and no survivor remains."""
        if not self._alive[idx]:
            return
        self._alive[idx] = False
        if self._m_ejections is not None:
            self._m_ejections.inc()
        replica = self.replicas[idx]
        orphans = replica.take_inflight()
        try:
            replica.close()
        except _RPC_ERRORS:
            pass
        if not orphans:
            return
        if self._m_redispatch is not None:
            self._m_redispatch.inc(len(orphans))
        redo = [Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                        max_new=self.max_new)
                for rid, prompt in orphans]
        self._submit_balanced(redo)     # raises if nobody is left
        if self._flushed:
            # the dead replica may have been mid-drain; survivors must
            # cut the re-dispatched work immediately, not wait for more
            self._flush_alive()

    def _pick_replica(self, plen, n):  # guarded_by: self._lock
        """Choose a live replica for `n` requests of prompt length
        `plen`: sticky while the current group has room (batch-aware),
        least-loaded when a new group opens or `large_batch` is unset."""
        alive = self._alive_replicas()
        if not alive:
            raise RemoteBackendError(
                f"all {len(self.replicas)} M_L replicas are dead "
                f"with {n} request(s) unplaced")
        lb = self.large_batch
        if not lb:
            return min(alive, key=lambda ir: ir[1].n_pending)
        ent = self._route.get(plen)
        if ent is not None and self._alive[ent[0]] and ent[1] + n <= lb:
            idx, count = ent[0], ent[1] + n
        else:
            idx, _ = min(alive, key=lambda ir: ir[1].n_pending)
            count = min(n, lb)
        if count >= lb:      # group full: next submit opens a new one
            self._route.pop(plen, None)
        else:
            self._route[plen] = (idx, count)
        return idx, self.replicas[idx]

    def _submit_balanced(self, requests):  # guarded_by: self._lock
        """Place requests on live replicas (grouped by prompt length so
        sticky routing can fill server-side batches), ejecting and
        retrying on failure until someone accepts them or nobody is
        left."""
        groups: Dict[int, List[Request]] = {}
        for r in requests:
            groups.setdefault(int(r.prompt_len), []).append(r)
        for plen, group in groups.items():
            while True:
                idx, replica = self._pick_replica(plen, len(group))
                try:
                    replica.submit(group)
                    break
                except _RPC_ERRORS:
                    self._eject(idx, "submit failed")

    def _flush_alive(self) -> None:  # guarded_by: self._lock
        for idx, replica in self._alive_replicas():
            try:
                replica.flush()
            except _RPC_ERRORS:
                self._eject(idx, "flush failed")

    def _health_check(self) -> None:  # guarded_by: self._lock
        for idx, replica in self._alive_replicas():
            if self._m_health is not None:
                self._m_health.inc()
            if not replica.healthy():
                self._eject(idx, "health check failed")

    # -- LargeBackend protocol ----------------------------------------------
    def submit(self, requests: List[Request]) -> int:
        if self._closed:
            raise RuntimeError("backend is closed")
        with self._lock:
            self._submit_balanced(list(requests))
            self._n_tickets += 1
            return self._n_tickets

    def poll(self, timeout: Optional[float] = None) -> List[LargeResult]:
        with self._lock:
            now = time.perf_counter()
            if now - self._last_health >= self.health_interval:
                self._last_health = now
                self._health_check()
            out: List[LargeResult] = []
            budget = timeout
            for idx, replica in self._alive_replicas():
                if not replica.n_pending:
                    continue
                try:
                    got = replica.poll(timeout=budget)
                except _RPC_ERRORS:
                    self._eject(idx, "poll failed")
                    continue
                out.extend(got)
                budget = None   # only the first busy replica blocks
            if not out and self.n_pending and not self._alive_replicas():
                raise RemoteBackendError(
                    f"all M_L replicas are dead with {self.n_pending} "
                    f"request(s) in flight")
            return out

    def flush(self) -> None:
        with self._lock:
            self._flushed = True
            self._route.clear()   # open groups are being cut server-side
            self._flush_alive()

    def drain(self) -> List[LargeResult]:
        self.flush()
        out: List[LargeResult] = []
        t_last = time.perf_counter()
        while self.n_pending:
            got = self.poll(timeout=0.05)
            out.extend(got)
            if got:
                t_last = time.perf_counter()
            elif time.perf_counter() - t_last > self.drain_stall_timeout:
                raise RemoteBackendError(
                    f"M_L pool drain stalled: {self.n_pending} requests "
                    f"pending, no progress for {self.drain_stall_timeout}s")
        return out

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        with self._lock:
            for _idx, replica in self._alive_replicas():
                try:
                    replica.close()
                except _RPC_ERRORS:
                    pass

    @property
    def n_pending(self) -> int:
        # metrics gauges read this off-thread; _alive must be read under
        # the lock (RLock, so the poll/drain paths can re-enter)
        with self._lock:
            return sum(r.n_pending for i, r in enumerate(self.replicas)
                       if self._alive[i])

    @property
    def batch_log(self) -> List[Dict[str, Any]]:
        """Merged per-replica batch logs (batch ids are per-replica;
        engine stats only aggregate counts/occupancy, never join on id)."""
        out: List[Dict[str, Any]] = []
        for r in self.replicas:
            out.extend(r.batch_log)
        return out

    @property
    def n_alive(self) -> int:
        with self._lock:
            return sum(self._alive)
