"""Wire protocol for the distributed M_L tier.

`RemoteStubBackend` pinned the serialized request/response contract over
an in-process pipe; this module promotes that contract to a real socket
wire format shared by the M_L server (`remote.server.MLServer`) and the
socket client (`remote.client.SocketBackend`):

  * **Framing** — length-prefixed JSON: a 4-byte big-endian unsigned
    length followed by that many bytes of UTF-8 JSON. Frames above
    `MAX_FRAME` are rejected before allocation (a corrupt length prefix
    must not OOM the server); a peer closing mid-frame raises
    `WireError("truncated frame")` rather than returning garbage.
  * **Envelope** — every message carries ``{"schema": SCHEMA_VERSION,
    "kind": <str>, ...}``. A schema mismatch is rejected loudly on both
    sides: the version bump is the escape hatch for breaking the wire
    format across rolling server/client upgrades (the golden fixture in
    tests/golden/wire_v1.json fails first otherwise).
  * **Payloads** — requests serialize as ``{"rid", "prompt"}`` and
    results as ``{"rid", "tokens", "batch_id", "n_real", "pad_to",
    "reason", "prompt_len"}`` — byte-compatible with the
    `RemoteStubBackend` JSON contract, now with strict decode-side
    validation that echoes the offending ``rid`` back in the error.

JSON bytes are canonical (sorted keys, no whitespace) so the golden
wire-format test can pin exact frame bytes, not just parsed content.
"""
from __future__ import annotations

import json
import math
import socket
import struct
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.large_backend import LargeResult

# bump when the frame layout or payload fields change incompatibly; the
# server rejects clients speaking a different version (and vice versa)
SCHEMA_VERSION = 1

# hard ceiling on one frame's body: a corrupt/hostile length prefix must
# fail fast instead of driving a multi-GiB allocation
MAX_FRAME = 64 * 1024 * 1024

_LEN = struct.Struct(">I")


class WireError(RuntimeError):
    """Malformed frame or payload. `rid` carries the offending request id
    when one could be extracted (echoed back to the client so it can
    reject that request instead of killing the whole connection)."""

    def __init__(self, msg: str, rid: Optional[int] = None):
        super().__init__(msg)
        self.rid = rid


def dumps(obj: Dict[str, Any]) -> bytes:
    """Canonical JSON bytes (sorted keys, compact separators): the same
    logical message always serializes to the same bytes, which is what
    lets the golden test pin frames instead of parse trees."""
    return json.dumps(obj, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")


def frame_bytes(obj: Dict[str, Any]) -> bytes:
    """Full frame (length prefix + canonical JSON body) for `obj`."""
    body = dumps(obj)
    if len(body) > MAX_FRAME:
        raise WireError(f"frame body {len(body)} bytes exceeds "
                        f"MAX_FRAME {MAX_FRAME}")
    return _LEN.pack(len(body)) + body


def send_frame(sock: socket.socket, obj: Dict[str, Any]) -> None:
    sock.sendall(frame_bytes(obj))


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """Read exactly `n` bytes. Returns None on clean EOF at a frame
    boundary (zero bytes read); raises WireError if the peer vanishes
    mid-frame. Socket timeouts propagate as socket.timeout."""
    chunks: List[bytes] = []
    got = 0
    while got < n:
        chunk = sock.recv(n - got)
        if not chunk:
            if got == 0:
                return None
            raise WireError(f"truncated frame: peer closed after "
                            f"{got}/{n} bytes")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> Optional[Dict[str, Any]]:
    """Read one frame. Returns the decoded dict, or None on clean EOF
    (peer closed between frames). Raises WireError on a truncated frame,
    an oversize length prefix, undecodable JSON, or a non-object body."""
    head = _recv_exact(sock, _LEN.size)
    if head is None:
        return None
    (n,) = _LEN.unpack(head)
    if n > MAX_FRAME:
        raise WireError(f"frame length {n} exceeds MAX_FRAME {MAX_FRAME} "
                        f"(corrupt length prefix?)")
    body = _recv_exact(sock, n)
    if body is None:
        raise WireError("truncated frame: peer closed after length prefix")
    try:
        msg = json.loads(body.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireError(f"undecodable frame body: {e}") from e
    if not isinstance(msg, dict):
        raise WireError(f"frame body must be a JSON object, "
                        f"got {type(msg).__name__}")
    return msg


def envelope(kind: str, **fields: Any) -> Dict[str, Any]:
    """Build a versioned message: schema + kind + payload fields."""
    return {"schema": SCHEMA_VERSION, "kind": kind, **fields}


def check_schema(msg: Dict[str, Any]) -> None:
    """Reject messages from a peer speaking a different wire version —
    the loud failure that makes the schema field a real rolling-upgrade
    escape hatch instead of decoration."""
    v = msg.get("schema")
    if v != SCHEMA_VERSION:
        raise WireError(f"wire schema mismatch: peer speaks {v!r}, "
                        f"this side speaks {SCHEMA_VERSION}")
    if not isinstance(msg.get("kind"), str):
        raise WireError("message missing 'kind'")


# -- request / response payloads (the RemoteStubBackend contract) -----------

def encode_request(rid: int, prompt: np.ndarray) -> Dict[str, Any]:
    return {"rid": int(rid), "prompt": np.asarray(prompt).tolist()}


def decode_request(d: Any) -> Tuple[int, np.ndarray]:
    """Validate + decode one submitted request. Raises WireError carrying
    the rid (when extractable) so the server can reject exactly that
    request instead of dropping the connection."""
    if not isinstance(d, dict):
        raise WireError(f"request must be an object, "
                        f"got {type(d).__name__}")
    rid = d.get("rid")
    if not isinstance(rid, int) or isinstance(rid, bool) or rid < 0:
        raise WireError(f"request rid must be a non-negative int, "
                        f"got {rid!r}")
    prompt = d.get("prompt")
    if (not isinstance(prompt, list) or not prompt
            or not all(isinstance(t, int) and not isinstance(t, bool)
                       for t in prompt)):
        raise WireError(f"rid {rid}: prompt must be a non-empty list of "
                        f"ints", rid=rid)
    return rid, np.asarray(prompt, np.int32)


def encode_result(res: LargeResult) -> Dict[str, Any]:
    out = {"rid": int(res.rid), "tokens": np.asarray(res.tokens).tolist(),
           "batch_id": int(res.batch_id), "n_real": int(res.n_real),
           "pad_to": int(res.pad_to), "reason": str(res.reason),
           "prompt_len": int(res.prompt_len)}
    # optional: only present when finite (JSON has no nan; omitting it
    # keeps pre-ladder frames byte-identical under SCHEMA_VERSION 1 —
    # the golden fixture pins that)
    conf = getattr(res, "confidence", math.nan)
    if isinstance(conf, float) and math.isfinite(conf):
        out["confidence"] = conf
    return out


def decode_result(d: Any) -> LargeResult:
    if not isinstance(d, dict):
        raise WireError(f"result must be an object, got {type(d).__name__}")
    try:
        return LargeResult(
            rid=int(d["rid"]),
            tokens=np.asarray(d["tokens"], np.int32),
            batch_id=int(d["batch_id"]), n_real=int(d["n_real"]),
            pad_to=int(d["pad_to"]), reason=str(d["reason"]),
            prompt_len=int(d["prompt_len"]),
            confidence=float(d.get("confidence", math.nan)))
    except (KeyError, TypeError, ValueError) as e:
        raise WireError(f"malformed result payload "
                        f"(rid={d.get('rid')!r}): {e}",
                        rid=d.get("rid") if isinstance(d.get("rid"), int)
                        else None) from e
