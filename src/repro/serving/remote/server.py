"""The M_L server process: a socket RPC service owning its own
`ModelRunner.generate` loop.

One `MLServer` is the out-of-process half of the distributed M_L tier
(`launch/ml_server.py` is its process entrypoint; tests and the bench
run it in-thread against a real localhost TCP socket — the transport is
identical either way). Structure:

    accept thread ──> one handler thread per connection (frame RPC)
                              │ submit / poll / flush / cancel / health
                              ▼
    worker thread ──  BatchPolicy (large_backend's, unchanged) +
                      `ModelRunner.generate` per prompt-length group

The server is single-tenant by design: one logical client (a
`SocketBackend`, possibly reconnecting through retries) owns it at a
time. Sessions make that safe:

  * the client opens every connection with ``hello(session=...)``; a
    RECONNECT with the same session id preserves all server state, so a
    retried submit after a lost ack deduplicates by rid instead of
    regenerating;
  * a NEW session id resets the server — pending requests are
    cancelled, undelivered results dropped, the drain flag cleared — so
    one server can back many consecutive engine runs (which reuse the
    same rid space) without cross-run contamination. In-flight batches
    from the old session are epoch-tagged and discarded on completion.

Delivery is at-least-once with explicit acks: ``poll`` responses stay
buffered server-side until the client acknowledges them in its next
``poll`` (a lost response is re-fetched, a duplicate is dropped
client-side by rid), so no deferral is ever silently lost to a flaky
connection.

Fault-injection hooks (`fault_delay_next`/`fault_delay_s`, `kill()`)
exist so tests/test_serving_remote.py can force the timeout-retry and
replica-death paths deterministically.
"""
from __future__ import annotations

import math
import queue
import socket
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.serving.large_backend import (BatchPolicy, _BackendMetrics,
                                         _generate_batch, _Pending)
from repro.serving.remote import wire

# server-internal rid mangling: results of a superseded session must not
# collide with the next session's rid space (engine runs restart at 0)
_EPOCH_SHIFT = 32
_RID_SPAN = 1 << _EPOCH_SHIFT


class MLServer:
    """Socket RPC server for batched M_L regeneration.

    `runner` is the large `ModelRunner`; batching policy knobs
    (`large_batch`, `max_wait`) mirror `make_large_backend` — the policy
    object itself IS `large_backend.BatchPolicy`, so batch shapes (and
    greedy parity) are identical to the in-process backends. `latency`
    injects per-batch response delay (the stub backend's knob, kept for
    benches). `port=0` binds an ephemeral port; read `.address` after
    construction.
    """

    def __init__(self, runner, max_new: int,
                 large_batch: Optional[int] = None,
                 max_wait: Optional[float] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 poll_interval: float = 0.002,
                 latency: float = 0.0,
                 registry=None):
        self._generate = runner.generate
        self.max_new = max_new
        self._poll_interval = poll_interval
        self.latency = latency
        self._policy = BatchPolicy(large_batch, max_wait)
        self._inq: "queue.Queue" = queue.Queue()
        self._outq: "queue.Queue" = queue.Queue()
        self._drain_flag = threading.Event()
        self._stop_flag = threading.Event()
        self._killed = False
        self._error: Optional[BaseException] = None

        # session + delivery state (under _lock; worker only sees srids)
        self._lock = threading.Lock()
        self._session: Optional[str] = None  # guarded_by: self._lock
        self._epoch = 0                    # guarded_by: self._lock
        # rids accepted this session
        self._seen: set = set()            # guarded_by: self._lock
        # rid -> undelivered result
        self._done: Dict[int, Dict[str, Any]] = {}  # guarded_by: self._lock
        # accepted - completed/cancelled
        self._n_open = 0                   # guarded_by: self._lock
        self._results_ready = threading.Event()

        # written by the worker thread, read by metrics gauges
        self._n_batches = 0                # guarded_by: self._lock
        self.batch_log: List[Dict[str, Any]] = []   # guarded_by: self._lock
        self._metrics = _BackendMetrics(registry, self)
        self._t_start = time.perf_counter()

        # fault injection (tests): delay the next N RPC responses by
        # fault_delay_s seconds each — forces client request timeouts
        self.fault_delay_next = 0
        self.fault_delay_s = 0.0

        self._lsock = socket.create_server((host, port))
        self._lsock.settimeout(0.2)
        self.host, self.port = self._lsock.getsockname()[:2]
        self._conns: List[socket.socket] = []
        self._threads: List[threading.Thread] = []
        self._worker = threading.Thread(target=self._run_worker,
                                        daemon=True, name="ml-server-gen")
        self._acceptor = threading.Thread(target=self._accept_loop,
                                          daemon=True, name="ml-server-acc")
        self._started = False

    # -- lifecycle ----------------------------------------------------------
    @property
    def address(self) -> Tuple[str, int]:
        return (self.host, self.port)

    @property
    def running(self) -> bool:
        return self._started and not self._stop_flag.is_set()

    @property
    def n_pending(self) -> int:
        """Requests accepted this session and not yet completed (the
        per-replica queue-depth number health responses report).
        Absorbs finished/cancelled work first — completions must be
        visible without waiting for the next client poll."""
        with self._lock:
            self._absorb_outq()
            return self._n_open

    def start(self) -> "MLServer":
        self._worker.start()
        self._acceptor.start()
        self._started = True
        return self

    def stop(self, timeout: float = 5.0) -> None:
        """Graceful stop: no new connections, worker + handlers join."""
        self._stop_flag.set()
        for t in (self._acceptor, self._worker, *self._threads):
            if t.is_alive():
                t.join(timeout=timeout)
        self._close_all()

    def kill(self) -> None:
        """Abrupt death (fault injection): drop the listening socket and
        every live connection mid-whatever, stop the worker without
        draining. Clients observe connection reset / refused."""
        self._killed = True
        self._stop_flag.set()
        self._close_all()

    def _close_all(self) -> None:
        for s in [self._lsock, *self._conns]:
            try:
                s.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                s.close()
            except OSError:
                pass
        self._conns.clear()

    def __enter__(self) -> "MLServer":
        return self if self._started else self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()

    # -- worker: the batching + generate loop -------------------------------
    def _run_worker(self) -> None:
        try:
            self._loop()
        except BaseException as e:              # noqa: BLE001
            self._error = e

    def _loop(self) -> None:
        while not self._stop_flag.is_set():
            deadline = self._policy.next_deadline()
            timeout = self._poll_interval
            if deadline is not None:
                timeout = min(timeout,
                              max(deadline - time.perf_counter(), 0.0))
            try:
                op, payload = self._inq.get(timeout=max(timeout, 1e-4))
                if op == "submit":
                    self._policy.add(payload)
                elif op == "cancel":
                    removed = self._policy.cancel(payload)
                    self._outq.put(("cancelled", removed))
                    self._results_ready.set()
                continue            # keep pulling before cutting a batch
            except queue.Empty:
                pass
            drain = self._drain_flag.is_set() and self._inq.empty()
            for group, pad_to, reason in self._policy.take(
                    time.perf_counter(), drain=drain):
                tokens, conf = _generate_batch(self._generate, group, pad_to,
                                               self.max_new)
                if self.latency > 0:
                    time.sleep(self.latency)
                with self._lock:
                    bid = self._n_batches
                    self._n_batches += 1
                    self.batch_log.append({
                        "batch_id": bid, "n_real": len(group),
                        "pad_to": pad_to, "reason": reason,
                        "prompt_len": int(group[0].prompt.shape[0])})
                self._metrics.record_batch(len(group), pad_to, reason)
                for i, p in enumerate(group):
                    epoch, rid = divmod(p.rid, _RID_SPAN)
                    res = {
                        "rid": rid, "tokens": tokens[i].tolist(),
                        "batch_id": bid, "n_real": len(group),
                        "pad_to": pad_to, "reason": reason,
                        "prompt_len": int(p.prompt.shape[0])}
                    # optional field: present only when finite (same
                    # rule as wire.encode_result — JSON has no nan)
                    if math.isfinite(float(conf[i])):
                        res["confidence"] = float(conf[i])
                    self._outq.put(("result", (epoch, res)))
                self._results_ready.set()

    # -- session / delivery bookkeeping (handler side, under _lock) ---------
    def _hello(self, session: str) -> None:
        with self._lock:
            if session == self._session:
                return                        # reconnect: keep everything
            # new logical client: cancel the old session's pending work,
            # drop its undelivered results, rearm the drain flag
            self._session = session
            self._epoch += 1
            if self._seen:
                stale = [(self._epoch - 1) * _RID_SPAN + r
                         for r in self._seen]
                self._inq.put(("cancel", stale))
            self._seen = set()
            self._done = {}
            self._n_open = 0
            self._drain_flag.clear()

    def _absorb_outq(self) -> None:  # guarded_by: self._lock
        """Move completed work from the worker into the undelivered
        buffer, dropping anything from a superseded session."""
        while True:
            try:
                op, payload = self._outq.get_nowait()
            except queue.Empty:
                return
            if op == "result":
                epoch, res = payload
                if epoch != self._epoch:
                    continue                  # stale session: discard
                if res["rid"] in self._seen and res["rid"] not in self._done:
                    self._done[res["rid"]] = res
                    self._n_open -= 1
            elif op == "cancelled":
                for srid in payload:
                    epoch, rid = divmod(srid, _RID_SPAN)
                    if epoch == self._epoch and rid in self._seen:
                        self._seen.discard(rid)
                        self._n_open -= 1

    def _handle_submit(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        reqs = msg.get("reqs")
        if not isinstance(reqs, list):
            raise wire.WireError("submit needs a 'reqs' list")
        decoded = [wire.decode_request(d) for d in reqs]  # validate first
        accepted = dup = 0
        with self._lock:
            for rid, prompt in decoded:
                if rid in self._seen:
                    dup += 1                  # retried submit: dedupe
                    continue
                self._seen.add(rid)
                self._n_open += 1
                srid = self._epoch * _RID_SPAN + rid
                self._inq.put(("submit", _Pending(srid, prompt,
                                                  time.perf_counter())))
                accepted += 1
        return wire.envelope("ok", accepted=accepted, dup=dup)

    def _handle_poll(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        ack = msg.get("ack") or []
        wait = min(float(msg.get("wait") or 0.0), 5.0)
        deadline = time.perf_counter() + wait
        with self._lock:
            for rid in ack:
                self._done.pop(rid, None)
            self._absorb_outq()
            results = list(self._done.values())
        while not results and time.perf_counter() < deadline:
            self._results_ready.clear()
            self._results_ready.wait(
                max(min(deadline - time.perf_counter(), 0.05), 1e-4))
            with self._lock:
                self._absorb_outq()
                results = list(self._done.values())
        with self._lock:
            pending = self._n_open
        return wire.envelope("results", results=results, pending=pending)

    def _handle_cancel(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        rids = msg.get("rids") or []
        with self._lock:
            todo = [self._epoch * _RID_SPAN + r for r in rids
                    if r in self._seen and r not in self._done]
        if todo:
            self._inq.put(("cancel", todo))
        return wire.envelope("ok", cancelling=len(todo))

    # -- connection handling ------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop_flag.is_set():
            try:
                conn, _addr = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                return                        # listening socket closed
            conn.settimeout(0.2)
            self._conns.append(conn)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True, name="ml-server-conn")
            self._threads.append(t)
            t.start()

    def _reply(self, conn: socket.socket, msg: Dict[str, Any]) -> None:
        if self.fault_delay_next > 0:
            self.fault_delay_next -= 1
            time.sleep(self.fault_delay_s)
        wire.send_frame(conn, msg)

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._stop_flag.is_set():
                try:
                    msg = wire.recv_frame(conn)
                except socket.timeout:
                    continue
                except OSError:
                    return                    # socket yanked (kill())
                except wire.WireError as e:
                    # framing is lost: report and drop the connection
                    # (the session survives — a reconnect resumes it)
                    try:
                        self._reply(conn, wire.envelope(
                            "error", error=str(e), rid=e.rid))
                    except OSError:
                        pass
                    return
                if msg is None:
                    return                    # clean EOF
                try:
                    wire.check_schema(msg)
                except wire.WireError as e:
                    self._reply(conn, wire.envelope("error", error=str(e),
                                                    rid=None))
                    return                    # can't talk to this peer
                kind = msg["kind"]
                try:
                    if kind == "hello":
                        self._hello(str(msg.get("session")))
                        reply = wire.envelope("ok", server="ml_server",
                                              pending=self.n_pending)
                    elif kind == "submit":
                        reply = self._handle_submit(msg)
                    elif kind == "poll":
                        reply = self._handle_poll(msg)
                    elif kind == "flush":
                        self._drain_flag.set()
                        reply = wire.envelope("ok")
                    elif kind == "cancel":
                        reply = self._handle_cancel(msg)
                    elif kind == "health":
                        if self._error is not None:
                            reply = wire.envelope(
                                "error", rid=None,
                                error=f"M_L worker died: {self._error!r}")
                        else:
                            reply = wire.envelope(
                                "ok", pending=self.n_pending,
                                uptime_s=round(time.perf_counter()
                                               - self._t_start, 3))
                    elif kind == "bye":
                        self._reply(conn, wire.envelope("ok"))
                        return
                    elif kind == "shutdown":
                        self._reply(conn, wire.envelope("ok"))
                        self._stop_flag.set()
                        return
                    else:
                        reply = wire.envelope(
                            "error", error=f"unknown kind {kind!r}",
                            rid=None)
                except wire.WireError as e:
                    # a well-framed but invalid payload rejects only the
                    # offending request — rid echoed, connection kept
                    reply = wire.envelope("error", error=str(e), rid=e.rid)
                try:
                    self._reply(conn, reply)
                except OSError:
                    return                    # client went away mid-reply
        finally:
            try:
                conn.close()
            except OSError:
                pass
