"""Pressure policies: what the engine does when the oversubscribed paged
pool runs out of PHYSICAL blocks.

Reservation-only admission (`oversubscribe == 1.0`) never gets here — the
invariant guarantees every admitted request can map its worst case. With
`oversubscribe > 1.0` the pool admits against a *virtual* budget and
`BlockPressure` can fire mid-prefill or mid-decode. The engine then asks
its `PressurePolicy` to pick a victim among the running slots and an
action for it:

  "preempt" — save the victim's decode state (device rows + generated
              tokens), register its context in the prefix registry so
              re-establishing the KV is mostly a registry walk, release
              the slot, and requeue the request age-first. Bounded by
              `max_preemptions` per request, after which the policy
              escalates to "defer" so a request cannot thrash forever.
  "defer"   — the cascade-unique escape hatch: hand the victim straight
              up the ladder (`deferred_reason="oom"`) through the
              existing edge backend. Its M_S work is discarded but the
              request still completes, on M_L.
  "shed"    — drop the victim (REJECTED terminal state, empty tokens).
              Load shedding for deployments that prefer fast failure.

Victim selection is deterministic: the YOUNGEST running slot — max
`admit_seq`, ties broken by max rid — loses. Youngest-victim maximizes
the work preserved (older requests are closer to completion) and matches
vLLM-style last-in preemption, which composes with age-first requeueing
into FIFO-like completion order.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Tuple

from repro.serving.request import Request

# actions a policy may return
PREEMPT = "preempt"
DEFER = "defer"
SHED = "shed"


def select_victim(running: Dict[int, Request],
                  exclude: Iterable[int] = ()) -> Optional[int]:
    """Deterministic victim slot: youngest admission (max admit_seq, tie
    max rid) among `running` minus `exclude`. None if no candidate."""
    ex = set(exclude)
    cands = [(r.admit_seq, r.rid, s) for s, r in running.items()
             if s not in ex]
    if not cands:
        return None
    return max(cands)[2]


class PressurePolicy:
    """Base policy: subclasses set `kind` and override `action_for`."""
    kind = "abstract"

    def __init__(self, max_preemptions: int = 2):
        self.max_preemptions = max_preemptions

    def select(self, running: Dict[int, Request],
               exclude: Iterable[int] = ()
               ) -> Optional[Tuple[int, str]]:
        """(victim_slot, action) or None when there is nothing to evict
        (pressure must then surface as a hard error)."""
        slot = select_victim(running, exclude)
        if slot is None:
            return None
        return slot, self.action_for(running[slot])

    def action_for(self, victim: Request) -> str:
        raise NotImplementedError


class PreemptPolicy(PressurePolicy):
    """Preempt-and-requeue, escalating to defer-on-OOM once a request has
    been preempted `max_preemptions` times (anti-thrash bound)."""
    kind = "preempt"

    def action_for(self, victim: Request) -> str:
        if victim.n_preempted >= self.max_preemptions:
            return DEFER
        return PREEMPT


class DeferOnOomPolicy(PressurePolicy):
    """Always defer the victim up the cascade ladder."""
    kind = "defer"

    def action_for(self, victim: Request) -> str:
        return DEFER


class ShedPolicy(PressurePolicy):
    """Always drop the victim (REJECTED)."""
    kind = "shed"

    def action_for(self, victim: Request) -> str:
        return SHED


_POLICIES = {
    "preempt": PreemptPolicy,
    "defer": DeferOnOomPolicy,
    "shed": ShedPolicy,
}


def make_pressure_policy(kind: str,
                         max_preemptions: int = 2) -> PressurePolicy:
    if kind not in _POLICIES:
        raise ValueError(f"unknown pressure policy {kind!r}; "
                         f"expected one of {sorted(_POLICIES)}")
    return _POLICIES[kind](max_preemptions=max_preemptions)
