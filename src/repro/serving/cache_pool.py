"""Slot-based KV-cache pool for continuous batching.

One cache tree is allocated ONCE with batch = n_slots and lives for the
engine's lifetime; requests borrow a slot (one batch row across every
leaf) and return it on retirement. Admission overwrites the whole row
with a freshly prefilled cache, so stale K/V from the previous tenant
never leaks (decode additionally masks positions > the row's depth).

Leaves differ per model family (GQA k/v, MLA compressed kv + rope key,
RWKV/Mamba recurrent states) and carry their batch dim at different axes
(stacked layer groups lead with a `layers` axis). The batch axis of each
leaf is discovered once from the abstract cache's logical axes rather
than hard-coded per family.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models import transformer as tfm
from repro.sharding import AbstractParam


def _is_abstract(x: Any) -> bool:
    return isinstance(x, AbstractParam)


def cache_batch_axes(cfg: ModelConfig, max_len: int) -> Any:
    """Tree (same structure as the cache) of ints: the batch axis of each
    leaf, read off the abstract cache's logical axes."""
    abstract = tfm.init_cache(cfg, 1, max_len, abstract=True)
    return jax.tree.map(lambda a: a.logical_axes.index("batch"), abstract,
                        is_leaf=_is_abstract)


def scatter_rows(pool_cache: Any, row_cache: Any, slots: jnp.ndarray,
                 batch_axes: Any) -> Any:
    """Write `row_cache` (batch = k) into rows `slots` [k] of `pool_cache`
    (batch = n_slots), leaf-wise along each leaf's batch axis. Pure /
    jittable."""
    def put(pool_leaf, row_leaf, ax):
        idx = (slice(None),) * ax + (slots,)
        return pool_leaf.at[idx].set(row_leaf.astype(pool_leaf.dtype))
    return jax.tree.map(put, pool_cache, row_cache, batch_axes)


class SlotCachePool:
    """Preallocated per-slot KV/state cache + free-slot bookkeeping.

    The device tree is exposed as `.cache` (replaced functionally after
    each jitted step — jax arrays are immutable); `alloc`/`release`
    manage slot ids on the host.
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int,
                 dtype=None):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = tfm.init_cache(cfg, n_slots, max_len,
                                    dtype=dtype or cfg.cdtype())
        self.batch_axes = cache_batch_axes(cfg, max_len)
        self._free: List[int] = list(range(n_slots - 1, -1, -1))
        self._in_use: set = set()
        # lifetime counters: how many requests each slot has hosted
        self.generations = [0] * n_slots

    # -- capacity ----------------------------------------------------------
    def footprint_bytes(self) -> int:
        """Device bytes held by the pool's cache tree. Every slot reserves
        a full `max_len` row regardless of its tenant's actual length —
        this is the worst-case cost the paged pool avoids."""
        return sum(l.nbytes for l in jax.tree.leaves(self.cache))

    # -- slot bookkeeping --------------------------------------------------
    @property
    def n_free(self) -> int:
        """Number of slots currently unoccupied."""
        return len(self._free)

    @property
    def in_use(self) -> frozenset:
        return frozenset(self._in_use)

    def alloc(self) -> int:
        """Lowest-numbered free slot (deterministic placement)."""
        if not self._free:
            raise RuntimeError("cache pool exhausted")
        slot = self._free.pop()
        self._in_use.add(slot)
        self.generations[slot] += 1
        return slot

    def release(self, slot: int,
                expected_generation: Optional[int] = None) -> None:
        """Return `slot` to the free list. A double release (slot already
        free) raises with the slot id; passing the generation captured at
        `alloc` additionally catches a STALE release — the slot was
        re-allocated to a new tenant in between — before it can corrupt
        the free list."""
        if slot not in self._in_use:
            raise RuntimeError(
                f"double release of slot {slot}: slot is not in use "
                "(already released or never allocated)")
        if (expected_generation is not None
                and expected_generation != self.generations[slot]):
            raise RuntimeError(
                f"stale release of slot {slot}: caller holds generation "
                f"{expected_generation} but the slot was re-allocated "
                f"(now generation {self.generations[slot]})")
        self._in_use.remove(slot)
        self._free.append(slot)
        self._free.sort(reverse=True)

    # -- device-side row writes -------------------------------------------
    def write_rows(self, row_cache: Any, slots) -> None:
        """Host-side convenience: scatter prefilled rows into the pool
        (the engine normally fuses this into its jitted admit step via
        `scatter_rows`)."""
        self.cache = scatter_rows(self.cache, row_cache,
                                  jnp.asarray(slots), self.batch_axes)
