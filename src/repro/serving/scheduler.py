"""Admission/retirement scheduler for the continuous-batching engine.

Each engine step the scheduler:
  1. releases newly arrived requests into the ready FIFO,
  2. admits ready requests into free cache-pool slots (strict FIFO — a
     request never overtakes an earlier arrival, even when a later,
     smaller request would fit: head-of-line blocking is the price of
     deterministic admission order),
  3. after the decode step, retires finished or in-flight-deferred
     requests and returns their slots to the pool.

The scheduler drives either pool flavor: `SlotCachePool` (admission
gated on free slots only) or `PagedCachePool` (the engine additionally
passes `can_admit`, gating the FIFO head on block-reservation capacity).

Under oversubscription the engine may also *preempt* a running slot
(`preempt`: the request leaves RUNNING with its state saved and re-enters
the arrival queue age-first) or *drop* one (`drop`: overload shed — the
caller marks the terminal state). Retirement/preemption release the slot
with the pool generation captured at admission, so a stale double release
of a re-allocated slot fails loudly instead of corrupting the free heap.

Invariants (pinned by tests/test_serving_continuous.py and
tests/test_serving_paged.py):
  * a slot hosts at most one request at a time;
  * admitted set + free set is always exactly {0..n_slots-1};
  * admission order equals arrival order (preempted requests re-enter
    at their original arrival position).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

from repro.serving.request import (DEFERRED, DONE, PENDING, PREEMPTED,
                                   RUNNING, ArrivalQueue, Request)


class SlotScheduler:
    """FIFO admission into free pool slots + retirement bookkeeping.
    `pool` is a SlotCachePool or PagedCachePool (anything with
    alloc/release/n_free/in_use)."""

    def __init__(self, pool):
        self.pool = pool
        self.running: Dict[int, Request] = {}     # slot -> request
        # pool generation captured at admission; passed back on release so
        # a stale release of a re-allocated slot raises instead of
        # corrupting the free heap
        self._admit_gen: Dict[int, int] = {}
        # lifetime counters (observability gauges read these; plain ints
        # so the admission/retire paths pay nothing extra)
        self.n_admitted = 0
        self.n_retired = 0
        self.n_preempted = 0

    # -- admission ---------------------------------------------------------
    def admit_ready(self, queue: ArrivalQueue, now: float,
                    limit: Optional[int] = None,
                    can_admit: Optional[Callable[[Request], bool]] = None
                    ) -> List[Tuple[int, Request]]:
        """Admit FIFO-ready requests into free slots. Returns
        [(slot, request), ...] in admission order.

        `can_admit(req)` (paged backend) vetoes admission of the FIFO
        head when the pool cannot reserve its worst-case block count;
        admission then stops entirely — strict FIFO means no later
        request may overtake the blocked head."""
        queue.release(now)
        admitted: List[Tuple[int, Request]] = []
        budget = self.pool.n_free if limit is None else min(limit,
                                                            self.pool.n_free)
        while budget > 0 and queue.n_ready > 0:
            if can_admit is not None and not can_admit(queue.peek_ready()):
                break
            req = queue.pop_ready()
            assert req is not None and req.state in (PENDING, PREEMPTED)
            slot = self.pool.alloc()
            req.slot = slot
            req.state = RUNNING
            if req.t_admit != req.t_admit:   # nan: first admission only
                req.t_admit = now
            req.admit_seq = self.n_admitted
            self.running[slot] = req
            self._admit_gen[slot] = self.pool.generations[slot]
            admitted.append((slot, req))
            self.n_admitted += 1
            budget -= 1
        return admitted

    # -- retirement --------------------------------------------------------
    def retire(self, slot: int, now: float, deferred: bool,
               early: bool = False) -> Request:
        """Remove the request in `slot` from M_S and free the slot.
        `deferred` routes it to the M_L queue; `early` marks an in-flight
        eviction (saved M_S steps)."""
        req = self.running.pop(slot)
        req.slot = None
        req.t_retire = now
        req.deferred = deferred
        req.early_exited = early
        if deferred:
            req.state = DEFERRED
        else:
            req.state = DONE
            req.t_done = now
        self.pool.release(slot, self._admit_gen.pop(slot))
        self.n_retired += 1
        return req

    def preempt(self, slot: int, now: float) -> Request:
        """Evict the request in `slot` under block pressure WITHOUT
        retiring it: the request leaves RUNNING as PREEMPTED (caller has
        already saved its resume state) and must be re-queued by the
        caller. Frees the slot and its blocks."""
        req = self.running.pop(slot)
        req.slot = None
        req.state = PREEMPTED
        req.n_preempted += 1
        self.pool.release(slot, self._admit_gen.pop(slot))
        self.n_preempted += 1
        return req

    def drop(self, slot: int, now: float) -> Request:
        """Remove the request in `slot` without completing it (overload
        shed of an in-flight victim). The caller sets the terminal state
        and telemetry; this only unwinds the slot accounting."""
        req = self.running.pop(slot)
        req.slot = None
        req.t_retire = now
        self.pool.release(slot, self._admit_gen.pop(slot))
        self.n_retired += 1
        return req

    # -- views -------------------------------------------------------------
    @property
    def active_slots(self) -> List[int]:
        return sorted(self.running)

    @property
    def n_active(self) -> int:
        return len(self.running)

    def check_invariants(self) -> None:
        """Assert slot accounting is consistent (used by tests)."""
        in_use = self.pool.in_use
        assert set(self.running) == in_use, (self.running, in_use)
        assert set(self._admit_gen) == in_use, (self._admit_gen, in_use)
        assert len(in_use) + self.pool.n_free == self.pool.n_slots
