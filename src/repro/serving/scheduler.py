"""Admission/retirement scheduler for the continuous-batching engine.

Each engine step the scheduler:
  1. releases newly arrived requests into the ready FIFO,
  2. admits ready requests into free cache-pool slots (strict FIFO — a
     request never overtakes an earlier arrival, even when a later,
     smaller request would fit: head-of-line blocking is the price of
     deterministic admission order),
  3. after the decode step, retires finished or in-flight-deferred
     requests and returns their slots to the pool.

The scheduler drives either pool flavor: `SlotCachePool` (admission
gated on free slots only) or `PagedCachePool` (the engine additionally
passes `can_admit`, gating the FIFO head on block-reservation capacity).

Invariants (pinned by tests/test_serving_continuous.py and
tests/test_serving_paged.py):
  * a slot hosts at most one request at a time;
  * admitted set + free set is always exactly {0..n_slots-1};
  * admission order equals arrival order.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

from repro.serving.request import (DEFERRED, DONE, PENDING, RUNNING,
                                   ArrivalQueue, Request)


class SlotScheduler:
    """FIFO admission into free pool slots + retirement bookkeeping.
    `pool` is a SlotCachePool or PagedCachePool (anything with
    alloc/release/n_free/in_use)."""

    def __init__(self, pool):
        self.pool = pool
        self.running: Dict[int, Request] = {}     # slot -> request
        # lifetime counters (observability gauges read these; plain ints
        # so the admission/retire paths pay nothing extra)
        self.n_admitted = 0
        self.n_retired = 0

    # -- admission ---------------------------------------------------------
    def admit_ready(self, queue: ArrivalQueue, now: float,
                    limit: Optional[int] = None,
                    can_admit: Optional[Callable[[Request], bool]] = None
                    ) -> List[Tuple[int, Request]]:
        """Admit FIFO-ready requests into free slots. Returns
        [(slot, request), ...] in admission order.

        `can_admit(req)` (paged backend) vetoes admission of the FIFO
        head when the pool cannot reserve its worst-case block count;
        admission then stops entirely — strict FIFO means no later
        request may overtake the blocked head."""
        queue.release(now)
        admitted: List[Tuple[int, Request]] = []
        budget = self.pool.n_free if limit is None else min(limit,
                                                            self.pool.n_free)
        while budget > 0 and queue.n_ready > 0:
            if can_admit is not None and not can_admit(queue.peek_ready()):
                break
            req = queue.pop_ready()
            assert req is not None and req.state == PENDING
            slot = self.pool.alloc()
            req.slot = slot
            req.state = RUNNING
            req.t_admit = now
            self.running[slot] = req
            admitted.append((slot, req))
            self.n_admitted += 1
            budget -= 1
        return admitted

    # -- retirement --------------------------------------------------------
    def retire(self, slot: int, now: float, deferred: bool,
               early: bool = False) -> Request:
        """Remove the request in `slot` from M_S and free the slot.
        `deferred` routes it to the M_L queue; `early` marks an in-flight
        eviction (saved M_S steps)."""
        req = self.running.pop(slot)
        req.slot = None
        req.t_retire = now
        req.deferred = deferred
        req.early_exited = early
        if deferred:
            req.state = DEFERRED
        else:
            req.state = DONE
            req.t_done = now
        self.pool.release(slot)
        self.n_retired += 1
        return req

    # -- views -------------------------------------------------------------
    @property
    def active_slots(self) -> List[int]:
        return sorted(self.running)

    @property
    def n_active(self) -> int:
        return len(self.running)

    def check_invariants(self) -> None:
        """Assert slot accounting is consistent (used by tests)."""
        in_use = self.pool.in_use
        assert set(self.running) == in_use, (self.running, in_use)
        assert len(in_use) + self.pool.n_free == self.pool.n_slots
