"""Admission/retirement scheduler for the continuous-batching engine.

Each engine step the scheduler:
  1. releases newly arrived requests into the ready FIFO,
  2. admits ready requests into free cache-pool slots (strict FIFO — a
     request never overtakes an earlier arrival),
  3. after the decode step, retires finished or in-flight-deferred
     requests and returns their slots to the pool.

Invariants (pinned by tests/test_serving_continuous.py):
  * a slot hosts at most one request at a time;
  * admitted set + free set is always exactly {0..n_slots-1};
  * admission order equals arrival order.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.serving.cache_pool import SlotCachePool
from repro.serving.request import (DEFERRED, DONE, PENDING, RUNNING,
                                   ArrivalQueue, Request)


class SlotScheduler:
    def __init__(self, pool: SlotCachePool):
        self.pool = pool
        self.running: Dict[int, Request] = {}     # slot -> request

    # -- admission ---------------------------------------------------------
    def admit_ready(self, queue: ArrivalQueue, now: float,
                    limit: Optional[int] = None
                    ) -> List[Tuple[int, Request]]:
        """Admit FIFO-ready requests into free slots. Returns
        [(slot, request), ...] in admission order."""
        queue.release(now)
        admitted: List[Tuple[int, Request]] = []
        budget = self.pool.n_free if limit is None else min(limit,
                                                            self.pool.n_free)
        while budget > 0 and queue.n_ready > 0:
            req = queue.pop_ready()
            assert req is not None and req.state == PENDING
            slot = self.pool.alloc()
            req.slot = slot
            req.state = RUNNING
            req.t_admit = now
            self.running[slot] = req
            admitted.append((slot, req))
            budget -= 1
        return admitted

    # -- retirement --------------------------------------------------------
    def retire(self, slot: int, now: float, deferred: bool,
               early: bool = False) -> Request:
        """Remove the request in `slot` from M_S and free the slot.
        `deferred` routes it to the M_L queue; `early` marks an in-flight
        eviction (saved M_S steps)."""
        req = self.running.pop(slot)
        req.slot = None
        req.t_retire = now
        req.deferred = deferred
        req.early_exited = early
        if deferred:
            req.state = DEFERRED
        else:
            req.state = DONE
            req.t_done = now
        self.pool.release(slot)
        return req

    # -- views -------------------------------------------------------------
    @property
    def active_slots(self) -> List[int]:
        return sorted(self.running)

    @property
    def n_active(self) -> int:
        return len(self.running)

    def check_invariants(self) -> None:
        """Assert slot accounting is consistent (used by tests)."""
        in_use = self.pool.in_use
        assert set(self.running) == in_use, (self.running, in_use)
        assert len(in_use) + self.pool.n_free == self.pool.n_slots
