"""Cascade serving engines (paper Fig. 1 deployment: M_S local, M_L remote,
confidence gate g).

Two engines share the same models and calibration:

`CascadeEngine` — the static reference path. Lock-step batches: M_S
prefills + greedy-decodes every request for the full `max_new` tokens
(now in a single on-device `fori_loop`, one host transfer per batch),
then requests whose mean eq.-8 negative predictive entropy falls below
tau are regenerated from scratch by M_L.

`ContinuousCascadeEngine` — the continuous-batching serving subsystem.
A slot-based KV-cache pool (`cache_pool.SlotCachePool`) is allocated once;
a scheduler (`scheduler.SlotScheduler`) admits pending requests into free
slots every step and retires finished or deferred ones. The jitted step
decodes ALL slots at once at per-slot positions (ragged depths — see
`models.attention.gqa_decode`) and accumulates the confidence sum on
device; only tiny per-slot control vectors cross to host each step.
**In-flight deferral**: once a request has decoded `min_tokens` tokens,
a running mean confidence below `tau - margin` evicts it from M_S
immediately — the remaining M_S decode steps are saved — and queues it
for batched M_L regeneration. With `early_exit=False` the continuous
engine is token-for-token identical to the static engine under greedy
decoding (pinned by tests/test_serving_continuous.py).

Metrics mirror the paper (deferral ratio, per-request confidence,
cost_small + r * cost_large) plus serving telemetry (tokens/s, latency
percentiles, early-exit savings) from `telemetry.ServingTelemetry`.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.core.calibration import (expected_compute_cost,
                                    threshold_for_deferral_ratio)
from repro.models import transformer as tfm
from repro.serving.cache_pool import SlotCachePool, scatter_rows
from repro.serving.request import DONE, ArrivalQueue, Request, make_requests
from repro.serving.scheduler import SlotScheduler
from repro.serving.telemetry import ServingTelemetry
from repro.sharding import ParallelContext


def _neg_entropy(logits: jnp.ndarray) -> jnp.ndarray:
    """Eq. 8 confidence: negative predictive entropy, computed in fp32."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.sum(jnp.exp(logp) * logp, axis=-1)


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray            # [B, max_new] final (post-cascade) tokens
    small_tokens: np.ndarray
    confidence: np.ndarray        # [B] mean per-step neg entropy (eq. 8)
    deferred: np.ndarray          # [B] bool
    deferral_ratio: float
    compute_cost: float
    steps: int


class ModelRunner:
    """Jit-compiled prefill + decode for one model.

    `generate` runs the whole greedy loop on device (`lax.fori_loop` over
    decode steps, tokens accumulated into a preallocated buffer) and
    transfers the token matrix + confidence vector to host ONCE — the old
    implementation round-tripped every token.
    """

    def __init__(self, cfg: ModelConfig, params: Any,
                 ctx: Optional[ParallelContext] = None,
                 max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or ParallelContext()
        self.max_len = max_len
        self._gen_fns: Dict[Tuple[int, int], Any] = {}

    def _generate_impl(self, params, prompts, *, prompt_len: int,
                       max_new: int):
        cfg, ctx = self.cfg, self.ctx
        B = prompts.shape[0]
        cache = tfm.init_cache(cfg, B, prompt_len + max_new,
                               dtype=cfg.cdtype())
        logits, cache = tfm.prefill(params, cfg, prompts, cache, ctx,
                                    last_only=True)
        last = logits[:, -1, :]
        tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        conf_sum = _neg_entropy(last)
        buf = jnp.zeros((B, max_new), jnp.int32).at[:, 0].set(tok)

        def body(i, carry):
            tok, conf_sum, cache, buf = carry
            step_logits, cache = tfm.decode_step(params, cfg, tok,
                                                 prompt_len + i, cache, ctx)
            tok = jnp.argmax(step_logits, axis=-1).astype(jnp.int32)
            conf_sum = conf_sum + _neg_entropy(step_logits)
            buf = buf.at[:, i + 1].set(tok)
            return tok, conf_sum, cache, buf

        _, conf_sum, _, buf = jax.lax.fori_loop(
            0, max_new - 1, body, (tok, conf_sum, cache, buf))
        return buf, conf_sum / max_new

    def generate(self, prompts: np.ndarray, prompt_len: int,
                 max_new: int) -> Tuple[np.ndarray, np.ndarray]:
        """Greedy generation. prompts [B, prompt_len]. Returns
        (tokens [B, max_new], mean_neg_entropy [B]) — one device->host
        transfer for the whole batch."""
        key = (prompt_len, max_new)
        fn = self._gen_fns.get(key)
        if fn is None:
            fn = jax.jit(functools.partial(self._generate_impl,
                                           prompt_len=prompt_len,
                                           max_new=max_new))
            self._gen_fns[key] = fn
        tokens, conf = fn(self.params, jnp.asarray(prompts))
        return np.asarray(tokens), np.asarray(conf)


class CascadeEngine:
    """Two-ModelRunner cascade with a calibrated threshold (static,
    lock-step batches — the reference path)."""

    def __init__(self, small: ModelRunner, large: ModelRunner,
                 tau: float = -1.0, cost_small: float = 0.2,
                 cost_large: float = 1.0):
        self.small = small
        self.large = large
        self.tau = tau
        self.cost_small = cost_small
        self.cost_large = cost_large

    def calibrate(self, val_prompts: np.ndarray, prompt_len: int,
                  max_new: int, deferral_ratio: float) -> float:
        _, conf = self.small.generate(val_prompts, prompt_len, max_new)
        # shared Stage-3 helper: consistent `deferred = conf < tau`
        # semantics (incl. the ratio<=0 / ratio>=1 sentinels) with
        # core.calibration users.
        self.tau = threshold_for_deferral_ratio(conf, deferral_ratio)
        return self.tau

    def serve(self, prompts: np.ndarray, prompt_len: int,
              max_new: int) -> ServeResult:
        s_tokens, conf = self.small.generate(prompts, prompt_len, max_new)
        deferred = conf < self.tau
        tokens = s_tokens.copy()
        if deferred.any():
            idx = np.nonzero(deferred)[0]
            l_tokens, _ = self.large.generate(prompts[idx], prompt_len,
                                              max_new)
            tokens[idx] = l_tokens
        ratio = float(deferred.mean())
        return ServeResult(
            tokens=tokens, small_tokens=s_tokens, confidence=conf,
            deferred=deferred, deferral_ratio=ratio,
            compute_cost=expected_compute_cost(ratio, self.cost_small,
                                               self.cost_large),
            steps=max_new)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ContinuousServeResult:
    requests: List[Request]
    tokens: np.ndarray            # [N, max_new] final tokens, rid order
    confidence: np.ndarray        # [N] mean neg entropy at retirement
    deferred: np.ndarray          # [N] bool
    early_exited: np.ndarray      # [N] bool (in-flight deferrals)
    deferral_ratio: float
    saved_steps: int              # M_S decode steps skipped via early exit
    steps: int                    # engine decode steps executed
    stats: Dict[str, Any]         # telemetry summary


class ContinuousCascadeEngine:
    """Continuous-batching cascade over a slot-based KV pool.

    Per-slot device state (all [n_slots] unless noted):
      last_tok  — input token for the next decode step
      pos       — absolute decode position (per-slot ragged depths)
      n_gen     — tokens generated so far (prefill token counts as 1)
      budget    — per-slot token budget (request's max_new); a slot
                  self-deactivates on device when n_gen reaches it
      conf_sum  — running eq.-8 negative-entropy sum (ON DEVICE)
      active    — slot currently hosts a running request
      tokens    — [n_slots, max_new] output buffer, transferred at retire

    `large_batch=None` defers M_L regeneration to end-of-run exact-size
    batches (bit-identical to the static path); an int flushes padded
    batches of that size as soon as enough deferrals accumulate.

    `steps_per_sync` > 1 enables multi-step scheduling: the jitted step
    runs that many decode steps before the host syncs the control
    vectors, amortizing dispatch overhead. Admission, retirement, and
    eviction then happen at chunk granularity (greedy outputs are
    unchanged — finished slots self-deactivate on device).
    """

    def __init__(self, small: ModelRunner, large: ModelRunner,
                 n_slots: int = 8, tau: float = -1.0,
                 margin: float = 0.0, min_tokens: int = 2,
                 early_exit: bool = True,
                 large_batch: Optional[int] = None,
                 steps_per_sync: int = 1,
                 cost_small: float = 0.2, cost_large: float = 1.0):
        self.small = small
        self.large = large
        self.n_slots = n_slots
        self.tau = tau
        self.margin = margin
        self.min_tokens = max(1, min_tokens)
        self.early_exit = early_exit
        self.large_batch = large_batch
        self.steps_per_sync = max(1, steps_per_sync)
        self.cost_small = cost_small
        self.cost_large = cost_large
        self._fns: Dict[Tuple[int, int], Tuple[Any, Any]] = {}

    # -- calibration (same Stage-3 helper as the static engine) -----------
    def calibrate(self, val_prompts: np.ndarray, prompt_len: int,
                  max_new: int, deferral_ratio: float) -> float:
        _, conf = self.small.generate(val_prompts, prompt_len, max_new)
        self.tau = threshold_for_deferral_ratio(conf, deferral_ratio)
        return self.tau

    # -- jitted device programs -------------------------------------------
    def _build_fns(self, prompt_len: int, max_new: int, pool: SlotCachePool):
        cfg, ctx = self.small.cfg, self.small.ctx
        n_slots, pool_len = pool.n_slots, pool.max_len
        batch_axes = pool.batch_axes

        def admit_fn(params, prompts, slots, budgets, cache, state):
            """Batched prefill of newly admitted prompts into a fresh
            cache, scattered into the pool rows `slots`."""
            k = prompts.shape[0]
            fresh = tfm.init_cache(cfg, k, pool_len, dtype=cfg.cdtype())
            logits, fresh = tfm.prefill(params, cfg, prompts, fresh, ctx,
                                        last_only=True)
            last = logits[:, -1, :]
            tok0 = jnp.argmax(last, axis=-1).astype(jnp.int32)
            conf0 = _neg_entropy(last)
            cache = scatter_rows(cache, fresh, slots, batch_axes)
            row0 = jnp.zeros((k, max_new), jnp.int32).at[:, 0].set(tok0)
            state = {
                "last_tok": state["last_tok"].at[slots].set(tok0),
                "pos": state["pos"].at[slots].set(prompt_len),
                "n_gen": state["n_gen"].at[slots].set(1),
                "budget": state["budget"].at[slots].set(budgets),
                "conf_sum": state["conf_sum"].at[slots].set(conf0),
                "active": state["active"].at[slots].set(budgets > 1),
                "tokens": state["tokens"].at[slots].set(row0),
            }
            return cache, state

        def one_step(carry, _):
            """One decode step over ALL slots at per-slot positions;
            inactive slots compute but their state/cache rows are inert
            (overwritten on next admission). Slots self-deactivate when
            n_gen reaches their budget so multi-step chunks never decode
            past a request's max_new."""
            params, cache, state = carry
            logits, cache = tfm.decode_step(params, cfg, state["last_tok"],
                                            state["pos"], cache, ctx)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            neg_ent = _neg_entropy(logits)
            act = state["active"]
            inc = act.astype(jnp.int32)
            rows = jnp.arange(n_slots)
            col = jnp.clip(state["n_gen"], 0, max_new - 1)
            cur = state["tokens"][rows, col]
            n_gen = state["n_gen"] + inc
            state = {
                "last_tok": jnp.where(act, tok, state["last_tok"]),
                "pos": state["pos"] + inc,
                "n_gen": n_gen,
                "budget": state["budget"],
                "conf_sum": state["conf_sum"]
                + jnp.where(act, neg_ent, 0.0),
                "active": act & (n_gen < state["budget"]),
                "tokens": state["tokens"].at[rows, col].set(
                    jnp.where(act, tok, cur)),
            }
            return (params, cache, state), None

        def step_fn(params, cache, state):
            (_, cache, state), _ = jax.lax.scan(
                one_step, (params, cache, state), None,
                length=self.steps_per_sync)
            return cache, state

        return jax.jit(admit_fn), jax.jit(step_fn)

    # -- host-side control loop -------------------------------------------
    def run(self, requests: List[Request], prompt_len: int, max_new: int,
            audit_path: Optional[str] = None) -> ContinuousServeResult:
        cfg = self.small.cfg
        for r in requests:
            # a run can never decode past its own max_new; clamp so the
            # device budget, retirement check, and saved-step accounting
            # all agree for heterogeneous requests
            r.max_new = min(r.max_new, max_new)
        pool = SlotCachePool(cfg, self.n_slots, prompt_len + max_new)
        sched = SlotScheduler(pool)
        queue = ArrivalQueue(requests)
        tel = ServingTelemetry(audit_path)

        key = (prompt_len, max_new)
        fns = self._fns.get(key)
        if fns is None:
            fns = self._build_fns(prompt_len, max_new, pool)
            self._fns[key] = fns
        admit_fn, step_fn = fns

        S = self.n_slots
        state = {
            "last_tok": jnp.zeros((S,), jnp.int32),
            "pos": jnp.zeros((S,), jnp.int32),
            "n_gen": jnp.zeros((S,), jnp.int32),
            "budget": jnp.full((S,), max_new, jnp.int32),
            "conf_sum": jnp.zeros((S,), jnp.float32),
            "active": jnp.zeros((S,), bool),
            "tokens": jnp.zeros((S, max_new), jnp.int32),
        }
        deferred_wait: List[Request] = []
        n_steps = 0
        tel.reset_clock()

        def sync_retire():
            """Pull the tiny control vectors, retire finished / in-flight
            deferred slots, release them, and deactivate on device."""
            nonlocal state
            n_gen = np.asarray(state["n_gen"])
            conf_sum = np.asarray(state["conf_sum"])
            toks = None
            retired: List[int] = []
            now = tel.now
            for slot in sched.active_slots:
                req = sched.running[slot]
                n = int(n_gen[slot])
                mean = float(conf_sum[slot]) / max(n, 1)
                finished = n >= req.max_new
                evict = (not finished and self.early_exit
                         and n >= self.min_tokens
                         and mean < self.tau - self.margin)
                if not (finished or evict):
                    continue
                if toks is None:
                    toks = np.asarray(state["tokens"])
                req.n_small_steps = n
                req.confidence = mean
                req.small_tokens = toks[slot, :n].copy()
                defer = mean < self.tau if finished else True
                sched.retire(slot, now, deferred=defer, early=evict)
                if defer:
                    deferred_wait.append(req)
                else:
                    req.tokens = toks[slot].copy()
                tel.event("retire", rid=req.rid, slot=slot,
                          reason=("defer_early" if evict else
                                  "defer_final" if defer else "finish"),
                          n_gen=n, confidence=round(mean, 6))
                retired.append(slot)
            if retired:
                state = dict(state)
                state["active"] = state["active"].at[
                    jnp.asarray(retired)].set(False)

        def flush_large(batch: List[Request], pad_to: Optional[int]):
            if not batch:
                return
            batch = sorted(batch, key=lambda r: r.rid)
            prompts = np.stack([r.prompt for r in batch])
            b = len(batch)
            if pad_to is not None and b < pad_to:
                prompts = np.concatenate(
                    [prompts, np.repeat(prompts[:1], pad_to - b, axis=0)])
            l_tokens, _ = self.large.generate(prompts, prompt_len, max_new)
            now = tel.now
            for i, req in enumerate(batch):
                req.tokens = l_tokens[i].copy()
                req.state = DONE
                req.t_done = now
            tel.event("large_batch", rids=[r.rid for r in batch],
                      padded=max(pad_to - b, 0) if pad_to else 0)

        while len(queue) or sched.n_active:
            admitted = sched.admit_ready(queue, tel.now)
            if admitted:
                slots = jnp.asarray([s for s, _ in admitted])
                prompts = jnp.asarray(
                    np.stack([r.prompt for _, r in admitted]))
                budgets = jnp.asarray([r.max_new for _, r in admitted],
                                      jnp.int32)
                pool.cache, state = admit_fn(self.small.params, prompts,
                                             slots, budgets, pool.cache,
                                             state)
                tel.event("admit", rids=[r.rid for _, r in admitted],
                          slots=[s for s, _ in admitted])
                sync_retire()        # min_tokens=1 / max_new=1 edge cases
            if sched.n_active:
                pool.cache, state = step_fn(self.small.params, pool.cache,
                                            state)
                n_steps += self.steps_per_sync
                sync_retire()
            elif len(queue):
                nxt = queue.next_arrival
                if nxt is not None:
                    time.sleep(min(max(nxt - tel.now, 0.0), 1e-3) + 1e-5)
            if (self.large_batch is not None
                    and len(deferred_wait) >= self.large_batch):
                flush_large(deferred_wait[:self.large_batch],
                            self.large_batch)
                del deferred_wait[:self.large_batch]

        # drain: pad to large_batch when set (shape-stable M_L compile);
        # exact-size otherwise (bit-identical to the static path)
        flush_large(deferred_wait, self.large_batch)
        makespan = tel.now
        tel.close()

        reqs = sorted(requests, key=lambda r: r.rid)
        result = ContinuousServeResult(
            requests=reqs,
            tokens=np.stack([r.tokens for r in reqs]),
            confidence=np.array([r.confidence for r in reqs]),
            deferred=np.array([r.deferred for r in reqs]),
            early_exited=np.array([r.early_exited for r in reqs]),
            deferral_ratio=float(np.mean([r.deferred for r in reqs])),
            saved_steps=sum(r.saved_steps for r in reqs),
            steps=n_steps,
            stats=tel.summary(reqs, makespan, self.cost_small,
                              self.cost_large),
        )
        return result

    # -- convenience: match the static engine's serve() signature ---------
    def serve(self, prompts: np.ndarray, prompt_len: int,
              max_new: int) -> ContinuousServeResult:
        return self.run(make_requests(prompts, max_new), prompt_len, max_new)
