"""Cascade serving engines (paper Fig. 1 deployment: M_S local, M_L remote,
confidence gate g).

Two engines share the same models and calibration:

`CascadeEngine` — the static reference path. Lock-step batches: M_S
prefills + greedy-decodes every request for the full `max_new` tokens
(in a single on-device `fori_loop`, one host transfer per batch), then
requests whose mean eq.-8 negative predictive entropy falls below tau
are regenerated from scratch by M_L.

`ContinuousCascadeEngine` — the continuous-batching serving subsystem.
Requests carry their own prompt lengths (ragged admission); a scheduler
(`scheduler.SlotScheduler`) admits pending requests into free slots every
step and retires finished or deferred ones. The jitted step decodes ALL
slots at once at per-slot positions and accumulates the confidence sum on
device; only tiny per-slot control vectors cross to host each step.
**In-flight deferral**: once a request has decoded `min_tokens` tokens,
a running mean confidence below `tau - margin` evicts it from M_S
immediately — the remaining M_S decode steps are saved — and queues it
for batched M_L regeneration.

Two selectable KV-cache backends (`backend=`):

  * ``"slot"``  — `cache_pool.SlotCachePool`: one dense row of
    `max(prompt_len + max_new)` positions per slot, allocated once.
    Ragged prompts are admitted in per-length groups (batched prefill per
    distinct length); every slot pays the worst-case row.
  * ``"paged"`` — `paged_pool.PagedCachePool`: fixed-size blocks + a
    per-slot page table; blocks are mapped on demand as each request's
    frontier advances and freed at retirement, so a short request never
    pays for the longest one. Long prompts prefill in fixed-size chunks
    (`prefill_chunk`) interleaved with decode steps, so a long arrival
    never stalls resident requests' decoding.

Parity guarantees (pinned by tests): with `early_exit=False` the
continuous engine is token-for-token identical to the static engine
under greedy decoding on uniform workloads, for BOTH backends; on ragged
workloads each request's greedy tokens equal a standalone
`ModelRunner.generate` run of that single request.

Metrics mirror the paper (deferral ratio, per-request confidence,
cost_small + r * cost_large) plus serving telemetry (tokens/s, latency
percentiles, early-exit savings, cache footprint) from
`telemetry.ServingTelemetry`.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
import warnings
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.core.calibration import (calibrate_edges, expected_compute_cost,
                                    ladder_compute_cost)
from repro.core.cascade_spec import CascadeSpec
from repro.core.deferral import SignalObservation
from repro.core.recalibration import EdgeRecalibrator
from repro.kernels import ops as kernel_ops
from repro.models import transformer as tfm
from repro.serving.cache_pool import (SlotCachePool, cache_batch_axes,
                                      scatter_rows)
from repro.serving.config import (LEGACY_KWARG_MAP, MIGRATION_HINT,
                                  EngineConfig, MLBackendConfig, PagedConfig)
from repro.serving.large_backend import make_large_backend
from repro.serving.obs import Observability
from repro.serving.obs.trace import emit_request_spans
from repro.serving.paged_pool import (BlockPressure, PagedCachePool,
                                      next_pow2)
from repro.serving.pressure import (DEFER, PREEMPT, SHED,
                                    make_pressure_policy)
from repro.serving.request import (DEFERRED_PENDING, DONE, EXPIRED,
                                   REJECTED, ArrivalQueue, Request,
                                   make_requests)
from repro.serving.scheduler import SlotScheduler
from repro.serving.telemetry import ServingTelemetry
from repro.sharding import ParallelContext


def _neg_entropy(logits: jnp.ndarray) -> jnp.ndarray:
    """Eq. 8 confidence: negative predictive entropy, computed in fp32."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.sum(jnp.exp(logp) * logp, axis=-1)


@dataclasses.dataclass
class ServeResult:
    """Static-engine output batch (rid order == input row order)."""
    tokens: np.ndarray            # [B, max_new] final (post-cascade) tokens
    small_tokens: np.ndarray
    confidence: np.ndarray        # [B] mean per-step neg entropy (eq. 8)
    deferred: np.ndarray          # [B] bool
    deferral_ratio: float
    compute_cost: float
    steps: int


class ModelRunner:
    """Jit-compiled prefill + decode for one model.

    `generate` runs the whole greedy loop on device (`lax.fori_loop` over
    decode steps, tokens accumulated into a preallocated buffer) and
    transfers the token matrix + confidence vector to host ONCE.
    """

    def __init__(self, cfg: ModelConfig, params: Any,
                 ctx: Optional[ParallelContext] = None,
                 max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or ParallelContext()
        self.max_len = max_len
        self._gen_fns: Dict[Tuple[int, int], Any] = {}

    def _generate_impl(self, params, prompts, *, prompt_len: int,
                       max_new: int):
        cfg, ctx = self.cfg, self.ctx
        B = prompts.shape[0]
        cache = tfm.init_cache(cfg, B, prompt_len + max_new,
                               dtype=cfg.cdtype())
        logits, cache = tfm.prefill(params, cfg, prompts, cache, ctx,
                                    last_only=True)
        last = logits[:, -1, :]
        tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        conf_sum = _neg_entropy(last)
        buf = jnp.zeros((B, max_new), jnp.int32).at[:, 0].set(tok)

        def body(i, carry):
            tok, conf_sum, cache, buf = carry
            step_logits, cache = tfm.decode_step(params, cfg, tok,
                                                 prompt_len + i, cache, ctx)
            tok = jnp.argmax(step_logits, axis=-1).astype(jnp.int32)
            conf_sum = conf_sum + _neg_entropy(step_logits)
            buf = buf.at[:, i + 1].set(tok)
            return tok, conf_sum, cache, buf

        _, conf_sum, _, buf = jax.lax.fori_loop(
            0, max_new - 1, body, (tok, conf_sum, cache, buf))
        return buf, conf_sum / max_new

    def generate(self, prompts: np.ndarray, prompt_len: int,
                 max_new: int) -> Tuple[np.ndarray, np.ndarray]:
        """Greedy generation. prompts [B, prompt_len]. Returns
        (tokens [B, max_new], mean_neg_entropy [B]) — one device->host
        transfer for the whole batch."""
        key = (prompt_len, max_new)
        fn = self._gen_fns.get(key)
        if fn is None:
            fn = jax.jit(functools.partial(self._generate_impl,
                                           prompt_len=prompt_len,
                                           max_new=max_new))
            self._gen_fns[key] = fn
        tokens, conf = fn(self.params, jnp.asarray(prompts))
        return np.asarray(tokens), np.asarray(conf)

    def _sample_impl(self, params, prompts, seed, *, prompt_len: int,
                     max_new: int, temperature: float):
        cfg, ctx = self.cfg, self.ctx
        B = prompts.shape[0]
        key = jax.random.PRNGKey(seed)
        cache = tfm.init_cache(cfg, B, prompt_len + max_new,
                               dtype=cfg.cdtype())
        logits, cache = tfm.prefill(params, cfg, prompts, cache, ctx,
                                    last_only=True)
        inv_t = 1.0 / temperature
        tok = jax.random.categorical(
            jax.random.fold_in(key, 0),
            logits[:, -1, :].astype(jnp.float32) * inv_t,
            axis=-1).astype(jnp.int32)
        buf = jnp.zeros((B, max_new), jnp.int32).at[:, 0].set(tok)

        def body(i, carry):
            tok, cache, buf = carry
            step_logits, cache = tfm.decode_step(params, cfg, tok,
                                                 prompt_len + i, cache, ctx)
            tok = jax.random.categorical(
                jax.random.fold_in(key, i + 1),
                step_logits.astype(jnp.float32) * inv_t,
                axis=-1).astype(jnp.int32)
            buf = buf.at[:, i + 1].set(tok)
            return tok, cache, buf

        _, _, buf = jax.lax.fori_loop(0, max_new - 1, body,
                                      (tok, cache, buf))
        return buf

    def sample(self, prompts: np.ndarray, prompt_len: int, max_new: int,
               seed: int = 0, temperature: float = 1.0) -> np.ndarray:
        """Stochastic generation (temperature sampling) for agreement-
        style deferral signals: rows draw independent per-step gumbel
        noise from one run-deterministic PRNG key, so the same
        (prompts, seed) always yields the same samples. Returns
        [B, max_new] int32 tokens."""
        if temperature <= 0:
            raise ValueError(f"temperature must be > 0, got {temperature}")
        key = (prompt_len, max_new, float(temperature), "sample")
        fn = self._gen_fns.get(key)
        if fn is None:
            fn = jax.jit(functools.partial(self._sample_impl,
                                           prompt_len=prompt_len,
                                           max_new=max_new,
                                           temperature=float(temperature)))
            self._gen_fns[key] = fn
        tokens = fn(self.params, jnp.asarray(prompts),
                    jnp.uint32(seed & 0xFFFFFFFF))
        return np.asarray(tokens)


class CascadeEngine:
    """Two-ModelRunner cascade with a calibrated threshold (static,
    lock-step uniform batches — the reference path)."""

    def __init__(self, small: ModelRunner, large: ModelRunner,
                 tau: float = -1.0, cost_small: float = 0.2,
                 cost_large: float = 1.0):
        self.small = small
        self.large = large
        self.tau = tau
        self.cost_small = cost_small
        self.cost_large = cost_large

    def calibrate(self, val_prompts: np.ndarray, prompt_len: int,
                  max_new: int, deferral_ratio: float) -> float:
        """Pick tau so `deferral_ratio` of the validation prompts fall
        below it, through the repo-wide calibration surface
        (`core.calibration.calibrate_edges`: one quantile rule, one
        ``deferred = conf < tau`` sentinel convention shared with the
        classifier cascade and the N-tier serving ladders)."""
        spec = CascadeSpec.two_tier(self.small, self.large, tau=self.tau,
                                    cost_small=self.cost_small,
                                    cost_large=self.cost_large)
        self.tau = calibrate_edges(spec, val_prompts, max_new=max_new,
                                   deferral_ratio=deferral_ratio,
                                   prompt_len=prompt_len)[0]
        return self.tau

    def serve(self, prompts: np.ndarray, prompt_len: int,
              max_new: int) -> ServeResult:
        """Serve one uniform lock-step batch: full M_S decode, then
        batched M_L regeneration of the below-tau rows."""
        s_tokens, conf = self.small.generate(prompts, prompt_len, max_new)
        deferred = conf < self.tau
        tokens = s_tokens.copy()
        if deferred.any():
            idx = np.nonzero(deferred)[0]
            l_tokens, _ = self.large.generate(prompts[idx], prompt_len,
                                              max_new)
            tokens[idx] = l_tokens
        ratio = float(deferred.mean())
        return ServeResult(
            tokens=tokens, small_tokens=s_tokens, confidence=conf,
            deferred=deferred, deferral_ratio=ratio,
            compute_cost=expected_compute_cost(ratio, self.cost_small,
                                               self.cost_large),
            steps=max_new)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ContinuousServeResult:
    """Continuous-engine output (requests sorted by rid)."""
    requests: List[Request]
    tokens: np.ndarray            # [N, max_new] final tokens, rid order
                                  # (rows with a smaller per-request
                                  # budget are zero-padded; the exact
                                  # vectors are requests[i].tokens)
    confidence: np.ndarray        # [N] mean neg entropy at retirement
    deferred: np.ndarray          # [N] bool
    early_exited: np.ndarray      # [N] bool (in-flight deferrals)
    deferral_ratio: float
    saved_steps: int              # M_S decode steps skipped via early exit
    steps: int                    # engine decode steps executed
    stats: Dict[str, Any]         # telemetry summary


class ContinuousCascadeEngine:
    """Continuous-batching N-tier cascade over a slot or block-paged KV
    pool.

    Constructed from a `core.cascade_spec.CascadeSpec` (the model
    ladder: ordered tiers, per-edge `DeferralEdge(signal, tau, margin,
    min_tokens)` gates) and a `serving.config.EngineConfig` (how to
    execute it: slots, KV backend, M_L batching, optional online tau
    recalibration). Tier 0 runs in the continuous-batching decode loop;
    each edge e hands its deferrals to an execution backend running tier
    e+1, and an intermediate tier's results are gated by the NEXT edge —
    deferred traffic from edge e is arrival traffic for edge e+1,
    through the same submit/poll/flush/drain machinery. A 2-tier spec
    reproduces the original two-model engine bit-exactly; the legacy
    flat-kwargs constructor still works via a deprecation shim
    (`config.LEGACY_KWARG_MAP`).

    With `EngineConfig.recalibration` set, a `core.recalibration
    .EdgeRecalibrator` nudges each edge's tau toward
    `recalib_target` deferral online (EWMA-gated stochastic quantile
    tracking with hysteresis); taus are fixed otherwise.

    Per-slot device state (all [n_slots] unless noted):
      last_tok  — input token for the next decode step
      pos       — absolute decode position (per-slot ragged depths)
      n_gen     — tokens generated so far (prefill token counts as 1)
      budget    — per-slot token budget (request's max_new); a slot
                  self-deactivates on device when n_gen reaches it
      conf_sum  — running eq.-8 negative-entropy sum (ON DEVICE)
      active    — slot currently hosts a decoding request
      tokens    — [n_slots, max_new] output buffer, transferred at retire

    Backends: ``backend="slot"`` preallocates one dense `max_len` cache
    row per slot (uniform worst case); ``backend="paged"`` shares
    `n_blocks` blocks of `block_size` tokens between slots through a page
    table, maps them on demand, and prefills long prompts in
    `prefill_chunk`-token chunks interleaved with resident decode steps.
    Admission is strict FIFO under both; the paged backend additionally
    gates the FIFO head on worst-case block reservation so an admitted
    request can never run out of cache mid-flight (no preemption path).

    Paged hot-path controls:

    * ``paged_kernel`` — True routes paged decode through the Pallas
      paged flash-decode kernels (kernels/paged_attention.py: page-table
      walk in-kernel, no dense gather); False forces the XLA gather
      fallback; None (default) defers to REPRO_PAGED_KERNEL / backend
      default (kernel on TPU, fallback on CPU).
    * every decode step slices the page table to the bucketed ACTIVE
      block prefix (`pool.active_prefix_blocks`), so both paths touch
      only `ceil((max_pos + steps_per_sync)/block_size)` blocks per row
      instead of all `max_blocks` — the dominant per-token HBM saving
      when residents are short.
    * ``batch_prefill`` (default True) packs same-offset prefill chunks
      of different mid-prefill requests into ONE `[B_chunk, C]` dispatch
      (per-row page tables + per-row last-index; B_chunk bucketed to a
      power of two with trash-table pad rows), instead of one request
      per engine iteration — at high arrival rates the host dispatch
      count per prompt token drops by ~the batch width. False restores
      the serial one-request-per-iteration loop (parity reference).
    * ``prefix_sharing`` (default True) — admission consults the pool's
      prefix registry: prompt blocks already resident (or cached from a
      retired request) are mapped into the new slot's page table by
      refcount instead of prefilled again, and prefill starts at the
      first unshared token. Blocks stay read-only while shared — every
      write path first runs `pool.ensure_writable`, which copy-on-write
      clones a shared block into a private one, and
      `pool.check_write_disjoint` asserts per dispatch that no physical
      block is writable from two rows (the paged write kernels' safety
      contract). Greedy outputs are bit-exact vs an unshared run.

    M_L regeneration goes through a pluggable `large_backend`
    (``"sync"`` — inline on the decode loop, the reference path;
    ``"thread"`` — a worker thread that overlaps M_L batches with M_S
    decode; ``"stub"`` — the threaded path behind a serialized
    request/response pipe with injectable latency, the shape of a real
    RPC; or a callable factory returning any `LargeBackend` — how the
    distributed socket/replica-pool backends plug in, see
    `serving.remote` and launch/serve.py). Each deferral streams into
    the backend the moment its slot
    retires; completions fold back in every engine iteration. Batch
    shape policy lives in the backend (`large_backend.BatchPolicy`):
    `large_batch=None` batches only at drain, exact-size (bit-identical
    to the static path); an int cuts per-prompt-length batches of that
    size as soon as a group fills, and `large_max_wait` seconds bound
    how long a partial group may wait before flushing padded.

    `steps_per_sync` > 1 enables multi-step scheduling: the jitted step
    runs that many decode steps before the host syncs the control
    vectors, amortizing dispatch overhead. Admission, retirement, and
    eviction then happen at chunk granularity (greedy outputs are
    unchanged — finished slots self-deactivate on device).
    """

    def __init__(self, spec, config: Optional[EngineConfig] = None,
                 **legacy):
        if isinstance(spec, CascadeSpec):
            if legacy:
                raise TypeError(
                    f"ContinuousCascadeEngine(spec, config) takes no extra "
                    f"kwargs, got {sorted(legacy)} — per-edge knobs live on "
                    f"the spec's DeferralEdges, execution knobs on "
                    f"EngineConfig")
            if config is not None and not isinstance(config, EngineConfig):
                raise TypeError(f"config must be an EngineConfig, got "
                                f"{type(config).__name__}")
            self.spec = spec
            self.config = config if config is not None else EngineConfig()
        else:
            # legacy flat-kwargs shim: (small, large, n_slots=..., tau=...,
            # ...) — every old name maps onto a spec/config field
            # (config.LEGACY_KWARG_MAP is the table) so old call sites run
            # through the exact same code path as a hand-built 2-tier spec
            small, large = spec, legacy.pop("large", config)
            if not (hasattr(small, "generate") and hasattr(large, "generate")):
                raise TypeError(
                    "ContinuousCascadeEngine needs a CascadeSpec (plus an "
                    "optional EngineConfig) or the legacy "
                    "(small, large) ModelRunner pair")
            unknown = set(legacy) - set(LEGACY_KWARG_MAP)
            if unknown:
                raise TypeError(f"unknown ContinuousCascadeEngine kwargs "
                                f"{sorted(unknown)}")
            warnings.warn(MIGRATION_HINT, DeprecationWarning, stacklevel=2)
            self.spec = CascadeSpec.two_tier(
                small, large,
                tau=legacy.get("tau", -1.0),
                margin=legacy.get("margin", 0.0),
                min_tokens=legacy.get("min_tokens", 2),
                cost_small=legacy.get("cost_small", 0.2),
                cost_large=legacy.get("cost_large", 1.0))
            self.config = EngineConfig(
                n_slots=legacy.get("n_slots", 8),
                early_exit=legacy.get("early_exit", True),
                steps_per_sync=legacy.get("steps_per_sync", 1),
                backend=legacy.get("backend", "slot"),
                paged=PagedConfig(
                    block_size=legacy.get("block_size", 16),
                    n_blocks=legacy.get("n_blocks"),
                    prefill_chunk=legacy.get("prefill_chunk"),
                    paged_kernel=legacy.get("paged_kernel"),
                    batch_prefill=legacy.get("batch_prefill", True),
                    prefix_sharing=legacy.get("prefix_sharing", True)),
                ml=MLBackendConfig(
                    kind=legacy.get("large_backend", "sync"),
                    large_batch=legacy.get("large_batch"),
                    max_wait=legacy.get("large_max_wait"),
                    stub_latency=legacy.get("stub_latency", 0.0)))
        self._fns: Dict[Tuple, Tuple] = {}

    # -- back-compat attribute surface (the legacy flat-kwarg names read —
    # and where old code mutated them, write — through to spec/config) ----
    @property
    def small(self) -> ModelRunner:
        return self.spec.tiers[0].runner

    @property
    def large(self):
        return self.spec.tiers[1].runner

    @property
    def tau(self) -> float:
        return self.spec.edges[0].tau

    @tau.setter
    def tau(self, v: float) -> None:
        self.spec.edges[0].tau = float(v)

    @property
    def margin(self) -> float:
        return self.spec.edges[0].margin

    @margin.setter
    def margin(self, v: float) -> None:
        self.spec.edges[0].margin = float(v)

    @property
    def min_tokens(self) -> int:
        return self.spec.edges[0].min_tokens

    @min_tokens.setter
    def min_tokens(self, v: int) -> None:
        self.spec.edges[0].min_tokens = max(1, int(v))

    @property
    def early_exit(self) -> bool:
        return self.config.early_exit

    @early_exit.setter
    def early_exit(self, v: bool) -> None:
        self.config.early_exit = bool(v)

    @property
    def n_slots(self) -> int:
        return self.config.n_slots

    @property
    def steps_per_sync(self) -> int:
        return self.config.steps_per_sync

    @property
    def backend(self) -> str:
        return self.config.backend

    @property
    def large_batch(self) -> Optional[int]:
        return self.config.ml.large_batch

    @property
    def large_backend(self):
        return self.config.ml.kind

    @property
    def large_max_wait(self) -> Optional[float]:
        return self.config.ml.max_wait

    @property
    def stub_latency(self) -> float:
        return self.config.ml.stub_latency

    @property
    def block_size(self) -> int:
        return self.config.paged.block_size

    @property
    def n_blocks(self) -> Optional[int]:
        return self.config.paged.n_blocks

    @property
    def prefill_chunk(self) -> Optional[int]:
        return self.config.paged.prefill_chunk

    @property
    def paged_kernel(self) -> Optional[bool]:
        return self.config.paged.paged_kernel

    @property
    def batch_prefill(self) -> bool:
        return self.config.paged.batch_prefill

    @property
    def prefix_sharing(self) -> bool:
        return self.config.paged.prefix_sharing

    @property
    def cost_small(self) -> float:
        return self.spec.tiers[0].cost

    @property
    def cost_large(self) -> float:
        return self.spec.tiers[1].cost

    # -- calibration (the repo-wide Stage-3 surface) -----------------------
    def calibrate(self, val_prompts: np.ndarray, prompt_len: int,
                  max_new: int, deferral_ratio=0.2):
        """Calibrate every edge tau on a uniform validation batch via
        `core.calibration.calibrate_edges` (edge i calibrates on the
        traffic upstream edges would defer that far). Returns the single
        tau for a 2-tier spec (legacy contract) or the per-edge list for
        deeper ladders; `deferral_ratio` may be per-edge."""
        taus = calibrate_edges(self.spec, val_prompts, max_new=max_new,
                               deferral_ratio=deferral_ratio,
                               prompt_len=prompt_len)
        return taus[0] if len(taus) == 1 else taus

    # -- jitted device programs -------------------------------------------
    def _decode_body(self, params, cache, state, pages, max_new,
                     paged_kernel=None):
        """One decode step over ALL slots at per-slot positions; inactive
        slots compute but their state/cache rows are inert. Slots
        self-deactivate when n_gen reaches their budget so multi-step
        chunks never decode past a request's max_new. In paged mode the
        page table rows of inactive slots are masked to the trash block,
        so a stale `pos` from a previous tenant can never scribble into a
        block that now belongs to someone else; `pages` arrives already
        sliced to the bucketed active block prefix, and `paged_kernel`
        picks Pallas flash-decode vs the XLA gather fallback."""
        cfg, ctx = self.small.cfg, self.small.ctx
        n_slots = state["active"].shape[0]
        if pages is not None:
            pages = jnp.where(state["active"][:, None], pages, 0)
        logits, cache = tfm.decode_step(params, cfg, state["last_tok"],
                                        state["pos"], cache, ctx,
                                        pages=pages,
                                        paged_kernel=paged_kernel)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        neg_ent = _neg_entropy(logits)
        act = state["active"]
        inc = act.astype(jnp.int32)
        rows = jnp.arange(n_slots)
        col = jnp.clip(state["n_gen"], 0, max_new - 1)
        cur = state["tokens"][rows, col]
        n_gen = state["n_gen"] + inc
        state = {
            "last_tok": jnp.where(act, tok, state["last_tok"]),
            "pos": state["pos"] + inc,
            "n_gen": n_gen,
            "budget": state["budget"],
            "conf_sum": state["conf_sum"] + jnp.where(act, neg_ent, 0.0),
            "active": act & (n_gen < state["budget"]),
            "tokens": state["tokens"].at[rows, col].set(
                jnp.where(act, tok, cur)),
        }
        return cache, state

    def _build_slot_fns(self, max_new: int, pool_len: int):
        """Jitted (admit, step) pair for the dense slot backend. `admit`
        handles one uniform-length group of newly admitted prompts (jit
        re-traces per distinct (group_size, prompt_len) shape)."""
        cfg, ctx = self.small.cfg, self.small.ctx
        batch_axes = cache_batch_axes(cfg, pool_len)

        def admit_fn(params, prompts, slots, budgets, cache, state):
            k, P = prompts.shape
            fresh = tfm.init_cache(cfg, k, pool_len, dtype=cfg.cdtype())
            logits, fresh = tfm.prefill(params, cfg, prompts, fresh, ctx,
                                        last_only=True)
            last = logits[:, -1, :]
            tok0 = jnp.argmax(last, axis=-1).astype(jnp.int32)
            conf0 = _neg_entropy(last)
            cache = scatter_rows(cache, fresh, slots, batch_axes)
            row0 = jnp.zeros((k, max_new), jnp.int32).at[:, 0].set(tok0)
            state = {
                "last_tok": state["last_tok"].at[slots].set(tok0),
                "pos": state["pos"].at[slots].set(P),
                "n_gen": state["n_gen"].at[slots].set(1),
                "budget": state["budget"].at[slots].set(budgets),
                "conf_sum": state["conf_sum"].at[slots].set(conf0),
                "active": state["active"].at[slots].set(budgets > 1),
                "tokens": state["tokens"].at[slots].set(row0),
            }
            return cache, state

        def step_fn(params, cache, state):
            def one(carry, _):
                params, cache, state = carry
                cache, state = self._decode_body(params, cache, state,
                                                 None, max_new)
                return (params, cache, state), None
            (_, cache, state), _ = jax.lax.scan(
                one, (params, cache, state), None,
                length=self.steps_per_sync)
            return cache, state

        return jax.jit(admit_fn), jax.jit(step_fn)

    def _build_paged_fns(self, max_new: int, paged_kernel: bool):
        """Jitted (prefill_chunk, finish, step) triple for the paged
        backend. `prefill_chunk` runs one `[B_chunk, C]` batch of
        same-offset chunks through the trunk at a traced cache offset,
        each row scattering K/V through its own page-table row (serial
        mode is just B_chunk == 1); `finish` seeds a slot's decode state
        from its row's last-real-position logits; `step` mirrors the slot
        backend but routes every cache access through the (active-prefix
        sliced) page table, via Pallas kernels when `paged_kernel`."""
        cfg, ctx = self.small.cfg, self.small.ctx

        def prefill_chunk_fn(params, tokens, tables, offset, last_index,
                             cache):
            logits, cache = tfm.prefill(params, cfg, tokens, cache, ctx,
                                        cache_offset=offset, pages=tables,
                                        last_index=last_index)
            return logits[:, 0, :], cache

        def finish_fn(state, slot, logits, budget, prompt_len):
            tok0 = jnp.argmax(logits[0]).astype(jnp.int32)
            conf0 = _neg_entropy(logits)[0]
            row0 = jnp.zeros((max_new,), jnp.int32).at[0].set(tok0)
            return {
                "last_tok": state["last_tok"].at[slot].set(tok0),
                "pos": state["pos"].at[slot].set(prompt_len),
                "n_gen": state["n_gen"].at[slot].set(1),
                "budget": state["budget"].at[slot].set(budget),
                "conf_sum": state["conf_sum"].at[slot].set(conf0),
                "active": state["active"].at[slot].set(budget > 1),
                "tokens": state["tokens"].at[slot].set(row0),
            }

        def step_fn(params, cache, state, tables):
            def one(carry, _):
                params, cache, state = carry
                cache, state = self._decode_body(params, cache, state,
                                                 tables, max_new,
                                                 paged_kernel=paged_kernel)
                return (params, cache, state), None
            (_, cache, state), _ = jax.lax.scan(
                one, (params, cache, state), None,
                length=self.steps_per_sync)
            return cache, state

        def resume_fn(state, slot, last_tok, pos, n_gen, conf_sum, budget,
                      row):
            """Restore a preempted request's decode state verbatim (the
            snapshot taken at preemption) — the counterpart of
            `finish_fn` for re-admission. Continuing from restored state
            over restored/recomputed KV is bit-exact with never having
            been preempted."""
            return {
                "last_tok": state["last_tok"].at[slot].set(last_tok),
                "pos": state["pos"].at[slot].set(pos),
                "n_gen": state["n_gen"].at[slot].set(n_gen),
                "budget": state["budget"].at[slot].set(budget),
                "conf_sum": state["conf_sum"].at[slot].set(conf_sum),
                "active": state["active"].at[slot].set(n_gen < budget),
                "tokens": state["tokens"].at[slot].set(row),
            }

        return (jax.jit(prefill_chunk_fn), jax.jit(finish_fn),
                jax.jit(step_fn), jax.jit(resume_fn))

    # -- host-side control loop -------------------------------------------
    def run(self, requests: List[Request], max_new: Optional[int] = None,
            audit_path: Optional[str] = None, *,
            prompt_len: Optional[int] = None,
            obs=None) -> ContinuousServeResult:
        """Serve `requests` (each carrying its own prompt and budget).

        `max_new` is the run-wide token-buffer width and budget cap
        (default: the largest request budget); per-request `max_new`
        larger than it is clamped so the device budget, retirement check,
        and saved-step accounting agree.

        `obs` selects the observability surface (`repro.serving.obs`):
        ``None`` (default) keeps only the always-on bounded metrics +
        phase attribution; an `ObsConfig` makes the engine build the
        runtime, run, and export/finish it (the one-shot CLI/bench
        path); a prebuilt `Observability` is caller-owned — the engine
        feeds it but never finishes it (e.g. serve.py keeping the
        /metrics endpoint open across runs). Instrumentation never
        changes what the device computes: greedy outputs are bit-exact
        with observability on or off.

        .. deprecated:: the old ``run(requests, prompt_len, max_new)``
           call shape is gone — prompt lengths are per-request
           (`Request.prompt_len`). Passing `prompt_len` (or the old
           positional layout) raises TypeError.
        """
        if prompt_len is not None:
            raise TypeError(
                "ContinuousCascadeEngine.run() no longer takes prompt_len: "
                "each Request carries its own prompt length "
                "(Request.prompt_len). Call run(requests, max_new) — for "
                "the old uniform behavior just pass uniform-length "
                "prompts to make_requests().")
        if isinstance(audit_path, (int, np.integer)):
            raise TypeError(
                "ContinuousCascadeEngine.run() signature changed: the old "
                "run(requests, prompt_len, max_new) call shape is "
                "deprecated. Prompt lengths are per-request now — call "
                "run(requests, max_new, audit_path=...).")
        if not requests:
            raise ValueError("run() needs at least one request")
        cfg = self.small.cfg
        if max_new is None:
            max_new = max(r.max_new for r in requests)
        for r in requests:
            # a run can never decode past its own max_new; clamp so the
            # device budget, retirement check, and saved-step accounting
            # all agree for heterogeneous requests
            r.max_new = min(r.max_new, max_new)
        max_len = max(r.prompt_len + r.max_new for r in requests)
        paged = self.backend == "paged"

        pressure = self.config.paged.pressure if paged else None
        policy = (make_pressure_policy(pressure.policy,
                                       pressure.max_preemptions)
                  if pressure is not None else None)
        if paged:
            bs = self.block_size
            n_blocks = (self.n_blocks if self.n_blocks is not None
                        else self.n_slots * math.ceil(max_len / bs))
            biggest = max(math.ceil((r.prompt_len + r.max_new - 1) / bs)
                          for r in requests)
            if n_blocks < biggest:
                # each request must fit the PHYSICAL budget on its own:
                # oversubscription stretches the admission (virtual)
                # budget, never physical capacity
                raise ValueError(
                    f"n_blocks={n_blocks} cannot hold the largest request "
                    f"({biggest} blocks of {bs}); raise n_blocks")
            pool = PagedCachePool(
                cfg, self.n_slots, n_blocks, bs, max_len,
                oversubscribe=(pressure.oversubscribe
                               if pressure is not None else 1.0),
                swap_blocks=(pressure.swap_blocks
                             if pressure is not None else 0))
            use_kernel = kernel_ops.paged_kernel_enabled(self.paged_kernel)
            fkey = ("paged", max_new, n_blocks, bs, pool.max_blocks,
                    use_kernel)
            fns = self._fns.get(fkey)
            if fns is None:
                fns = self._build_paged_fns(max_new, use_kernel)
                self._fns[fkey] = fns
            prefill_fn, finish_fn, step_fn, resume_fn = fns
        else:
            pool = SlotCachePool(cfg, self.n_slots, max_len)
            fkey = ("slot", max_new, max_len)
            fns = self._fns.get(fkey)
            if fns is None:
                fns = self._build_slot_fns(max_new, max_len)
                self._fns[fkey] = fns
            admit_fn, step_fn = fns

        sched = SlotScheduler(pool)
        queue = ArrivalQueue(requests, max_queue=self.config.max_queue)
        # engine-level deadline default; an explicit per-request deadline
        # (e.g. from make_requests(deadline_s=...)) wins
        if self.config.deadline_s is not None:
            for r in requests:
                if r.deadline is None:
                    r.deadline = r.arrival_time + self.config.deadline_s
        overload_on = (queue.max_queue is not None
                       or any(r.deadline is not None for r in requests))
        # a passed-in Observability is caller-owned; anything else
        # (None or an ObsConfig) the engine builds and finishes itself
        own_obs = not isinstance(obs, Observability)
        obs_rt = obs if isinstance(obs, Observability) else Observability(obs)
        if own_obs:
            obs_rt.start_server()
        tr = obs_rt.tracer
        dev_timer = obs_rt.device_timer
        profiler = obs_rt.profiler
        # the audit-log handle must be released even when setup or the
        # serve loop raises: ServingTelemetry is a context manager, and
        # the worker backend gets its own try/finally inside (a leaked
        # worker thread spins its poll loop for the life of the process)
        tel = ServingTelemetry(audit_path, obs=obs_rt)
        spec = self.spec
        n_edges = len(spec.edges)
        last_tier = spec.n_tiers - 1
        edge0 = spec.edges[0]
        # online tau recalibration: one controller per edge, seeded from
        # the configured (offline) taus; None = fixed taus, the
        # parity-pinned default
        recal = None
        if self.config.recalibration is not None:
            recal = EdgeRecalibrator(list(spec.taus),
                                     self.config.recalib_target,
                                     self.config.recalibration)

        def edge_tau(e: int) -> float:
            return recal.tau(e) if recal is not None else spec.edges[e].tau

        backends: List[Any] = []
        try:
            S = self.n_slots
            state = {
                "last_tok": jnp.zeros((S,), jnp.int32),
                "pos": jnp.zeros((S,), jnp.int32),
                "n_gen": jnp.zeros((S,), jnp.int32),
                "budget": jnp.full((S,), max_new, jnp.int32),
                "conf_sum": jnp.zeros((S,), jnp.float32),
                "active": jnp.zeros((S,), bool),
                "tokens": jnp.zeros((S, max_new), jnp.int32),
            }
            # paged: requests admitted to a slot but still prefilling,
            # FIFO of [request, slot, next chunk offset]
            prefilling: List[List] = []
            n_steps = 0
            n_prefill_chunks = 0
            n_prefill_dispatches = 0
            n_prefill_tokens = 0
            n_shared_tokens = 0
            peak_active = 0
            # memory-pressure accounting (oversubscribed paged runs)
            n_oom_defers = 0
            n_relief = 0
            # one execution backend per edge: backends[e] runs tier e+1.
            # A tier's own `backend` wins; otherwise config.ml.kind.
            # Only edge 0's backend registers metrics (the registry's
            # metric names are unique per run; edge 0 is the legacy
            # surface the dashboards already chart).
            cfg_ml = self.config.ml
            for e in range(n_edges):
                tier = spec.tiers[e + 1]
                kind = tier.backend if tier.backend is not None \
                    else cfg_ml.kind
                backends.append(make_large_backend(
                    kind, tier.runner, max_new,
                    cfg_ml.large_batch, cfg_ml.max_wait,
                    cfg_ml.stub_latency,
                    registry=tel.registry if e == 0 else None))
            ml = backends[0]
            by_rid = {r.rid: r for r in requests}
            ml_depths: List[int] = []
            # pull-mode gauges: evaluated only when someone scrapes
            # /metrics or renders the registry — zero loop cost
            reg = tel.registry
            reg.gauge("serving_active_slots",
                      "requests resident in M_S decode slots",
                      fn=lambda: sched.n_active)
            reg.gauge("serving_queue_ready",
                      "arrived requests awaiting slot admission",
                      fn=lambda: queue.n_ready)
            reg.gauge("serving_requests_admitted",
                      "requests admitted into slots (lifetime)",
                      fn=lambda: sched.n_admitted)
            reg.gauge("serving_requests_retired",
                      "requests retired from slots (lifetime)",
                      fn=lambda: sched.n_retired)
            reg.gauge("serving_preemptions",
                      "requests preempted under block pressure (lifetime)",
                      fn=lambda: sched.n_preempted)
            if paged:
                pool.register_metrics(reg)
            # host mirrors of the device confidence accumulators, used
            # only when span tracing is on to derive the per-token
            # confidence record from per-sync deltas of conf_sum
            conf_prev = np.zeros(S, np.float64)
            ngen_prev = np.zeros(S, np.int64)
            tel.reset_clock()

            edge_deferrals = [0] * n_edges

            def submit_large(req: Request, edge: int):
                """Stream one deferral across `edge` into tier edge+1's
                backend the moment the upstream tier lets go of it — the
                rest of the ladder keeps working while that tier
                regenerates."""
                edge_deferrals[edge] += 1
                req.tier = edge + 1
                req.state = DEFERRED_PENDING
                req.t_submit_large = tel.now
                backends[edge].submit([req])
                tel.event("large_submit", rid=req.rid, edge=edge,
                          depth=backends[edge].n_pending)

            def total_pending() -> int:
                return sum(b.n_pending for b in backends)

            def poll_large():
                """Fold completed regenerations back into the run. A
                result from backends[e] is tier e+1's output: at the last
                tier it is final; at an intermediate tier it is gated by
                edge e+1 — below tau it becomes arrival traffic for the
                next backend, above it the request retires here."""
                for e, be in enumerate(backends):
                    for res in be.poll():
                        req = by_rid[res.rid]
                        tier = e + 1
                        now = tel.now
                        if tier < last_tier:
                            edge = spec.edges[tier]
                            sig = edge.signal
                            if sig.supports_running:
                                conf = float(res.confidence)
                            else:
                                conf = float(sig.finalize(SignalObservation(
                                    prompt=req.prompt,
                                    mean_confidence=float(res.confidence),
                                    tokens=np.asarray(res.tokens, np.int32),
                                    runner=spec.tiers[tier].runner,
                                    max_new=max_new, rid=req.rid)))
                            tau_e = edge_tau(tier)
                            defer = conf < tau_e
                            if recal is not None:
                                recal.observe(tier, conf)
                            tel.event("tier_gate", rid=req.rid, tier=tier,
                                      edge=tier, confidence=round(conf, 6),
                                      tau=round(tau_e, 6), deferred=defer)
                            if defer:
                                submit_large(req, tier)
                                continue
                        # accepted at this tier: final tokens, trimmed to
                        # the request's own budget (backends pad
                        # generation width to the run-wide max_new)
                        req.tier = tier
                        req.tokens = np.asarray(
                            res.tokens, np.int32)[:req.max_new].copy()
                        req.state = DONE
                        req.t_done = now
                        tel.m_tokens.labels(model="large").inc(
                            len(req.tokens))
                        tel.event("large_complete", rid=req.rid, tier=tier,
                                  batch_id=res.batch_id, n_real=res.n_real,
                                  pad_to=res.pad_to, reason=res.reason,
                                  wait_ms=round(
                                      (now - req.t_submit_large) * 1e3, 3))

            def sync_retire():
                """Pull the tiny control vectors, retire finished /
                in-flight deferred slots, release them, and deactivate on
                device. Slots still prefilling are skipped — their device
                state is stale until the final chunk seeds it."""
                nonlocal state
                mid_prefill = {s for _, s, _ in prefilling}
                n_gen = np.asarray(state["n_gen"])
                conf_sum = np.asarray(state["conf_sum"])
                toks = None
                retired: List[int] = []
                now = tel.now
                sig0 = edge0.signal
                for slot in sched.active_slots:
                    if slot in mid_prefill:
                        continue
                    req = sched.running[slot]
                    n = int(n_gen[slot])
                    mean = float(conf_sum[slot]) / max(n, 1)
                    finished = n >= req.max_new
                    tau0 = edge_tau(0)
                    # in-flight deferral needs a running form of the
                    # signal; signals without one (k-sample agreement)
                    # can only gate at full retirement
                    evict = (not finished and self.early_exit
                             and sig0.supports_running
                             and n >= edge0.min_tokens
                             and sig0.running(mean, n) < tau0 - edge0.margin)
                    if not (finished or evict):
                        continue
                    if toks is None:
                        toks = np.asarray(state["tokens"])
                    req.n_small_steps = n
                    req.small_tokens = toks[slot, :n].copy()
                    if evict:
                        conf, defer = mean, True
                    else:
                        conf = (mean if sig0.supports_running
                                else float(sig0.finalize(SignalObservation(
                                    prompt=req.prompt, mean_confidence=mean,
                                    tokens=req.small_tokens,
                                    runner=self.small, max_new=req.max_new,
                                    rid=req.rid))))
                        defer = conf < tau0
                    req.confidence = conf
                    if recal is not None:
                        recal.observe(0, conf)
                    sched.retire(slot, now, deferred=defer, early=evict)
                    if defer:
                        submit_large(req, 0)
                    else:
                        req.tokens = toks[slot, :req.max_new].copy()
                    reason = ("defer_early" if evict else
                              "defer_final" if defer else "finish")
                    tel.event("retire", rid=req.rid, slot=slot,
                              reason=reason, n_gen=n,
                              confidence=round(mean, 6))
                    tel.m_requests.labels(outcome=reason).inc()
                    if not defer:
                        tel.m_tokens.labels(model="small").inc(
                            len(req.tokens))
                    retired.append(slot)
                if retired:
                    state = dict(state)
                    state["active"] = state["active"].at[
                        jnp.asarray(retired)].set(False)

            def seed_conf_trace(pairs):
                """Start each newly decoding request's per-token
                confidence record from its prefill seed value (tracing
                mode only; one transfer for the whole batch)."""
                cs = np.asarray(state["conf_sum"])
                ng = np.asarray(state["n_gen"])
                for slot, req in pairs:
                    conf_prev[slot] = float(cs[slot])
                    ngen_prev[slot] = int(ng[slot])
                    req.conf_trace = [round(conf_prev[slot], 6)]

            def record_conf_trace(decoding):
                """Extend the per-token confidence records from per-sync
                deltas of the device-accumulated conf_sum (tracing mode
                only — sync_retire transfers these vectors right after,
                so no extra device work is forced; with steps_per_sync>1
                each entry is the chunk's mean)."""
                cs, ng = jax.device_get((state["conf_sum"],
                                         state["n_gen"]))
                for slot in decoding:
                    req = sched.running[slot]
                    dn = int(ng[slot]) - int(ngen_prev[slot])
                    if req.conf_trace is not None and dn > 0:
                        req.conf_trace.append(round(
                            (float(cs[slot]) - conf_prev[slot]) / dn, 6))
                    conf_prev[slot] = float(cs[slot])
                    ngen_prev[slot] = int(ng[slot])

            def admit_slot_groups(admitted):
                """Slot backend: batched prefill per distinct prompt length
                (mixed lengths can't share one dense prefill shape;
                grouping keeps each group's math identical to a uniform
                run)."""
                nonlocal state
                by_len: Dict[int, List[Tuple[int, Request]]] = {}
                for s, r in admitted:
                    by_len.setdefault(r.prompt_len, []).append((s, r))
                for P, group in sorted(by_len.items()):
                    slots = jnp.asarray([s for s, _ in group])
                    prompts = jnp.asarray(np.stack([r.prompt
                                                    for _, r in group]))
                    budgets = jnp.asarray([r.max_new for _, r in group],
                                          jnp.int32)
                    pool.cache, state = admit_fn(self.small.params, prompts,
                                                 slots, budgets, pool.cache,
                                                 state)
                now = tel.now
                for _, r in admitted:
                    r.t_prefill_done = now
                if tr is not None:
                    seed_conf_trace(admitted)

            def run_prefill_chunk():
                """Paged backend: run one chunk of the oldest mid-prefill
                request — PLUS, with `batch_prefill`, the same-offset
                chunks of every other mid-prefill request — in a single
                dispatch, so long prompts interleave with resident decode
                steps and simultaneous arrivals don't serialize on host
                overhead. Under oversubscription the chunk's CoW clones
                can hit BlockPressure; pressure strikes before the
                dispatch mutates anything, so after a policy eviction
                (which may remove mid-prefill entries) the whole chunk
                simply restarts against the survivors."""
                while prefilling:
                    try:
                        return _prefill_chunk_once()
                    except BlockPressure:
                        if policy is None or not relieve_pressure():
                            raise

            def _prefill_chunk_once():
                """One prefill dispatch. Before it, every row's chunk
                span is made write-private (`ensure_writable` CoW-clones
                a shared tail block) and the rows' writable blocks are
                asserted pairwise disjoint — the paged write paths'
                contract. A resumed request's chunks stop at
                `prefill_end` (its decode-written tail is restored from
                the preemption snapshot instead of recomputed)."""
                nonlocal state, n_prefill_chunks, n_prefill_dispatches, \
                    n_prefill_tokens
                head_req, _, off0 = prefilling[0]
                C = self.prefill_chunk or (prefill_end(head_req) - off0)
                if self.batch_prefill:
                    # pack every request at the head's offset whose chunk
                    # width matches (differing widths only arise with
                    # prefill_chunk=None, where C spans the whole
                    # unshared prompt tail)
                    group = [e for e in prefilling if e[2] == off0
                             and (self.prefill_chunk
                                  or prefill_end(e[0]) - e[2]) == C]
                else:
                    group = [prefilling[0]]
                k = len(group)
                for req, slot, off in group:
                    pool.ensure_writable(slot, off, off + C)
                pool.check_write_disjoint(
                    (slot, off, off + C) for _, slot, off in group)
                # bucket the dispatch width to a power of two: pad rows
                # write to the trash block, their logits are ignored
                Bc = next_pow2(k)
                chunks = np.zeros((Bc, C), np.int32)
                tbl = np.zeros((Bc, pool.max_blocks), np.int32)
                last_idx = np.zeros((Bc,), np.int32)
                for i, (req, slot, off) in enumerate(group):
                    piece = req.prompt[off:off + C]
                    chunks[i, :piece.shape[0]] = piece  # right-pad final
                    tbl[i] = pool.tables[slot]          # chunk; padded
                    last_idx[i] = min(prefill_end(req) - 1 - off, C - 1)
                    n_prefill_tokens += int(piece.shape[0])  # K/V -> trash
                logits, pool.cache = prefill_fn(
                    self.small.params, jnp.asarray(chunks), jnp.asarray(tbl),
                    off0, jnp.asarray(last_idx), pool.cache)
                if dev_timer.enabled:
                    t_dev = tel.now
                    jax.block_until_ready((logits, pool.cache))
                    tel.phase_add("prefill", 0.0, tel.now - t_dev)
                n_prefill_dispatches += 1
                n_prefill_chunks += k
                seeded: List[Tuple[int, Request]] = []
                for i, entry in enumerate(group):
                    req, slot, off = entry
                    if off + C >= prefill_end(req):   # final chunk
                        prefilling.remove(entry)
                        if req.resume is not None:
                            # prompt blocks re-established: restore the
                            # decode tail + device rows; the row's seed
                            # logits are ignored (the snapshot carries
                            # the in-flight token instead)
                            apply_resume(slot, req)
                            if self.prefix_sharing:
                                pool.register_prefix(slot, req.prompt)
                            continue
                        state = finish_fn(state, slot, logits[i:i + 1],
                                          req.max_new, req.prompt_len)
                        if self.prefix_sharing:
                            # publish the fully-written prompt blocks so
                            # later same-prefix arrivals can map them
                            pool.register_prefix(slot, req.prompt)
                        req.t_prefill_done = tel.now
                        tel.event("prefill_done", rid=req.rid, slot=slot,
                                  chunks=math.ceil(
                                      max(req.prompt_len
                                          - req.shared_prefix_tokens, 1)
                                      / C),
                                  shared=req.shared_prefix_tokens)
                        seeded.append((slot, req))
                    else:
                        entry[2] = off + C
                if seeded:
                    if tr is not None:
                        seed_conf_trace(seeded)
                    sync_retire()        # max_new == 1: already finished

            def decoding_slots() -> List[int]:
                mid_prefill = {s for _, s, _ in prefilling}
                return [s for s in sched.active_slots
                        if s not in mid_prefill]

            # -- pressure machinery (oversubscribed paged pool only) ----
            def prefill_end(req: Request) -> int:
                """Last token (exclusive) the engine must PREFILL for this
                admission: the full prompt for a fresh request; for a
                resumed one only up to the first decode-written block —
                everything past that boundary is restored verbatim from
                the preemption snapshot, never recomputed (decode-written
                K/V is not bit-identical under a prefill recompute)."""
                return (req.prompt_len if req.resume is None
                        else req.resume["mb0"] * bs)

            def apply_resume(slot: int, req: Request) -> None:
                """Re-establish a preempted request in its new slot:
                restore the decode-written blocks from the host snapshot
                over the freshly mapped tail, then restore the device
                decode state verbatim. From here the request decodes as
                if it had never been evicted."""
                nonlocal state
                rs = req.resume
                pool.restore_block_span(slot, rs["mb0"] * bs,
                                        rs["ctx_len"], rs["blocks"])
                state = resume_fn(state, slot, rs["last_tok"], rs["pos"],
                                  rs["n_gen"], rs["conf_sum"],
                                  req.max_new, rs["tokens"])
                conf_prev[slot] = rs["conf_sum"]
                ngen_prev[slot] = rs["n_gen"]
                req.resume = None
                tel.event("resume", rid=req.rid, slot=slot,
                          n_gen=rs["n_gen"],
                          restored_blocks=len(rs["blocks"]))

            def preempt_slot(slot: int) -> None:
                """Evict the request in `slot` under block pressure with
                bit-exact resume state: snapshot its device rows and the
                blocks holding decode-written K/V, publish its prompt
                blocks in the prefix registry (resurrection makes the
                prompt recompute mostly a registry walk), release the
                slot, and requeue the request at its ORIGINAL arrival
                position (age-priority — repeated preemption cannot
                starve it behind fresh traffic)."""
                nonlocal state
                req = sched.running[slot]
                entry = next((e for e in prefilling if e[1] == slot), None)
                if entry is not None:
                    # mid-prefill victim: no decode state exists yet —
                    # keep the chunks already written via the registry
                    # and requeue as a plain re-admission
                    prefilling.remove(entry)
                    pool.register_prefix(slot, req.prompt[:entry[2]])
                    req.resume = None
                else:
                    lt, ps, ng, cs, toks = jax.device_get(
                        (state["last_tok"], state["pos"], state["n_gen"],
                         state["conf_sum"], state["tokens"]))
                    g = int(ng[slot])
                    ctx_len = req.prompt_len + g - 1
                    assert int(ps[slot]) == ctx_len, (slot, ps[slot],
                                                      ctx_len)
                    mb0 = req.prompt_len // bs
                    req.resume = {
                        "last_tok": int(lt[slot]), "pos": int(ps[slot]),
                        "n_gen": g, "conf_sum": float(cs[slot]),
                        "tokens": np.asarray(toks[slot]).copy(),
                        "ctx_len": ctx_len, "mb0": mb0,
                        "blocks": pool.save_block_span(slot, mb0 * bs,
                                                       ctx_len),
                    }
                    pool.register_prefix(slot, req.prompt)
                    state = dict(state)
                    state["active"] = state["active"].at[slot].set(False)
                sched.preempt(slot, tel.now)
                queue.requeue(req)
                tel.event("preempt", rid=req.rid, slot=slot,
                          n_preempted=req.n_preempted,
                          mid_prefill=entry is not None)

            def defer_oom(slot: int) -> None:
                """Defer the victim straight up the cascade ladder — the
                cascade's escape hatch under memory pressure: its blocks
                free immediately and the request still completes, on the
                next tier (`deferred_reason="oom"`)."""
                nonlocal state, n_oom_defers
                req = sched.running[slot]
                entry = next((e for e in prefilling if e[1] == slot), None)
                if entry is not None:
                    prefilling.remove(entry)
                    req.n_small_steps = 0
                    req.small_tokens = np.zeros(0, np.int32)
                else:
                    ng, cs, toks = jax.device_get(
                        (state["n_gen"], state["conf_sum"],
                         state["tokens"]))
                    n = int(ng[slot])
                    req.n_small_steps = n
                    req.small_tokens = np.asarray(toks[slot, :n]).copy()
                    req.confidence = float(cs[slot]) / max(n, 1)
                    state = dict(state)
                    state["active"] = state["active"].at[slot].set(False)
                req.deferred_reason = "oom"
                n_oom_defers += 1
                sched.retire(slot, tel.now, deferred=True, early=True)
                tel.event("defer_oom", rid=req.rid, slot=slot,
                          n_gen=req.n_small_steps)
                tel.m_requests.labels(outcome="defer_oom").inc()
                submit_large(req, 0)

            def finalize_shed(req: Request, terminal: str,
                              reason: str) -> None:
                """Terminal bookkeeping for a shed request: empty token
                vector, REJECTED/EXPIRED state, audit-log event, and the
                outcome counter — exactly once per request."""
                req.state = terminal
                req.tokens = np.zeros(0, np.int32)
                req.t_done = tel.now
                tel.event("shed", rid=req.rid, reason=reason,
                          outcome=terminal)
                tel.m_requests.labels(outcome=terminal).inc()

            def shed_slot(slot: int) -> None:
                """Drop an in-flight victim (shed pressure policy)."""
                nonlocal state
                req = sched.running[slot]
                entry = next((e for e in prefilling if e[1] == slot), None)
                if entry is not None:
                    prefilling.remove(entry)
                else:
                    state = dict(state)
                    state["active"] = state["active"].at[slot].set(False)
                sched.drop(slot, tel.now)
                finalize_shed(req, REJECTED, "shed_pressure")

            def relieve_pressure(exclude=()) -> bool:
                """Free physical blocks by evicting one deterministic
                victim (youngest admission) per the pressure policy.
                False when no victim exists — the caller must surface
                the pressure as a hard error."""
                nonlocal n_relief
                sel = policy.select(sched.running, exclude)
                if sel is None:
                    return False
                slot, action = sel
                if action == PREEMPT:
                    preempt_slot(slot)
                elif action == DEFER:
                    defer_oom(slot)
                else:
                    assert action == SHED, action
                    shed_slot(slot)
                n_relief += 1
                return True

            def with_relief(fn, needy=()):
                """Run `fn`, relieving `BlockPressure` by policy eviction
                and retrying (the pool's mapping calls are idempotent,
                so a retry resumes exactly where pressure struck).
                `needy` slots are exempt from victim selection — evicting
                the slot being mapped would livelock its own retry."""
                while True:
                    try:
                        return fn()
                    except BlockPressure:
                        if policy is None or not relieve_pressure(needy):
                            raise

            try:
                while len(queue) or sched.n_active:
                    t_it = tel.now
                    if profiler.enabled:
                        profiler.tick()
                    if overload_on:
                        # admission overload control BEFORE admitting:
                        # release arrivals into the ready queue, shed
                        # deadline-expired entries, then bound the queue
                        # (newest-first overflow). Admitted requests are
                        # never expired — deadlines gate queueing only.
                        queue.release(t_it)
                        for r in queue.expire(t_it):
                            finalize_shed(r, EXPIRED, "deadline")
                        for r in queue.shed_overflow():
                            finalize_shed(r, REJECTED, "queue_full")
                    if paged:
                        # admit one at a time: each admission reserves its
                        # blocks immediately, so the capacity check for the
                        # next FIFO head sees the updated reservation
                        admitted = []
                        relief0 = n_relief
                        while True:
                            got = sched.admit_ready(
                                queue, tel.now, limit=1,
                                can_admit=lambda r: pool.can_reserve(
                                    r.prompt_len + r.max_new - 1))
                            if not got:
                                break
                            slot, req = got[0]
                            pool.reserve(slot,
                                         req.prompt_len + req.max_new - 1)
                            rs = req.resume
                            end = prefill_end(req)
                            start = 0
                            if self.prefix_sharing or rs is not None:
                                # map already-resident (or cached) prefix
                                # blocks by refcount; prefill resumes at
                                # the first unshared token. A fully-shared
                                # prompt still recomputes its final token
                                # for the seed logits — run_prefill_chunk
                                # CoW-clones that block before the write.
                                # Preempted requests walk the same chain:
                                # their prompt blocks were registered at
                                # eviction, so the resume recompute is
                                # mostly (often entirely) a registry walk.
                                shared = pool.share_prefix(slot, req.prompt)
                                if rs is None:
                                    start = min(shared, req.prompt_len - 1)
                                    req.shared_prefix_tokens = start
                                    n_shared_tokens += start
                                else:
                                    start = min(shared, end)
                            L = req.prompt_len if rs is None \
                                else rs["ctx_len"]
                            with_relief(lambda s=slot, n=L:
                                        pool.ensure_mapped(s, n),
                                        needy=(slot,))
                            if rs is not None and start >= end:
                                # every surviving prompt block came
                                # straight from the registry: restore the
                                # decode-written tail and resume now
                                apply_resume(slot, req)
                            else:
                                prefilling.append([req, slot, start])
                            admitted.append((slot, req))
                            if n_relief != relief0:
                                # pressure fired while admitting: stop —
                                # admitting more this iteration would
                                # thrash straight back into it
                                break
                        if admitted:
                            tel.event("admit",
                                      rids=[r.rid for _, r in admitted],
                                      slots=[s for s, _ in admitted],
                                      shared=[r.shared_prefix_tokens
                                              for _, r in admitted])
                        t_sched = tel.now
                        did_prefill = bool(prefilling)
                        if did_prefill:
                            run_prefill_chunk()
                    else:
                        admitted = sched.admit_ready(queue, tel.now)
                        t_sched = tel.now
                        did_prefill = bool(admitted)
                        if admitted:
                            admit_slot_groups(admitted)
                            tel.event("admit",
                                      rids=[r.rid for _, r in admitted],
                                      slots=[s for s, _ in admitted])
                            sync_retire()   # min_tokens=1 / max_new=1 edges
                    t_prefill = tel.now
                    tel.phase_add("schedule", t_sched - t_it)
                    if did_prefill:
                        tel.phase_add("prefill", t_prefill - t_sched)
                    peak_active = max(peak_active, sched.n_active)
                    decoding = decoding_slots()
                    if paged and decoding:
                        # mapping the decode cover can hit BlockPressure
                        # under oversubscription: relieve (the victim may
                        # itself be a decoding slot) and redo the prep
                        # against the survivors — ensure_mapped /
                        # ensure_writable are idempotent, so the retry
                        # resumes exactly where pressure struck
                        while True:
                            decoding = decoding_slots()
                            if not decoding:
                                break
                            try:
                                pos_host = np.asarray(state["pos"])
                                need = 1
                                covers = {}
                                for slot in decoding:
                                    req = sched.running[slot]
                                    total = (req.prompt_len
                                             + req.max_new - 1)
                                    cover = min(int(pos_host[slot])
                                                + self.steps_per_sync,
                                                total)
                                    pool.ensure_mapped(slot, cover)
                                    # decode writes [pos, cover):
                                    # CoW-clone any still-shared block in
                                    # that span so the in-flight write
                                    # scatter stays row-disjoint
                                    pool.ensure_writable(
                                        slot, int(pos_host[slot]), cover)
                                    covers[slot] = cover
                                    need = max(need, cover)
                                pool.check_write_disjoint(
                                    (s, int(pos_host[s]), c)
                                    for s, c in covers.items())
                                break
                            except BlockPressure:
                                if (policy is None
                                        or not relieve_pressure()):
                                    raise
                    t_dec = tel.now
                    if decoding:
                        if paged:
                            # active-prefix tightening: hand the jitted step
                            # only the bucketed block prefix the masks can
                            # reach — the gather/kernel walk shrinks with it
                            mb = pool.active_prefix_blocks(need)
                            pool.cache, state = step_fn(
                                self.small.params, pool.cache, state,
                                pool.tables_device(mb))
                        else:
                            pool.cache, state = step_fn(self.small.params,
                                                        pool.cache, state)
                        if dev_timer.enabled:
                            t_dev = tel.now
                            jax.block_until_ready(state)
                            dec_dev = tel.now - t_dev
                        else:
                            dec_dev = 0.0
                        n_steps += self.steps_per_sync
                        tel.event("step", slots=decoding,
                                  n=self.steps_per_sync,
                                  ml_pending=ml.n_pending)
                        if tr is not None:
                            record_conf_trace(decoding)
                        sync_retire()
                        t_dec_end = tel.now
                        tel.phase_add("decode", t_dec_end - t_dec, dec_dev)
                        tel.m_decode_step.observe(
                            (t_dec_end - t_dec) / self.steps_per_sync)
                    elif not sched.n_active and len(queue):
                        nxt = queue.next_arrival
                        if nxt is not None:
                            time.sleep(min(max(nxt - tel.now, 0.0), 1e-3)
                                       + 1e-5)
                        t_dec_end = tel.now
                    else:
                        t_dec_end = t_dec
                    t_poll = tel.now
                    ml_depths.append(ml.n_pending)
                    poll_large()
                    t_end = tel.now
                    tel.phase_add("ml_wait", t_end - t_poll)
                    if tr is not None:
                        # engine-iteration span + nested phase spans on
                        # the engine track (shared timestamps guarantee
                        # proper nesting in the exported trace)
                        if admitted:
                            tr.complete("schedule", "engine", t_it,
                                        t_sched - t_it, 0)
                        if did_prefill:
                            tr.complete("prefill", "engine", t_sched,
                                        t_prefill - t_sched, 0)
                        if decoding:
                            tr.complete("decode", "engine", t_dec,
                                        t_dec_end - t_dec, 0)
                        tr.complete("ml_poll", "engine", t_poll,
                                    t_end - t_poll, 0)
                        tr.complete("iteration", "engine", t_it,
                                    t_end - t_it, 0,
                                    args={"n_active": sched.n_active,
                                          "ml_pending": ml.n_pending})

                # all M_S work is done: drain the ladder edge by edge.
                # Backend e is flushed only once every backend upstream of
                # it is empty — deferred traffic from edge e-1 is edge e's
                # arrival traffic, so flushing earlier would cut partial
                # batches that a sequential reference run would have
                # batched together. Remote backends advertise
                # drain_stall_timeout: when a replica dies mid-drain and
                # nothing can make progress, abort with the pending count
                # instead of spinning forever
                t_drain = tel.now
                stalls = [getattr(b, "drain_stall_timeout", None)
                          for b in backends]
                stall_s = min((s for s in stalls if s is not None),
                              default=None)
                last_pending = total_pending()
                t_progress = time.perf_counter()
                while True:
                    for e, be in enumerate(backends):
                        if all(backends[u].n_pending == 0
                               for u in range(e)):
                            be.flush()
                    poll_large()
                    pending = total_pending()
                    if not pending:
                        break
                    if pending != last_pending:
                        last_pending = pending
                        t_progress = time.perf_counter()
                    elif (stall_s is not None
                          and time.perf_counter() - t_progress > stall_s):
                        names = [f"{getattr(b, 'name', '?')}:"
                                 f"{b.n_pending}" for b in backends]
                        raise RuntimeError(
                            f"M_L drain stalled: {pending} deferral(s) "
                            f"still pending ({', '.join(names)}) with no "
                            f"progress for {stall_s}s")
                    time.sleep(2e-3)
                makespan = tel.now
                tel.phase_add("drain", makespan - t_drain)
                if tr is not None:
                    tr.complete("drain", "engine", t_drain,
                                makespan - t_drain, 0)
            finally:
                for be in backends:
                    be.close()
        finally:
            # a still-open jax.profiler window must be stopped even when
            # the run raises (leaking one poisons later profiled runs)
            profiler.close()
            tel.close()

        reqs = sorted(requests, key=lambda r: r.rid)
        if tr is not None:
            # request-lifecycle spans come from the recorded timestamps,
            # so their cost is paid once here, not in the serve loop
            emit_request_spans(tr, reqs)
        stats = tel.summary(reqs, makespan, self.cost_small,
                            self.cost_large)
        stats["backend"] = self.backend
        stats["cache_bytes"] = pool.footprint_bytes()
        stats["peak_active"] = peak_active
        stats["ml_backend"] = getattr(ml, "name",
                                      str(self.large_backend))
        stats["ml_batches"] = len(ml.batch_log)
        stats["ml_batch_occupancy"] = (
            float(np.mean([b["n_real"] / max(b["pad_to"], 1)
                           for b in ml.batch_log]))
            if ml.batch_log else float("nan"))
        stats["ml_queue_depth_peak"] = int(max(ml_depths, default=0))
        stats["ml_queue_depth_mean"] = (float(np.mean(ml_depths))
                                        if ml_depths else 0.0)
        # ladder accounting: reach[i] = fraction of traffic that paid
        # tier i (tier 0 always 1.0); compute_cost generalizes
        # cost_small + r * cost_large — bitwise identical for 2 tiers
        n_req = len(reqs)
        reach = [1.0] + [edge_deferrals[e] / n_req for e in range(n_edges)]
        stats["n_tiers"] = spec.n_tiers
        stats["tier_names"] = [t.name for t in spec.tiers]
        stats["tier_served"] = [sum(1 for r in reqs if r.tier == i)
                                for i in range(spec.n_tiers)]
        stats["edge_deferrals"] = list(edge_deferrals)
        stats["edge_tau"] = [edge_tau(e) for e in range(n_edges)]
        stats["edge_signal"] = [ed.signal.name for ed in spec.edges]
        stats["tier_reach"] = reach
        stats["compute_cost"] = ladder_compute_cost(reach, spec.costs)
        if n_edges > 1:
            stats["ml_backends"] = [getattr(b, "name", "?")
                                    for b in backends]
            stats["ml_batches_per_edge"] = [len(b.batch_log)
                                            for b in backends]
        if recal is not None:
            stats["recalibration"] = recal.summary()
        if paged:
            stats.update(block_size=self.block_size,
                         n_blocks=pool.n_blocks,
                         peak_blocks=pool.peak_mapped,
                         prefill_chunks=n_prefill_chunks,
                         prefill_dispatches=n_prefill_dispatches,
                         prefill_tokens=n_prefill_tokens,
                         prefix_sharing=self.prefix_sharing,
                         shared_tokens=n_shared_tokens,
                         shared_blocks=pool.shared_blocks_total,
                         cow_clones=pool.cow_clones,
                         paged_kernel=use_kernel)
            if pressure is not None:
                stats.update(oversubscribe=pressure.oversubscribe,
                             virtual_blocks=pool.virtual_blocks,
                             pressure_policy=pressure.policy,
                             pressure_reliefs=n_relief,
                             swap_blocks=pressure.swap_blocks,
                             swap_outs=pool.swap_outs,
                             swap_ins=pool.swap_ins,
                             swapped_blocks=pool.n_swapped_blocks)
        if own_obs:
            # engine-owned runtime: export the trace / metrics dump and
            # stop the endpoint now that the stats are final
            obs_rt.finish()
        # per-request final tokens are trimmed to each request's budget;
        # the matrix view pads the short rows back to the run width
        tokens = np.zeros((len(reqs), max_new), np.int32)
        for i, r in enumerate(reqs):
            tokens[i, :len(r.tokens)] = r.tokens
        result = ContinuousServeResult(
            requests=reqs,
            tokens=tokens,
            confidence=np.array([r.confidence for r in reqs]),
            deferred=np.array([r.deferred for r in reqs]),
            early_exited=np.array([r.early_exited for r in reqs]),
            deferral_ratio=float(np.mean([r.deferred for r in reqs])),
            saved_steps=sum(r.saved_steps for r in reqs),
            steps=n_steps,
            stats=stats,
        )
        return result

    # -- convenience: match the static engine's serve() signature ---------
    def serve(self, prompts: np.ndarray, prompt_len: int,
              max_new: int) -> ContinuousServeResult:
        """Uniform-batch convenience wrapper (static-engine signature);
        `prompt_len` must match the prompt matrix width."""
        if prompts.shape[1] != prompt_len:
            raise ValueError(f"prompt_len {prompt_len} != prompts width "
                             f"{prompts.shape[1]}")
        return self.run(make_requests(prompts, max_new), max_new)
