"""Pluggable M_L regeneration backends for the continuous cascade engine.

The engine used to regenerate deferred requests inline on the decode
loop (`flush_large`), so every M_L batch stalled all resident M_S
requests.  This module turns M_L into a *backend* behind a small
submit/poll/drain protocol so the engine can stream each deferral out
the moment its slot retires and keep decoding while M_L works:

    ``LargeBackend`` protocol
        submit(requests) -> ticket   enqueue deferred requests
        poll(timeout=None) -> finished   completed work so far; blocks up
                                     to `timeout` s for the first result
        flush()                      no more submissions; release partials
        drain()          -> finished block until every ticket completes
        close()                      stop worker resources

Three implementations, all sharing one batching policy (`BatchPolicy`)
so batch *shape* decisions live here rather than in the engine:

``SyncLocalBackend``
    The old behavior, extracted: batches run inline in `submit`/`flush`
    on the caller's thread (M_S decode blocks while M_L runs).  The
    parity reference.

``ThreadedBackend``
    A worker thread owns its own `ModelRunner.generate` loop on a
    queue.  Deferrals batch by prompt-length group up to `large_batch`,
    with a max-wait timer so partial groups don't starve when the batch
    never fills.  M_S decode proceeds concurrently: jax releases the
    GIL while XLA executes, so the small model's decode steps interleave
    with large-model regeneration on the worker.

``RemoteStubBackend``
    The shape of a real RPC: requests and responses cross an in-process
    byte pipe as serialized JSON payloads (no Python objects shared with
    the worker), with injectable per-batch network latency.  Swap the
    pipe for a socket and this is a remote M_L server.

Greedy parity is bit-exact per request across all three backends (and
order-independent): every backend regenerates through the same
`ModelRunner.generate` per prompt-length group, and XLA's row-wise
decode makes per-request tokens independent of batch composition —
pinned by tests/test_serving_async.py.
"""
from __future__ import annotations

import dataclasses
import json
import math
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Protocol, Tuple

import numpy as np

from repro.serving.request import Request

# flush reasons recorded per batch (telemetry / no-starvation tests)
FLUSH_FULL = "full"          # a prompt-length group reached large_batch
FLUSH_MAX_WAIT = "max_wait"  # oldest pending exceeded max_wait
FLUSH_DRAIN = "drain"        # end-of-run drain

# batch occupancy is a fraction in (0, 1]: fixed fine-grained buckets
OCCUPANCY_BUCKETS = (0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0)


class _BackendMetrics:
    """Optional metrics hooks shared by every backend. Built from a
    `MetricsRegistry` (observability layer) or as a no-op; the worker
    thread records through it, the engine thread scrapes — the registry
    primitives are lock-protected."""

    def __init__(self, registry=None, backend=None):
        self.enabled = registry is not None
        if not self.enabled:
            return
        self._batches = registry.counter(
            "serving_ml_batches_total",
            "M_L regeneration batches by flush reason", ("reason",))
        self._occupancy = registry.histogram(
            "serving_ml_batch_occupancy",
            "real rows / dispatched rows per M_L batch",
            buckets=OCCUPANCY_BUCKETS)
        if backend is not None:
            registry.gauge("serving_ml_queue_depth",
                           "requests submitted to the M_L backend and "
                           "not yet returned",
                           fn=lambda: backend.n_pending)

    def record_batch(self, n_real: int, pad_to: int, reason: str) -> None:
        if self.enabled:
            self._batches.labels(reason=reason).inc()
            self._occupancy.observe(n_real / max(pad_to, 1))


@dataclasses.dataclass
class LargeResult:
    """One completed M_L regeneration, as returned by `poll`/`drain`."""
    rid: int
    tokens: np.ndarray           # [max_new] int32 final tokens
    batch_id: int
    n_real: int                  # real rows in the regeneration batch
    pad_to: int                  # rows actually dispatched (>= n_real)
    reason: str                  # FLUSH_FULL | FLUSH_MAX_WAIT | FLUSH_DRAIN
    prompt_len: int
    confidence: float = math.nan  # eq.-8 mean confidence of this row
    # (nan when the backend predates the field — cascade ladders gate
    # intermediate tiers on it, the last tier ignores it)


@dataclasses.dataclass
class _Pending:
    """Backend-internal view of one submitted request (the stub backend
    reconstructs these from serialized payloads — no shared objects)."""
    rid: int
    prompt: np.ndarray
    t_submit: float              # backend-internal monotonic clock


class BatchPolicy:
    """Batch *shape* policy shared by every backend (and both the
    mid-run and end-of-run flush paths — they used to diverge).

    Pending requests group by prompt length (ragged deferrals can't
    share one prefill shape).  A group flushes when:

      * it reaches `large_batch` rows (FLUSH_FULL, no padding needed);
      * its oldest member has waited `max_wait` seconds (FLUSH_MAX_WAIT,
        padded up to `large_batch` so the compiled shape is reused by
        later partial flushes of the same hot length);
      * the run drains (FLUSH_DRAIN — padded only when the drain is a
        SINGLE length group: uniform leftovers then reuse the mid-run
        compiled shape, while multi-length ragged drains go exact-size,
        since padding every length group would just multiply M_L
        compute on shapes that are never reused again).

    `large_batch=None` means batch only at drain, exact-size (the
    bit-identical-to-static reference path).  Padding duplicates the
    group's first row; pad rows are discarded on return.
    """

    def __init__(self, large_batch: Optional[int],
                 max_wait: Optional[float] = None):
        self.large_batch = large_batch
        self.max_wait = max_wait
        self._groups: Dict[int, List[_Pending]] = {}

    def add(self, item: _Pending) -> None:
        self._groups.setdefault(int(item.prompt.shape[0]), []).append(item)

    @property
    def n_pending(self) -> int:
        return sum(len(g) for g in self._groups.values())

    def next_deadline(self) -> Optional[float]:
        """Monotonic time at which the oldest pending group times out
        (None when no timer applies)."""
        if self.max_wait is None or not self._groups:
            return None
        oldest = min(g[0].t_submit for g in self._groups.values() if g)
        return oldest + self.max_wait

    def take(self, now: float, drain: bool = False
             ) -> List[Tuple[List[_Pending], int, str]]:
        """Pop every group ready to flush. Returns
        [(rid-sorted group, pad_to, reason)] — pad_to == len(group) when
        no padding applies."""
        out: List[Tuple[List[_Pending], int, str]] = []
        drain_multi_len = drain and sum(
            1 for g in self._groups.values()
            if g and (self.large_batch is None
                      or len(g) % self.large_batch)) > 1
        for plen in sorted(self._groups):
            group = self._groups[plen]
            while (self.large_batch is not None
                   and len(group) >= self.large_batch):
                take, self._groups[plen] = (group[:self.large_batch],
                                            group[self.large_batch:])
                group = self._groups[plen]
                out.append((sorted(take, key=lambda p: p.rid),
                            self.large_batch, FLUSH_FULL))
            if not group:
                continue
            timed_out = (self.max_wait is not None
                         and now - group[0].t_submit >= self.max_wait)
            if drain or timed_out:
                pad = (self.large_batch
                       if self.large_batch is not None else len(group))
                if drain_multi_len:
                    pad = len(group)
                out.append((sorted(group, key=lambda p: p.rid), pad,
                            FLUSH_DRAIN if drain else FLUSH_MAX_WAIT))
                self._groups[plen] = []
        self._groups = {p: g for p, g in self._groups.items() if g}
        return out

    def cancel(self, rids: List[int]) -> List[int]:
        """Remove still-pending requests by rid (an engine shutting down
        mid-run withdraws its in-flight deferrals). Returns the rids
        actually removed — anything already taken into a batch keeps
        running and completes normally."""
        wanted = set(rids)
        removed: List[int] = []
        for plen, group in list(self._groups.items()):
            keep = [p for p in group if p.rid not in wanted]
            if len(keep) != len(group):
                removed.extend(p.rid for p in group if p.rid in wanted)
                self._groups[plen] = keep
        self._groups = {p: g for p, g in self._groups.items() if g}
        return removed


def _generate_batch(generate: Callable, group: List[_Pending], pad_to: int,
                    max_new: int) -> Tuple[np.ndarray, np.ndarray]:
    """Run one rid-sorted, uniform-length group through M_L, padded to
    `pad_to` rows by duplicating the first row (the compiled shape is
    then reused across partial flushes). Returns
    ([len(group), max_new] tokens, [len(group)] mean confidences)."""
    prompts = np.stack([p.prompt for p in group])
    b = len(group)
    if pad_to > b:
        prompts = np.concatenate(
            [prompts, np.repeat(prompts[:1], pad_to - b, axis=0)])
    tokens, conf = generate(prompts, int(prompts.shape[1]), max_new)
    # runners may report no confidence (conf=None) — nan rows then, the
    # LargeResult default, so only ladder-gated tiers require the signal
    conf = (np.full(b, math.nan) if conf is None
            else np.asarray(conf, np.float64))
    return tokens[:b], conf[:b]


class LargeBackend(Protocol):
    """Protocol every M_L backend implements (see module docstring).

    `poll` takes an optional `timeout`: None/0 returns whatever has
    completed without blocking; a positive value may block up to that
    long waiting for the FIRST result (the engine's drain loop uses it
    to avoid busy-waiting). Every implementation must accept the kwarg,
    even ones that never block — the engine can't know which it holds.
    """

    def submit(self, requests: List[Request]) -> int: ...
    def poll(self, timeout: Optional[float] = None) -> List[LargeResult]: ...
    def flush(self) -> None: ...
    def drain(self) -> List[LargeResult]: ...
    def close(self) -> None: ...
    @property
    def n_pending(self) -> int: ...


class SyncLocalBackend:
    """Inline M_L regeneration on the caller's thread (the engine's old
    `flush_large` behavior, extracted).  `submit` runs any batch the
    policy releases immediately — blocking M_S decode — and `drain`
    flushes the leftovers.  Zero concurrency, maximal determinism: the
    parity reference for the other backends."""

    name = "sync"

    def __init__(self, runner, max_new: int,
                 large_batch: Optional[int] = None,
                 max_wait: Optional[float] = None,
                 registry=None):
        self._generate = runner.generate
        self.max_new = max_new
        self._policy = BatchPolicy(large_batch, max_wait)
        self._results: List[LargeResult] = []
        self._n_tickets = 0
        self._n_open = 0
        self._n_batches = 0
        self.batch_log: List[Dict[str, Any]] = []
        self._metrics = _BackendMetrics(registry, self)

    def submit(self, requests: List[Request]) -> int:
        for r in requests:
            self._policy.add(_Pending(r.rid, r.prompt, time.perf_counter()))
            self._n_open += 1
        self._run_ready()
        self._n_tickets += 1
        return self._n_tickets

    def _run_ready(self, drain: bool = False) -> None:
        for group, pad_to, reason in self._policy.take(
                time.perf_counter(), drain=drain):
            tokens, conf = _generate_batch(self._generate, group, pad_to,
                                           self.max_new)
            bid = self._n_batches
            self._n_batches += 1
            self.batch_log.append({
                "batch_id": bid, "n_real": len(group), "pad_to": pad_to,
                "reason": reason,
                "prompt_len": int(group[0].prompt.shape[0])})
            self._metrics.record_batch(len(group), pad_to, reason)
            for i, p in enumerate(group):
                self._results.append(LargeResult(
                    rid=p.rid, tokens=tokens[i].copy(), batch_id=bid,
                    n_real=len(group), pad_to=pad_to, reason=reason,
                    prompt_len=int(p.prompt.shape[0]),
                    confidence=float(conf[i])))
            self._n_open -= len(group)

    def poll(self, timeout: Optional[float] = None) -> List[LargeResult]:
        # timeout is accepted for protocol conformance but meaningless
        # here: batches run inline, so results exist before poll is
        # called — there is never anything to wait for
        self._run_ready()          # max-wait timer also fires on poll
        out, self._results = self._results, []
        return out

    def flush(self) -> None:
        self._run_ready(drain=True)

    def drain(self) -> List[LargeResult]:
        self.flush()
        return self.poll()

    def close(self) -> None:
        pass

    @property
    def n_pending(self) -> int:
        return self._n_open


class _WorkerBackend:
    """Shared machinery for backends whose `ModelRunner.generate` loop
    runs on a worker thread: a submission channel in, a completion
    channel out, the `BatchPolicy` owned by the worker.  Subclasses
    define the channel encoding (`_encode_submit`/`_decode_submit`,
    `_encode_result`/`_decode_result`) and any injected latency."""

    name = "worker"

    def __init__(self, runner, max_new: int,
                 large_batch: Optional[int] = None,
                 max_wait: Optional[float] = None,
                 poll_interval: float = 0.002,
                 registry=None):
        self._generate = runner.generate
        self.max_new = max_new
        self._poll_interval = poll_interval
        self._metrics = _BackendMetrics(registry, self)
        self._policy = BatchPolicy(large_batch, max_wait)
        self._inq: "queue.Queue" = queue.Queue()
        self._outq: "queue.Queue" = queue.Queue()
        self._drain_flag = threading.Event()
        self._stop_flag = threading.Event()
        self._n_tickets = 0
        # _lock covers the worker<->main shared state: batch stats are
        # written mid-_loop while metrics gauges scrape, _error crosses
        # from the worker's except to _check_error, and _n_open is read
        # by the queue-depth gauge off the engine thread
        self._lock = threading.Lock()
        # submitted - returned
        self._n_open = 0            # guarded_by: self._lock
        self._n_batches = 0         # guarded_by: self._lock
        self.batch_log: List[Dict[str, Any]] = []  # guarded_by: self._lock
        self._error: Optional[BaseException] = None  # guarded_by: self._lock
        self._worker = threading.Thread(target=self._run_worker,
                                        daemon=True,
                                        name=f"large-{self.name}")
        self._worker.start()

    # -- channel encoding (identity for ThreadedBackend) -------------------
    def _encode_submit(self, req: Request) -> Any:
        return _Pending(req.rid, req.prompt, time.perf_counter())

    def _decode_submit(self, payload: Any) -> _Pending:
        return payload

    def _encode_result(self, res: LargeResult) -> Any:
        return res

    def _decode_result(self, payload: Any) -> LargeResult:
        return payload

    def _sleep_latency(self) -> None:
        """Injected per-batch response latency (stub backend)."""

    # -- worker thread ------------------------------------------------------
    def _run_worker(self) -> None:
        """Thread target: a worker death must surface on the caller's
        thread (via `_check_error` in poll/drain), never hang it."""
        try:
            self._loop()
        except BaseException as e:              # noqa: BLE001
            with self._lock:
                self._error = e

    def _check_error(self) -> None:
        with self._lock:
            error, n_open = self._error, self._n_open
        if error is not None:
            raise RuntimeError(
                f"M_L {self.name} backend worker died: "
                f"{error!r}") from error
        if not self._worker.is_alive() and n_open > 0 \
                and not self._stop_flag.is_set():
            raise RuntimeError(f"M_L {self.name} backend worker exited "
                               f"with {n_open} requests pending")

    def _loop(self) -> None:
        while not self._stop_flag.is_set():
            deadline = self._policy.next_deadline()
            timeout = self._poll_interval
            if deadline is not None:
                timeout = min(timeout, max(deadline - time.perf_counter(),
                                           0.0))
            try:
                payload = self._inq.get(timeout=max(timeout, 1e-4))
                self._policy.add(self._decode_submit(payload))
                continue            # keep pulling before cutting a batch
            except queue.Empty:
                pass
            drain = self._drain_flag.is_set() and self._inq.empty()
            for group, pad_to, reason in self._policy.take(
                    time.perf_counter(), drain=drain):
                tokens, conf = _generate_batch(self._generate, group, pad_to,
                                               self.max_new)
                self._sleep_latency()
                with self._lock:
                    bid = self._n_batches
                    self._n_batches += 1
                    self.batch_log.append({
                        "batch_id": bid, "n_real": len(group),
                        "pad_to": pad_to, "reason": reason,
                        "prompt_len": int(group[0].prompt.shape[0])})
                self._metrics.record_batch(len(group), pad_to, reason)
                for i, p in enumerate(group):
                    self._outq.put(self._encode_result(LargeResult(
                        rid=p.rid, tokens=tokens[i].copy(), batch_id=bid,
                        n_real=len(group), pad_to=pad_to, reason=reason,
                        prompt_len=int(p.prompt.shape[0]),
                        confidence=float(conf[i]))))

    # -- main-thread API ----------------------------------------------------
    def submit(self, requests: List[Request]) -> int:
        if self._stop_flag.is_set():
            raise RuntimeError("backend is closed")
        for r in requests:
            self._inq.put(self._encode_submit(r))
            with self._lock:
                self._n_open += 1
        self._n_tickets += 1
        return self._n_tickets

    def poll(self, timeout: Optional[float] = None) -> List[LargeResult]:
        """Completed regenerations so far (non-blocking by default;
        `timeout` blocks up to that long for the FIRST result)."""
        self._check_error()
        out: List[LargeResult] = []
        try:
            if timeout:
                out.append(self._decode_result(
                    self._outq.get(timeout=timeout)))
            while True:
                out.append(self._decode_result(self._outq.get_nowait()))
        except queue.Empty:
            pass
        with self._lock:
            self._n_open -= len(out)
        return out

    def flush(self) -> None:
        """No more submissions are coming: release partial groups."""
        self._drain_flag.set()

    def drain(self) -> List[LargeResult]:
        """Block until every submitted request has completed."""
        self.flush()
        out: List[LargeResult] = []
        while self.n_pending > 0:
            out.extend(self.poll(timeout=0.05))
        return out

    def close(self) -> None:
        self._stop_flag.set()
        self._worker.join(timeout=5.0)

    @property
    def n_pending(self) -> int:
        with self._lock:
            return self._n_open


class ThreadedBackend(_WorkerBackend):
    """Worker-thread M_L backend: deferrals stream into a queue, the
    worker batches them by prompt-length group (`large_batch` rows, or
    `max_wait` seconds, whichever first) and runs `ModelRunner.generate`
    concurrently with the engine's M_S decode loop (XLA releases the
    GIL while executing, so the two genuinely overlap on CPU too)."""

    name = "thread"


class RemoteStubBackend(_WorkerBackend):
    """RPC-shaped M_L backend: every request and response crosses the
    worker boundary as a serialized JSON payload (rid + token lists —
    no shared Python objects), with `latency` seconds of injected
    response delay per batch.  Functionally identical to
    `ThreadedBackend`; exists to pin the serialization contract a real
    remote M_L server would use."""

    name = "stub"

    def __init__(self, runner, max_new: int,
                 large_batch: Optional[int] = None,
                 max_wait: Optional[float] = None,
                 latency: float = 0.0,
                 poll_interval: float = 0.002,
                 registry=None):
        self.latency = latency
        super().__init__(runner, max_new, large_batch, max_wait,
                         poll_interval, registry)

    def _encode_submit(self, req: Request) -> bytes:
        return json.dumps({"rid": req.rid,
                           "prompt": req.prompt.tolist()}).encode()

    def _decode_submit(self, payload: bytes) -> _Pending:
        msg = json.loads(payload.decode())
        return _Pending(int(msg["rid"]),
                        np.asarray(msg["prompt"], np.int32),
                        time.perf_counter())

    def _encode_result(self, res: LargeResult) -> bytes:
        msg = {
            "rid": res.rid, "tokens": res.tokens.tolist(),
            "batch_id": res.batch_id, "n_real": res.n_real,
            "pad_to": res.pad_to, "reason": res.reason,
            "prompt_len": res.prompt_len}
        # optional field, present only when finite: JSON has no nan, and
        # pre-ladder payloads stay byte-identical
        if math.isfinite(res.confidence):
            msg["confidence"] = res.confidence
        return json.dumps(msg).encode()

    def _decode_result(self, payload: bytes) -> LargeResult:
        msg = json.loads(payload.decode())
        return LargeResult(
            rid=int(msg["rid"]),
            tokens=np.asarray(msg["tokens"], np.int32),
            batch_id=int(msg["batch_id"]), n_real=int(msg["n_real"]),
            pad_to=int(msg["pad_to"]), reason=msg["reason"],
            prompt_len=int(msg["prompt_len"]),
            confidence=float(msg.get("confidence", math.nan)))

    def _sleep_latency(self) -> None:
        if self.latency > 0:
            time.sleep(self.latency)


BACKENDS = ("sync", "thread", "stub")


def make_large_backend(kind, runner, max_new: int,
                       large_batch: Optional[int] = None,
                       max_wait: Optional[float] = None,
                       stub_latency: float = 0.0,
                       registry=None) -> LargeBackend:
    """Factory used by the engine/CLI: `kind` in {sync, thread, stub},
    or a callable `(runner=, max_new=, large_batch=, max_wait=,
    stub_latency=, registry=) -> LargeBackend` for backends that need
    extra construction context (the socket/replica-pool backends close
    over their server addresses this way — see launch/serve.py).
    `registry` (a `MetricsRegistry`) turns on per-batch metrics and the
    queue-depth gauge."""
    if callable(kind):
        return kind(runner=runner, max_new=max_new,
                    large_batch=large_batch, max_wait=max_wait,
                    stub_latency=stub_latency, registry=registry)
    if kind == "sync":
        return SyncLocalBackend(runner, max_new, large_batch, max_wait,
                                registry=registry)
    if kind == "thread":
        return ThreadedBackend(runner, max_new, large_batch, max_wait,
                               registry=registry)
    if kind == "stub":
        return RemoteStubBackend(runner, max_new, large_batch, max_wait,
                                 latency=stub_latency, registry=registry)
    raise ValueError(f"large backend must be one of {BACKENDS}, "
                     f"got {kind!r}")
