"""Stdlib-only Prometheus scrape endpoint for a `MetricsRegistry`.

``MetricsServer`` runs a ``ThreadingHTTPServer`` on a daemon thread and
answers ``GET /metrics`` with the registry's current render (text
exposition format v0.0.4). Pull-mode gauges are evaluated per scrape, so
a scrape always sees live pool/queue state, not a snapshot.

Port 0 binds an ephemeral port (tests); `serve.py --metrics-port N`
binds a fixed one for a real scraper:

    scrape_configs:
      - job_name: repro-serving
        static_configs: [{targets: ["localhost:9100"]}]
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.serving.obs.metrics import MetricsRegistry

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class MetricsServer:
    """Background /metrics endpoint bound to one registry."""

    def __init__(self, registry: MetricsRegistry, port: int = 0,
                 host: str = "127.0.0.1"):
        self.registry = registry
        server = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):              # noqa: N802 (stdlib API)
                if self.path.rstrip("/") in ("", "/metrics"):
                    body = server.registry.render().encode()
                    self.send_response(200)
                    self.send_header("Content-Type", CONTENT_TYPE)
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)
                else:
                    self.send_error(404, "try /metrics")

            def log_message(self, *a):     # silence per-request stderr
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self.host, self.port = self._httpd.server_address[:2]
        self._thread: threading.Thread = threading.Thread(
            target=self._httpd.serve_forever, daemon=True,
            name="metrics-httpd")

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def start(self) -> "MetricsServer":
        self._thread.start()
        return self

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread.is_alive():
            self._thread.join(timeout=5.0)
