"""Bounded-memory metrics registry with Prometheus text export.

Three metric types, all with O(1) memory per label set (no per-sample
retention — the registry is safe to leave enabled on an unbounded
serving run, unlike the telemetry event list):

  * ``Counter``   — monotonically increasing float (``inc``).
  * ``Gauge``     — instantaneous value, either pushed (``set``/``inc``)
    or pulled through a zero-hot-path-cost callback (``set_fn``)
    evaluated only at scrape/render time — how the engine exposes
    paged-pool occupancy and M_L queue depth without touching the
    decode loop.
  * ``Histogram`` — fixed-bucket distribution (cumulative bucket
    counts + sum + count, Prometheus semantics). Buckets are frozen at
    creation; observations never allocate.

Metrics are created through :class:`MetricsRegistry` (get-or-create by
name; re-registering a name with a different type/labels raises) and
rendered with :meth:`MetricsRegistry.render` in the Prometheus text
exposition format (v0.0.4) — served over HTTP by
``obs.httpd.MetricsServer`` or dumped to a file with ``write``.

All mutation is lock-protected: the threaded/stub M_L backends observe
batch metrics from their worker threads while the engine thread scrapes.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

# default latency buckets (seconds): micro-benchmark CPU decode steps sit
# around 1-50 ms; the tail covers slow M_L waits
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _fmt(v: float) -> str:
    """Prometheus sample value formatting: finite floats as repr
    ("1.0", "0.25"), infinities as +Inf/-Inf."""
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    return repr(float(v))


def _fmt_labels(names: Sequence[str], values: Sequence[str]) -> str:
    if not names:
        return ""
    body = ",".join(f'{n}="{_escape(v)}"' for n, v in zip(names, values))
    return "{" + body + "}"


def _escape(v: str) -> str:
    return (str(v).replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


class _Child:
    """One (labelset, value) cell of a counter/gauge family."""

    __slots__ = ("_lock", "_value", "_fn")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self._value = 0.0                  # guarded_by: self._lock
        self._fn: Optional[Callable[[], float]] = None

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self._value += v

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def set_fn(self, fn: Callable[[], float]) -> None:
        """Pull-mode gauge: `fn` is evaluated at render/scrape time only,
        so registering one adds zero cost to the instrumented hot path."""
        self._fn = fn

    @property
    def value(self) -> float:
        if self._fn is not None:
            return float(self._fn())
        with self._lock:
            return self._value


class _HistChild:
    """One labelset cell of a histogram family: cumulative fixed-bucket
    counts + sum + count (Prometheus semantics, bounded memory)."""

    __slots__ = ("_lock", "buckets", "counts", "sum", "count")

    def __init__(self, lock: threading.Lock, buckets: Tuple[float, ...]):
        self._lock = lock
        self.buckets = buckets
        # cumulative state (last bucket = +Inf)
        self.counts = [0] * (len(buckets) + 1)  # guarded_by: self._lock
        self.sum = 0.0                     # guarded_by: self._lock
        self.count = 0                     # guarded_by: self._lock

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        for i, ub in enumerate(self.buckets):     # noqa: B007
            if v <= ub:
                break
        else:
            i = len(self.buckets)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1

    def cumulative(self) -> List[int]:
        return self.snapshot()[0]

    def snapshot(self) -> Tuple[List[int], float, int]:
        """(cumulative bucket counts, sum, count) read atomically — the
        renderer must not see a count from one observation and a sum
        from the next."""
        with self._lock:
            out, acc = [], 0
            for c in self.counts:
                acc += c
                out.append(acc)
            return out, self.sum, self.count


class MetricFamily:
    """A named metric plus its labeled children. With no label names the
    family itself is the single child (``family.inc(...)`` etc. work
    directly); with label names, address cells via ``labels(...)``."""

    def __init__(self, name: str, help_: str, mtype: str,
                 labelnames: Tuple[str, ...] = (),
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.name = name
        self.help = help_
        self.type = mtype
        self.labelnames = labelnames
        self.buckets = tuple(buckets)
        self._lock = threading.Lock()
        # NOTE: children share this lock — never read a child's value
        # while holding it (collect under lock, read outside)
        self._children: Dict[Tuple[str, ...], object] = {}  # guarded_by: self._lock
        if not labelnames:
            self._default = self._make_child()
            self._children[()] = self._default
        else:
            self._default = None

    def _make_child(self):
        if self.type == "histogram":
            return _HistChild(self._lock, self.buckets)
        return _Child(self._lock)

    def labels(self, **kv) -> object:
        if set(kv) != set(self.labelnames):
            raise ValueError(f"{self.name}: expected labels "
                             f"{self.labelnames}, got {tuple(kv)}")
        key = tuple(str(kv[n]) for n in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._make_child()
                self._children[key] = child
            return child

    # -- unlabeled convenience --------------------------------------------
    def _only(self):
        if self._default is None:
            raise ValueError(f"{self.name} is labeled "
                             f"{self.labelnames}: use .labels(...)")
        return self._default

    def inc(self, v: float = 1.0) -> None:
        self._only().inc(v)

    def set(self, v: float) -> None:
        self._only().set(v)

    def set_fn(self, fn: Callable[[], float]) -> None:
        self._only().set_fn(fn)

    def observe(self, v: float) -> None:
        self._only().observe(v)

    @property
    def value(self) -> float:
        return self._only().value

    # -- rendering ---------------------------------------------------------
    def render(self) -> str:
        lines = []
        if self.help:
            lines.append(f"# HELP {self.name} {self.help}")
        lines.append(f"# TYPE {self.name} {self.type}")
        with self._lock:
            children = sorted(self._children.items())
        for key, child in children:   # child reads re-take the lock
            if self.type == "histogram":
                cum, total, count = child.snapshot()
                for ub, c in zip((*self.buckets, float("inf")), cum):
                    lbl = _fmt_labels((*self.labelnames, "le"),
                                      (*key, _fmt(ub)))
                    lines.append(f"{self.name}_bucket{lbl} {c}")
                base = _fmt_labels(self.labelnames, key)
                lines.append(f"{self.name}_sum{base} {_fmt(total)}")
                lines.append(f"{self.name}_count{base} {count}")
            else:
                lbl = _fmt_labels(self.labelnames, key)
                lines.append(f"{self.name}{lbl} {_fmt(child.value)}")
        return "\n".join(lines)


class MetricsRegistry:
    """Get-or-create registry of metric families + Prometheus renderer.

    Re-requesting an existing name returns the same family; asking for it
    with a different type or label names raises (catches silent metric
    collisions between subsystems)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: Dict[str, MetricFamily] = {}  # guarded_by: self._lock

    def _get(self, name: str, help_: str, mtype: str,
             labels: Iterable[str], buckets=DEFAULT_BUCKETS) -> MetricFamily:
        labels = tuple(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.type != mtype or fam.labelnames != labels:
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.type}{fam.labelnames}, requested "
                        f"{mtype}{labels}")
                return fam
            fam = MetricFamily(name, help_, mtype, labels, buckets)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help_: str = "",
                labels: Iterable[str] = ()) -> MetricFamily:
        return self._get(name, help_, "counter", labels)

    def gauge(self, name: str, help_: str = "",
              labels: Iterable[str] = (),
              fn: Optional[Callable[[], float]] = None) -> MetricFamily:
        fam = self._get(name, help_, "gauge", labels)
        if fn is not None:
            fam.set_fn(fn)
        return fam

    def histogram(self, name: str, help_: str = "",
                  labels: Iterable[str] = (),
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS
                  ) -> MetricFamily:
        return self._get(name, help_, "histogram", labels, buckets)

    def get(self, name: str) -> Optional[MetricFamily]:
        with self._lock:
            return self._families.get(name)

    def snapshot(self) -> Dict[str, Dict[Tuple[str, ...], float]]:
        """Point-in-time ``{family: {label-values: value}}`` view of
        every counter and gauge (histograms are excluded — ``render()``
        reports their buckets). The no-parse alternative to scraping
        the text exposition: tests and overload-control assertions read
        e.g. ``snapshot()["serving_requests_total"][("rejected",)]``
        instead of regexing the Prometheus dump."""
        with self._lock:
            fams = list(self._families.values())
        out: Dict[str, Dict[Tuple[str, ...], float]] = {}
        for fam in fams:
            if fam.type == "histogram":
                continue
            with fam._lock:
                children = sorted(fam._children.items())
            # .value re-takes the (non-reentrant) family lock
            out[fam.name] = {key: child.value for key, child in children}
        return out

    def render(self) -> str:
        """Prometheus text exposition format (v0.0.4), families sorted by
        name, trailing newline included (scrapers require it)."""
        with self._lock:
            fams = [self._families[n] for n in sorted(self._families)]
        parts = [f.render() for f in fams]
        return "\n".join(parts) + ("\n" if parts else "")

    def write(self, path: str) -> None:
        """Dump the current scrape to a file (the no-HTTP export path)."""
        import os
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            f.write(self.render())
