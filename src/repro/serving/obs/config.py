"""Observability configuration + runtime facade for the serving stack.

``ObsConfig`` is the declarative knob set (CLI flags map 1:1 onto it);
``Observability`` owns the live objects — one `MetricsRegistry` (always,
bounded memory), plus the opt-in `Tracer`, `DeviceTimer`,
`ProfilerWindow`, and `MetricsServer`.

Everything beyond the registry is **off by default**: with a default
config the engine's instrumented paths see ``tracer is None``, a
disabled device timer, and no profiler — a branch test per site, no
retained spans, no forced device syncs. The parity contract (pinned by
tests/test_serving_obs.py) is that greedy outputs are bit-exact with
observability fully on vs fully off: instrumentation only ever *reads*
device state the engine already transfers (or blocks on it), never
changes what is computed.

Ownership: ``ContinuousCascadeEngine.run(..., obs=...)`` accepts either
an `ObsConfig` (the engine builds the runtime, runs, and calls
``finish()`` — the one-shot CLI/bench path) or a prebuilt
`Observability` (the caller keeps ownership and finishes it, e.g.
`serve.py` holding the /metrics endpoint open across the run).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serving.obs.device_time import DeviceTimer, ProfilerWindow
from repro.serving.obs.metrics import MetricsRegistry
from repro.serving.obs.trace import Tracer


@dataclasses.dataclass
class ObsConfig:
    """Declarative observability switches (all off/None by default)."""
    trace_path: Optional[str] = None     # Chrome-trace JSON out (Perfetto)
    metrics_path: Optional[str] = None   # Prometheus text dump at finish
    metrics_port: Optional[int] = None   # /metrics endpoint port (0 = any)
    device_timing: bool = False          # host/device split per dispatch
    profile_dir: Optional[str] = None    # jax.profiler capture directory
    profile_iters: int = 20              # engine iterations to capture
    audit_flush_every: int = 256         # JSONL flush cadence (events)
    max_events: Optional[int] = None     # telemetry retention (None = all,
                                         # 0 = none, N = ring of last N)

    @property
    def any_enabled(self) -> bool:
        return bool(self.trace_path or self.metrics_path
                    or self.metrics_port is not None or self.device_timing
                    or self.profile_dir)


def add_obs_args(ap) -> None:
    """Attach the shared observability CLI flags (serve.py and
    bench_serving.py expose the same set; they map 1:1 onto
    `ObsConfig` via :func:`obs_config_from_args`)."""
    g = ap.add_argument_group("observability")
    g.add_argument("--trace-out", default=None,
                   help="write a Chrome-trace-event JSON of the run "
                        "(load in https://ui.perfetto.dev)")
    g.add_argument("--metrics-out", default=None,
                   help="dump the final Prometheus text scrape to this "
                        "file")
    g.add_argument("--metrics-port", type=int, default=None,
                   help="serve a Prometheus /metrics endpoint on this "
                        "port during the run (0 = any free port)")
    g.add_argument("--device-timing", action="store_true",
                   help="bracket each dispatch with block_until_ready to "
                        "split host vs device wall time per phase "
                        "(serializes dispatch; outputs unchanged)")
    g.add_argument("--profile-dir", default=None,
                   help="capture a jax.profiler trace of the first "
                        "--profile-iters engine iterations here")
    g.add_argument("--profile-iters", type=int, default=20,
                   help="engine iterations inside the profiler window")
    g.add_argument("--audit-flush-every", type=int, default=256,
                   help="flush the JSONL audit log every N events")
    g.add_argument("--max-events", type=int, default=None,
                   help="in-memory telemetry event retention: unset = "
                        "keep all, 0 = keep none, N = ring of last N "
                        "(the audit log streams every event regardless)")


def obs_config_from_args(args) -> ObsConfig:
    """Build an `ObsConfig` from a parsed `add_obs_args` namespace."""
    return ObsConfig(trace_path=args.trace_out,
                     metrics_path=args.metrics_out,
                     metrics_port=args.metrics_port,
                     device_timing=args.device_timing,
                     profile_dir=args.profile_dir,
                     profile_iters=args.profile_iters,
                     audit_flush_every=args.audit_flush_every,
                     max_events=args.max_events)


class Observability:
    """Live observability objects for one (or more) engine runs."""

    def __init__(self, cfg: Optional[ObsConfig] = None,
                 registry: Optional[MetricsRegistry] = None):
        self.cfg = cfg or ObsConfig()
        self.registry = registry or MetricsRegistry()
        self.tracer: Optional[Tracer] = (Tracer() if self.cfg.trace_path
                                         else None)
        self.device_timer = DeviceTimer(self.cfg.device_timing)
        self.profiler = ProfilerWindow(self.cfg.profile_dir,
                                       self.cfg.profile_iters)
        self.server = None

    def start_server(self):
        """Bind + start the /metrics endpoint when configured. Returns
        the `MetricsServer` (or None); safe to call once."""
        if self.cfg.metrics_port is not None and self.server is None:
            from repro.serving.obs.httpd import MetricsServer
            self.server = MetricsServer(self.registry,
                                        port=self.cfg.metrics_port).start()
        return self.server

    def finish(self) -> None:
        """Export the trace / metrics dump, stop the profiler and the
        endpoint. Idempotent; exporters only run when configured."""
        if self.tracer is not None and self.cfg.trace_path:
            self.tracer.export(self.cfg.trace_path)
        if self.cfg.metrics_path:
            self.registry.write(self.cfg.metrics_path)
        self.profiler.close()
        if self.server is not None:
            self.server.close()
            self.server = None
