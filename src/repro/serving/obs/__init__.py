"""Serving observability: span tracing, metrics registry + Prometheus
export, and device-time attribution.

Three pillars (see docs/observability.md):

``trace``        Per-request lifecycle spans (queued -> prefill ->
                 decode -> ml_wait -> done, with the per-token eq.-8
                 confidence record on decode spans) and per-engine-
                 iteration phase spans, exported as Chrome trace-event
                 JSON loadable in Perfetto (``--trace-out``).
``metrics``      Counters / gauges / fixed-bucket histograms with zero
                 unbounded memory, rendered in Prometheus text format —
                 file dump (``--metrics-out``) or live ``/metrics``
                 endpoint (``httpd.MetricsServer``, ``--metrics-port``).
``device_time``  Opt-in host/device wall-time split per dispatch
                 (``--device-timing``) and a bounded ``jax.profiler``
                 capture window (``--profile-dir``).

``config.ObsConfig`` / ``config.Observability`` tie them together;
``ContinuousCascadeEngine.run(..., obs=...)`` accepts either. Everything
is off by default and the instrumented engine stays bit-exact and within
a few percent tokens/s of an uninstrumented run (gated in CI).
"""
from repro.serving.obs.config import (Observability, ObsConfig,
                                      add_obs_args, obs_config_from_args)
from repro.serving.obs.device_time import DeviceTimer, ProfilerWindow
from repro.serving.obs.httpd import MetricsServer
from repro.serving.obs.metrics import (DEFAULT_BUCKETS, MetricFamily,
                                       MetricsRegistry)
from repro.serving.obs.trace import (PID_ENGINE, PID_REQUESTS, Tracer,
                                     emit_request_spans,
                                     validate_chrome_trace)

__all__ = [
    "DEFAULT_BUCKETS", "DeviceTimer", "MetricFamily", "MetricsRegistry",
    "MetricsServer", "ObsConfig", "Observability", "PID_ENGINE",
    "PID_REQUESTS", "ProfilerWindow", "Tracer", "add_obs_args",
    "emit_request_spans", "obs_config_from_args", "validate_chrome_trace",
]
