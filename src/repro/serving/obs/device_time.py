"""Device-time attribution: host/device wall-time split + profiler window.

JAX dispatch is asynchronous: the host returns from a jitted call as
soon as the work is enqueued, so host-side section timers conflate
"time spent driving the engine" with "time the accelerator was busy".
Two opt-in tools recover the split:

``DeviceTimer``
    When enabled, the engine brackets each dispatch with
    ``jax.block_until_ready`` on the dispatch result: the time up to
    the dispatch return is **host** (python + tracing + enqueue), the
    blocking remainder is **device** (XLA execution + transfer).
    Blocking serializes dispatch against execution, which can cost
    real overlap — that is why this is a *mode* (``--device-timing``)
    and not the default; outputs are bit-identical either way.

``ProfilerWindow``
    Captures a ``jax.profiler`` trace (XPlane, loadable in
    TensorBoard/Perfetto) for the first `n_iters` engine iterations of
    a run into ``profile_dir``. A bounded window rather than
    whole-run capture: profiler traces grow quickly and one window is
    what kernel-level analysis needs.
"""
from __future__ import annotations

import time
from typing import Any, Optional

import jax


class DeviceTimer:
    """Host/device split bracketing for jitted dispatches.

    Usage (engine hot path)::

        t0 = perf_counter()
        out = jitted_fn(...)
        host_s, device_s = timer.split(t0, out)

    Disabled (the default), ``split`` never blocks and reports the whole
    section as host time with device time 0 — callers record the pair
    unconditionally and the summary only advertises the split when the
    mode was on."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled

    def split(self, t_start: float, result: Any) -> tuple:
        """Returns (host_seconds, device_seconds) for a dispatch started
        at `t_start` (perf_counter) whose output tree is `result`."""
        t_disp = time.perf_counter()
        if not self.enabled:
            return t_disp - t_start, 0.0
        jax.block_until_ready(result)
        return t_disp - t_start, time.perf_counter() - t_disp


class ProfilerWindow:
    """Capture a jax.profiler trace for the first `n_iters` calls to
    ``tick()`` (one per engine iteration). Idempotent and crash-safe:
    ``close()`` stops a still-open capture."""

    def __init__(self, profile_dir: Optional[str], n_iters: int = 20):
        self.profile_dir = profile_dir
        self.n_iters = max(1, n_iters)
        self._i = 0
        self._running = False

    @property
    def enabled(self) -> bool:
        return self.profile_dir is not None

    def tick(self) -> None:
        if not self.enabled or self._i > self.n_iters:
            return
        if self._i == 0:
            jax.profiler.start_trace(self.profile_dir)
            self._running = True
        elif self._i == self.n_iters and self._running:
            jax.profiler.stop_trace()
            self._running = False
        self._i += 1

    def close(self) -> None:
        if self._running:
            jax.profiler.stop_trace()
            self._running = False
