"""Span tracing with Chrome trace-event JSON export (Perfetto-loadable).

The tracer records **complete** spans (``ph: "X"``) and **instant**
events (``ph: "i"``) on (pid, tid) tracks, with timestamps in seconds on
the telemetry run-relative clock (converted to microseconds at export).
Two process tracks are used by the serving engine:

  * ``pid=PID_ENGINE`` — the engine control loop. ``tid 0`` carries one
    span per engine iteration with nested phase spans (schedule /
    prefill / decode / ml_poll) and a final ``drain`` span.
  * ``pid=PID_REQUESTS`` — one tid per request (tid == rid) carrying the
    request's lifecycle spans: ``queued -> prefill -> decode ->
    ml_wait`` and a ``done`` instant. Decode spans carry the per-token
    confidence record (eq.-8 running negative entropy deltas) in
    ``args["conf"]``.

Export (:meth:`Tracer.export`) writes the standard JSON object format
``{"traceEvents": [...], "displayTimeUnit": "ms"}`` that
https://ui.perfetto.dev and ``chrome://tracing`` load directly.

:func:`validate_chrome_trace` is the schema/nesting checker the golden
test (and anything else consuming these traces) uses: required keys per
event, non-negative microsecond timestamps, and proper span nesting per
(pid, tid) track.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

PID_ENGINE = 1
PID_REQUESTS = 2


class Tracer:
    """Append-only span recorder. Timestamps are *seconds* on the
    caller's run-relative clock (`ServingTelemetry.now`); the Chrome
    format's microseconds appear only at export.

    Tracing retains one dict per span, so it is opt-in (``--trace-out``);
    the always-on path is the bounded `MetricsRegistry`."""

    def __init__(self):
        self.events: List[Dict[str, Any]] = []
        self._names: Dict[tuple, str] = {}

    # -- emission ----------------------------------------------------------
    def complete(self, name: str, cat: str, ts_s: float, dur_s: float,
                 tid: int, pid: int = PID_ENGINE,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """One finished span: [ts_s, ts_s + dur_s) on track (pid, tid)."""
        ev = {"name": name, "cat": cat, "ph": "X",
              "ts": round(ts_s * 1e6, 3),
              "dur": round(max(dur_s, 0.0) * 1e6, 3),
              "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, cat: str, ts_s: float, tid: int,
                pid: int = PID_ENGINE,
                args: Optional[Dict[str, Any]] = None) -> None:
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "ts": round(ts_s * 1e6, 3), "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def name_process(self, pid: int, name: str) -> None:
        self._names[(pid, None)] = name

    def name_thread(self, pid: int, tid: int, name: str) -> None:
        self._names[(pid, tid)] = name

    # -- export ------------------------------------------------------------
    def export_obj(self) -> Dict[str, Any]:
        """The Chrome trace JSON object (metadata events + recorded
        events, stably sorted by timestamp with wider spans first so
        nesting renders correctly)."""
        meta = []
        for (pid, tid), name in sorted(self._names.items(),
                                       key=lambda kv: (kv[0][0],
                                                       kv[0][1] or 0)):
            if tid is None:
                meta.append({"name": "process_name", "ph": "M", "pid": pid,
                             "args": {"name": name}})
            else:
                meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                             "tid": tid, "args": {"name": name}})
        events = sorted(self.events,
                        key=lambda e: (e["pid"], e["tid"], e["ts"],
                                       -e.get("dur", 0.0)))
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.export_obj(), f)


def emit_request_spans(tracer: Tracer, requests) -> None:
    """Emit the lifecycle spans of finished `requests` (one tid per rid
    on the PID_REQUESTS track) from their recorded timestamps:
    ``queued -> prefill -> decode -> ml_wait`` + a ``done`` instant.
    Called once at end of run — per-request cost is paid only when
    tracing is on, and span edges equal the audit-log timestamps by
    construction (same clock, same fields)."""
    import math

    def fin(x):
        return x is not None and not math.isnan(x)

    tracer.name_process(PID_ENGINE, "engine")
    tracer.name_thread(PID_ENGINE, 0, "iterations")
    tracer.name_process(PID_REQUESTS, "requests")
    for r in requests:
        tid = r.rid
        tracer.name_thread(PID_REQUESTS, tid, f"req {r.rid}")
        if not fin(r.t_admit):
            continue
        tracer.complete("queued", "request", r.arrival_time,
                        r.t_admit - r.arrival_time, tid, PID_REQUESTS,
                        args={"rid": r.rid})
        pf_end = r.t_prefill_done if fin(r.t_prefill_done) else r.t_admit
        tracer.complete("prefill", "request", r.t_admit,
                        pf_end - r.t_admit, tid, PID_REQUESTS,
                        args={"prompt_len": r.prompt_len,
                              "shared_prefix_tokens":
                                  r.shared_prefix_tokens})
        if fin(r.t_retire):
            args: Dict[str, Any] = {"n_tokens": int(r.n_small_steps),
                                    "confidence": round(r.confidence, 6),
                                    "deferred": bool(r.deferred),
                                    "early_exited": bool(r.early_exited)}
            if r.conf_trace is not None:
                args["conf"] = r.conf_trace
            tracer.complete("decode", "request", pf_end,
                            r.t_retire - pf_end, tid, PID_REQUESTS,
                            args=args)
        if r.deferred and fin(r.t_submit_large) and fin(r.t_done):
            tracer.complete("ml_wait", "request", r.t_submit_large,
                            r.t_done - r.t_submit_large, tid, PID_REQUESTS)
        if fin(r.t_done):
            tracer.instant("done", "request", r.t_done, tid, PID_REQUESTS)


def validate_chrome_trace(obj: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Assert `obj` is schema-valid Chrome trace-event JSON and that
    spans nest properly per (pid, tid) track. Returns the "X" spans
    (ts-sorted) for further inspection. Raises AssertionError with a
    specific message on the first violation."""
    assert isinstance(obj, dict), "trace must be a JSON object"
    assert "traceEvents" in obj, "missing traceEvents"
    events = obj["traceEvents"]
    assert isinstance(events, list) and events, "traceEvents empty"
    spans = []
    for ev in events:
        assert isinstance(ev, dict), f"event not an object: {ev!r}"
        assert "ph" in ev and "pid" in ev and "name" in ev, \
            f"event missing required keys: {ev!r}"
        ph = ev["ph"]
        if ph == "M":
            continue
        assert "ts" in ev and "tid" in ev, f"event missing ts/tid: {ev!r}"
        assert isinstance(ev["ts"], (int, float)) and ev["ts"] >= 0, \
            f"bad ts: {ev!r}"
        if ph == "X":
            assert isinstance(ev.get("dur"), (int, float)) \
                and ev["dur"] >= 0, f"X event needs dur >= 0: {ev!r}"
            spans.append(ev)
        else:
            assert ph in ("i", "I", "B", "E", "C"), f"unknown ph: {ev!r}"
    # nesting: within one track, sorted by (ts, -dur), every span must
    # either start at/after the enclosing span's end (sibling) or end
    # within it (child) — partial overlap is a malformed trace
    by_track: Dict[tuple, List[Dict[str, Any]]] = {}
    for s in spans:
        by_track.setdefault((s["pid"], s["tid"]), []).append(s)
    eps = 0.5  # µs slack for the export rounding
    for track, tr_spans in by_track.items():
        tr_spans.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack: List[Dict[str, Any]] = []
        for s in tr_spans:
            while stack and s["ts"] >= stack[-1]["ts"] + stack[-1]["dur"] \
                    - eps:
                stack.pop()
            if stack:
                parent_end = stack[-1]["ts"] + stack[-1]["dur"]
                assert s["ts"] + s["dur"] <= parent_end + eps, (
                    f"span {s['name']!r} [{s['ts']}, "
                    f"{s['ts'] + s['dur']}] overlaps parent "
                    f"{stack[-1]['name']!r} ending {parent_end} on track "
                    f"{track}")
            stack.append(s)
    return sorted(spans, key=lambda e: e["ts"])
