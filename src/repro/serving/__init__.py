"""Cascade serving subsystem (paper Fig. 1: M_S local, M_L remote, gate g).

Architecture
------------

::

    arrivals ──> request.ArrivalQueue ──> scheduler.SlotScheduler
                                              │ admit (strict FIFO; paged
                                              │ backend also gates on
                                              │ block reservation)
                                              ▼
            cache_pool.SlotCachePool   [slot 0 | slot 1 | ... ]   (dense)
         or paged_pool.PagedCachePool  [blk 7|blk 2|...] + page table
                                              │ paged admission prefills
                                              │ in fixed chunks
                                              │ interleaved with decode
                                              │ jitted batched step:
                                              │ decode all slots at
                                              │ per-slot positions,
                                              │ eq.-8 confidence summed
                                              │ on device
                                              ▼
                  engine.ContinuousCascadeEngine
                      │ retire: finished … keep M_S output
                      │         in-flight deferral (running mean conf
                      │         < tau - margin after min_tokens): evict,
                      │         saving the remaining M_S steps
                      ▼
              large_backend.{Sync,Threaded,RemoteStub}Backend
                      │ deferrals stream in at retirement; batched
                      │ M_L regeneration (sync: inline on the decode
                      │ loop; thread/stub: worker thread overlapped
                      │ with M_S decode, max-wait bounded batching)
                      ▼
                  telemetry.ServingTelemetry
                      (tokens/s, latency pXX, deferral ratio + wait,
                       M_L queue depth / batch occupancy, savings,
                       cache footprint, JSONL audit log)

`engine.CascadeEngine` is the static lock-step reference path; with
`early_exit=False` the continuous engine reproduces it token-for-token
under greedy decoding (both backends).

Modules
-------
request     Request lifecycle (PENDING/RUNNING/DEFERRED/DONE, plus
            PREEMPTED and the REJECTED/EXPIRED overload terminals) +
            arrival queue with delayed visibility, optional bound
            (`max_queue`), deadlines, and age-priority requeue +
            Poisson arrival helper. Requests carry their own prompt
            lengths (ragged admission).
cache_pool  Dense slot-based KV/state cache pool, preallocated once and
            reused across request generations; batch axes discovered
            from the abstract cache.
paged_pool  Block-paged KV cache: fixed-size blocks + per-slot page
            tables, on-demand mapping, reservation-based admission;
            optional oversubscription (virtual admission budget,
            `BlockPressure` on physical exhaustion) and a host-RAM swap
            tier for cold registered prefix blocks.
pressure    Memory-pressure policies for oversubscribed paged runs:
            preempt-and-requeue (bit-exact resume), defer-on-OOM up the
            cascade ladder, shed; deterministic youngest-victim
            selection.
scheduler   FIFO admission into free slots (optionally capacity-gated),
            retirement, invariants.
large_backend  Pluggable M_L regeneration backends (submit/poll/drain):
            sync (inline), thread (worker-thread overlap), stub
            (serialized RPC shape with injectable latency); shared
            batch-shape policy (large_batch x max_wait).
remote      Distributed M_L tier: MLServer (socket RPC server process,
            entrypoint repro.launch.ml_server), SocketBackend (the
            LargeBackend protocol over the wire: timeouts, bounded
            retry, cancellation), ReplicaPool (N replicas with health
            checks, ejection, in-flight re-dispatch), wire (versioned
            length-prefixed JSON framing).
engine      ModelRunner (on-device greedy loop), static CascadeEngine,
            ContinuousCascadeEngine (continuous batching + in-flight
            deferral over either backend, chunked prefill, streaming
            M_L deferral).
telemetry   Event stream, JSONL audit log, throughput/latency summary +
            phase-time breakdown, built on the obs metrics registry.
obs         Observability layer: span tracing with Chrome-trace export
            (Perfetto), bounded Prometheus metrics registry + /metrics
            endpoint, host/device time attribution, jax.profiler window.
"""
from repro.core.cascade_spec import (CascadeSpec, CascadeTier,
                                     DeferralEdge)
from repro.core.recalibration import RecalibConfig
from repro.serving.cache_pool import SlotCachePool
from repro.serving.config import (EngineConfig, MLBackendConfig,
                                  PagedConfig, PressureConfig)
from repro.serving.engine import (CascadeEngine, ContinuousCascadeEngine,
                                  ContinuousServeResult, ModelRunner,
                                  ServeResult)
from repro.serving.large_backend import (BatchPolicy, LargeBackend,
                                         LargeResult, RemoteStubBackend,
                                         SyncLocalBackend, ThreadedBackend,
                                         make_large_backend)
from repro.serving.obs import (MetricsRegistry, Observability, ObsConfig,
                               Tracer, validate_chrome_trace)
from repro.serving.paged_pool import BlockPressure, PagedCachePool
from repro.serving.pressure import (DeferOnOomPolicy, PreemptPolicy,
                                    PressurePolicy, ShedPolicy,
                                    make_pressure_policy)
from repro.serving.remote import (MLServer, ReplicaPool, SocketBackend)
from repro.serving.request import (ArrivalQueue, Request, make_requests,
                                   poisson_arrivals)
from repro.serving.scheduler import SlotScheduler
from repro.serving.telemetry import ServingTelemetry

__all__ = [
    "ArrivalQueue", "BatchPolicy", "BlockPressure", "CascadeEngine",
    "CascadeSpec", "CascadeTier", "ContinuousCascadeEngine",
    "ContinuousServeResult", "DeferOnOomPolicy", "DeferralEdge",
    "EngineConfig", "LargeBackend", "LargeResult", "MLBackendConfig",
    "MLServer", "MetricsRegistry", "ModelRunner", "ObsConfig",
    "Observability", "PagedCachePool", "PagedConfig", "PreemptPolicy",
    "PressureConfig", "PressurePolicy", "RecalibConfig",
    "RemoteStubBackend", "ReplicaPool", "Request", "ServeResult",
    "ServingTelemetry", "ShedPolicy", "SlotCachePool", "SlotScheduler",
    "SocketBackend", "SyncLocalBackend", "ThreadedBackend", "Tracer",
    "make_large_backend", "make_pressure_policy", "make_requests",
    "poisson_arrivals", "validate_chrome_trace",
]
