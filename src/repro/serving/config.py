"""Typed engine configuration for `ContinuousCascadeEngine`.

The engine's constructor grew ~20 flat kwargs across PRs 1-7 (slots,
paged-cache knobs, M_L batching, kernel switches, ...). This module is
the replacement surface:

    engine = ContinuousCascadeEngine(spec, EngineConfig(
        n_slots=8,
        backend="paged",
        paged=PagedConfig(block_size=8, prefill_chunk=8),
        ml=MLBackendConfig(kind="thread", large_batch=4)))

`spec` is a `core.cascade_spec.CascadeSpec` (model ladder + per-edge
gates); `EngineConfig` holds everything about HOW the engine executes
it. The old flat-kwargs constructor still works through a back-compat
shim that maps every legacy name onto these fields (`LEGACY_KWARG_MAP`
below is the single source of truth for the docs migration table) and
emits one `DeprecationWarning` with the migration hint.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.core.recalibration import RecalibConfig


@dataclasses.dataclass
class PressureConfig:
    """Memory-oversubscription policy for the paged backend.

    oversubscribe   — admit up to `round(n_blocks * oversubscribe)`
                      virtual blocks of reservations (1.0: classic
                      reservation invariant, physical exhaustion is
                      impossible).
    policy          — what to do when a mapped block is needed but the
                      physical pool is empty:
                      "preempt" — evict the youngest running slot, save
                        its decode state, requeue it age-first (bit-exact
                        resume via the prefix registry);
                      "defer"   — defer the youngest victim up the
                        cascade ladder (`deferred_reason="oom"`);
                      "shed"    — drop the youngest victim (REJECTED).
    max_preemptions — preemption bound per request; a victim past the
                      bound escalates to defer-on-OOM so it cannot
                      thrash forever ("preempt" policy only).
    swap_blocks     — host-RAM swap-tier capacity in blocks: cold cached
                      prefix blocks spill here on eviction instead of
                      being dropped, and swap back in on a registry hit
                      (0: no swap tier).
    """
    oversubscribe: float = 1.0
    policy: str = "preempt"
    max_preemptions: int = 2
    swap_blocks: int = 0

    def __post_init__(self):
        if self.oversubscribe < 1.0:
            raise ValueError(f"oversubscribe must be >= 1.0, "
                             f"got {self.oversubscribe}")
        if self.policy not in ("preempt", "defer", "shed"):
            raise ValueError(f"policy must be 'preempt', 'defer' or "
                             f"'shed', got {self.policy!r}")
        if self.max_preemptions < 0:
            raise ValueError("max_preemptions must be >= 0")
        if self.swap_blocks < 0:
            raise ValueError("swap_blocks must be >= 0")


@dataclasses.dataclass
class PagedConfig:
    """Block-paged KV-cache backend knobs (`backend="paged"`).

    block_size     — tokens per cache block.
    n_blocks       — physical block budget (None: worst case, always
                     fits).
    prefill_chunk  — prefill chunk tokens (None: whole prompt in one
                     chunk).
    paged_kernel   — True: Pallas paged flash-decode kernels; False: XLA
                     gather fallback; None: REPRO_PAGED_KERNEL / platform
                     default (TPU on, CPU off).
    batch_prefill  — pack same-offset prefill chunks of all mid-prefill
                     requests into one dispatch.
    prefix_sharing — copy-on-write prompt-prefix sharing through the
                     pool's prefix registry.
    pressure       — `PressureConfig` enabling oversubscription /
                     swap-tier behavior (None: reservation-only, the
                     parity-pinned default).
    """
    block_size: int = 16
    n_blocks: Optional[int] = None
    prefill_chunk: Optional[int] = None
    paged_kernel: Optional[bool] = None
    batch_prefill: bool = True
    prefix_sharing: bool = True
    pressure: Optional[PressureConfig] = None


@dataclasses.dataclass
class MLBackendConfig:
    """Default execution backend for tiers >= 1 (a tier's own
    `CascadeTier.backend` overrides `kind` per tier).

    kind         — "sync" | "thread" | "stub", or a callable factory
                   (the socket / replica-pool path).
    large_batch  — regeneration batch size per prompt-length group
                   (None: one exact-size batch at drain).
    max_wait     — seconds a partial batch may wait before flushing
                   padded (None: wait for a full batch).
    stub_latency — injected per-batch RPC latency (kind="stub").
    """
    kind: Any = "sync"
    large_batch: Optional[int] = None
    max_wait: Optional[float] = None
    stub_latency: float = 0.0


@dataclasses.dataclass
class EngineConfig:
    """How the continuous engine executes a `CascadeSpec`.

    n_slots        — tier-0 decode slots.
    early_exit     — in-flight deferral on edges whose signal supports a
                     running confidence.
    steps_per_sync — decode steps per host sync (multi-step scheduling).
    backend        — tier-0 KV-cache backend: "slot" | "paged".
    paged          — `PagedConfig` (used when backend="paged").
    ml             — `MLBackendConfig` defaults for tiers >= 1.
    recalibration  — `RecalibConfig` to recalibrate each edge's tau
                     online toward `recalib_target` (None: taus are
                     fixed — the parity-pinned default).
    recalib_target — target deferral ratio(s) the online controller
                     holds; a float for every edge or a per-edge list.
    max_queue      — admission overload control: bound on the READY
                     arrival queue; overflow is shed newest-first as
                     REJECTED (None: unbounded, the default).
    deadline_s     — per-request queueing deadline in seconds from
                     arrival; requests still queued past it are shed as
                     EXPIRED (None: no deadlines). Per-request deadlines
                     set on the `Request` itself take precedence.
    """
    n_slots: int = 8
    early_exit: bool = True
    steps_per_sync: int = 1
    backend: str = "slot"
    paged: PagedConfig = dataclasses.field(default_factory=PagedConfig)
    ml: MLBackendConfig = dataclasses.field(default_factory=MLBackendConfig)
    recalibration: Optional[RecalibConfig] = None
    recalib_target: Any = 0.2
    max_queue: Optional[int] = None
    deadline_s: Optional[float] = None

    def __post_init__(self):
        if self.backend not in ("slot", "paged"):
            raise ValueError(f"backend must be 'slot' or 'paged', "
                             f"got {self.backend!r}")
        if self.paged.pressure is not None and self.backend != "paged":
            raise ValueError("paged.pressure requires backend='paged'")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {self.deadline_s}")
        self.steps_per_sync = max(1, self.steps_per_sync)


# legacy constructor kwarg -> (object path, field) — the shim consumes
# this and docs/serving.md renders it as the migration table
LEGACY_KWARG_MAP = {
    "n_slots":        ("config", "n_slots"),
    "tau":            ("spec.edges[0]", "tau"),
    "margin":         ("spec.edges[0]", "margin"),
    "min_tokens":     ("spec.edges[0]", "min_tokens"),
    "early_exit":     ("config", "early_exit"),
    "large_batch":    ("config.ml", "large_batch"),
    "large_backend":  ("config.ml", "kind"),
    "large_max_wait": ("config.ml", "max_wait"),
    "stub_latency":   ("config.ml", "stub_latency"),
    "steps_per_sync": ("config", "steps_per_sync"),
    "backend":        ("config", "backend"),
    "block_size":     ("config.paged", "block_size"),
    "n_blocks":       ("config.paged", "n_blocks"),
    "prefill_chunk":  ("config.paged", "prefill_chunk"),
    "paged_kernel":   ("config.paged", "paged_kernel"),
    "batch_prefill":  ("config.paged", "batch_prefill"),
    "prefix_sharing": ("config.paged", "prefix_sharing"),
    "cost_small":     ("spec.tiers[0]", "cost"),
    "cost_large":     ("spec.tiers[1]", "cost"),
}

MIGRATION_HINT = (
    "ContinuousCascadeEngine(small, large, **kwargs) is deprecated: "
    "build a CascadeSpec + EngineConfig instead — "
    "ContinuousCascadeEngine(CascadeSpec.two_tier(small, large, "
    "tau=...), EngineConfig(n_slots=..., "
    "ml=MLBackendConfig(kind=...), paged=PagedConfig(...))). "
    "See docs/serving.md for the full old-kwarg -> config-field table.")
