"""Threshold calibration for the cascade gate (paper Stage 3).

Given validation-set confidences, pick tau to hit a target deferral ratio or
a target joint accuracy (the two practical deployment knobs).

`calibrate_edges` is the ONE calibration surface for every cascade shape
in the repo — the classifier `Cascade`, the static two-model
`CascadeEngine`, the continuous serving engine, and N-tier
`CascadeSpec` ladders all route through it (their own `calibrate`
methods are thin wrappers). Per-edge semantics: edge 0 calibrates on the
full validation set; edge i calibrates only on the prompts the
already-calibrated edges 0..i-1 would defer that far — the traffic that
actually reaches it. Every edge keeps the repo-wide sentinel semantics
of `threshold_for_deferral_ratio` (``deferred = conf < tau``; ratio<=0
-> below-min tau, never defer; ratio>=1 -> above-max tau, always
defer).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Union

import numpy as np


def threshold_for_deferral_ratio(confidence: np.ndarray, ratio: float) -> float:
    """tau s.t. the fraction of examples with g(x) < tau is ~`ratio`.

    ratio=0 -> never defer; ratio=1 -> always defer.
    """
    conf = np.asarray(confidence, np.float64).ravel()
    if ratio <= 0.0:
        return float(conf.min() - 1.0)
    if ratio >= 1.0:
        return float(conf.max() + 1.0)
    return float(np.quantile(conf, ratio))


def threshold_for_accuracy(confidence: np.ndarray,
                           small_correct: np.ndarray,
                           large_correct: np.ndarray,
                           target_accuracy: float) -> Optional[float]:
    """Smallest-deferral tau whose joint accuracy >= target on validation.

    Returns None when the target exceeds what full deferral achieves.
    """
    conf = np.asarray(confidence, np.float64).ravel()
    sc = np.asarray(small_correct, np.float64).ravel()
    lc = np.asarray(large_correct, np.float64).ravel()
    n = conf.size
    order = np.argsort(conf)                       # least confident first
    sc_s, lc_s = sc[order], lc[order]
    prefix_lc = np.concatenate([[0.0], np.cumsum(lc_s)])
    prefix_sc = np.concatenate([[0.0], np.cumsum(sc_s)])
    total_sc = prefix_sc[-1]
    joint = (prefix_lc + (total_sc - prefix_sc)) / n   # joint acc deferring k
    ok = np.nonzero(joint >= target_accuracy)[0]
    if ok.size == 0:
        return None
    k = int(ok[0])
    if k == 0:
        return float(conf.min() - 1.0)
    if k >= n:
        return float(conf.max() + 1.0)
    sorted_conf = conf[order]
    return float(0.5 * (sorted_conf[k - 1] + sorted_conf[k]))


def expected_compute_cost(deferral_ratio: float,
                          cost_small: float = 0.2,
                          cost_large: float = 1.0) -> float:
    """Compute budget of the cascade (paper Fig. 1): every request pays
    cost_small; deferred requests additionally pay cost_large."""
    return cost_small + deferral_ratio * cost_large


def ladder_compute_cost(reach_fractions: Sequence[float],
                        costs: Sequence[float]) -> float:
    """N-tier generalization of `expected_compute_cost`: tier i costs
    `costs[i]` and is paid by the `reach_fractions[i]` fraction of
    traffic that reaches it (tier 0 always has reach 1.0). The two-tier
    case reduces to cost_small + r * cost_large exactly."""
    if len(reach_fractions) != len(costs):
        raise ValueError(f"{len(reach_fractions)} reach fractions but "
                         f"{len(costs)} tier costs")
    return float(sum(r * c for r, c in zip(reach_fractions, costs)))


def _per_edge_ratios(n_edges: int,
                     deferral_ratio: Union[float, Sequence[float]]
                     ) -> List[float]:
    if hasattr(deferral_ratio, "__len__"):
        ratios = [float(r) for r in deferral_ratio]
        if len(ratios) != n_edges:
            raise ValueError(f"{n_edges} edges but {len(ratios)} "
                             f"deferral ratios")
        return ratios
    return [float(deferral_ratio)] * n_edges


def calibrate_edges(spec, val_prompts, *,
                    max_new: Optional[int] = None,
                    deferral_ratio: Union[float, Sequence[float]] = 0.2,
                    prompt_len: Optional[int] = None,
                    valid_mask=None) -> List[float]:
    """Calibrate every edge threshold of a cascade from one validation
    batch; sets the taus in place and returns them (edge order).

    `spec` is either a `core.cascade_spec.CascadeSpec` (token-model
    ladder: tier i's runner generates on the traffic reaching it, the
    edge's signal scores it, tau_i is the target quantile) or a
    `core.cascade.Cascade` (classifier: the configured logit signal on
    the small model, single edge). `deferral_ratio` is one target for
    every edge or a per-edge sequence. Tiers gated by an edge must carry
    a local runner — a remote-only tier cannot be calibrated offline
    (calibrate against its local twin, or rely on online
    recalibration)."""
    # classifier cascade: one edge, confidence from the logit signal
    if hasattr(spec, "small_apply"):
        ratios = _per_edge_ratios(1, deferral_ratio)
        logits = spec.small_apply(spec.small_params, val_prompts)
        conf = np.asarray(spec.confidence(logits, valid_mask))
        spec.tau = threshold_for_deferral_ratio(conf, ratios[0])
        return [spec.tau]

    from repro.core.deferral import SignalObservation

    ratios = _per_edge_ratios(len(spec.edges), deferral_ratio)
    if max_new is None:
        raise ValueError("calibrate_edges needs max_new for a "
                         "generation ladder")
    prompts = np.asarray(val_prompts, np.int32)
    if prompt_len is None:
        prompt_len = int(prompts.shape[1])
    reach = np.arange(prompts.shape[0])          # rows reaching edge i
    taus: List[float] = []
    for i, (edge, ratio) in enumerate(zip(spec.edges, ratios)):
        if reach.size == 0:
            # nothing reaches this edge under the upstream taus; keep
            # its configured tau — no data to re-derive one from
            taus.append(edge.tau)
            continue
        runner = spec.tiers[i].runner
        if runner is None:
            raise ValueError(
                f"cannot calibrate edge {i}: tier {i} "
                f"({spec.tiers[i].name!r}) has no local runner")
        sub = prompts[reach]
        tokens, mean_conf = runner.generate(sub, prompt_len, max_new)
        sig = edge.signal
        if sig.supports_running:
            conf = np.asarray(mean_conf, np.float64)
        else:
            conf = np.array([
                sig.finalize(SignalObservation(
                    prompt=sub[j], mean_confidence=float(mean_conf[j]),
                    tokens=tokens[j], runner=runner, max_new=max_new))
                for j in range(sub.shape[0])], np.float64)
        edge.tau = threshold_for_deferral_ratio(conf, ratio)
        taus.append(edge.tau)
        reach = reach[conf < edge.tau]
    return taus
