"""Threshold calibration for the cascade gate (paper Stage 3).

Given validation-set confidences, pick tau to hit a target deferral ratio or
a target joint accuracy (the two practical deployment knobs).
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def threshold_for_deferral_ratio(confidence: np.ndarray, ratio: float) -> float:
    """tau s.t. the fraction of examples with g(x) < tau is ~`ratio`.

    ratio=0 -> never defer; ratio=1 -> always defer.
    """
    conf = np.asarray(confidence, np.float64).ravel()
    if ratio <= 0.0:
        return float(conf.min() - 1.0)
    if ratio >= 1.0:
        return float(conf.max() + 1.0)
    return float(np.quantile(conf, ratio))


def threshold_for_accuracy(confidence: np.ndarray,
                           small_correct: np.ndarray,
                           large_correct: np.ndarray,
                           target_accuracy: float) -> Optional[float]:
    """Smallest-deferral tau whose joint accuracy >= target on validation.

    Returns None when the target exceeds what full deferral achieves.
    """
    conf = np.asarray(confidence, np.float64).ravel()
    sc = np.asarray(small_correct, np.float64).ravel()
    lc = np.asarray(large_correct, np.float64).ravel()
    n = conf.size
    order = np.argsort(conf)                       # least confident first
    sc_s, lc_s = sc[order], lc[order]
    prefix_lc = np.concatenate([[0.0], np.cumsum(lc_s)])
    prefix_sc = np.concatenate([[0.0], np.cumsum(sc_s)])
    total_sc = prefix_sc[-1]
    joint = (prefix_lc + (total_sc - prefix_sc)) / n   # joint acc deferring k
    ok = np.nonzero(joint >= target_accuracy)[0]
    if ok.size == 0:
        return None
    k = int(ok[0])
    if k == 0:
        return float(conf.min() - 1.0)
    if k >= n:
        return float(conf.max() + 1.0)
    sorted_conf = conf[order]
    return float(0.5 * (sorted_conf[k - 1] + sorted_conf[k]))


def expected_compute_cost(deferral_ratio: float,
                          cost_small: float = 0.2,
                          cost_large: float = 1.0) -> float:
    """Compute budget of the cascade (paper Fig. 1): every request pays
    cost_small; deferred requests additionally pay cost_large."""
    return cost_small + deferral_ratio * cost_large
