"""Core library: the paper's contribution (Gatekeeper) as composable pieces.

Public API:
  GatekeeperConfig, gatekeeper_loss, gatekeeper_token_loss, standard_ce_loss
  deferral signals (max_softmax, negative_entropy, sequence_negative_entropy)
  Cascade / CascadeResult
  metrics (distributional_overlap s_o, deferral_performance s_d, auroc, ...)
  calibration (threshold_for_deferral_ratio, threshold_for_accuracy)
  baselines (static_partition_loss, PromptingBaseline)
"""
from repro.core.gatekeeper import (            # noqa: F401
    GatekeeperConfig, gatekeeper_loss, gatekeeper_token_loss,
    standard_ce_loss, cross_entropy, kl_to_uniform, predictive_entropy,
    soft_cross_entropy)
from repro.core.deferral import (              # noqa: F401
    max_softmax, negative_entropy, sequence_negative_entropy,
    margin_confidence, defer_mask, selective_predict, SIGNALS,
    SERVING_SIGNALS, SignalObservation, MeanConfidenceSignal,
    SemanticAgreementSignal, pairwise_agreement, resolve_signal)
from repro.core.cascade import Cascade, CascadeResult  # noqa: F401
from repro.core.cascade_spec import (          # noqa: F401
    CascadeSpec, CascadeTier, DeferralEdge)
from repro.core.recalibration import (         # noqa: F401
    EdgeRecalibrator, RecalibConfig, TauController)
from repro.core.metrics import (               # noqa: F401
    distributional_overlap, deferral_performance, ideal_deferral_curve,
    random_deferral_curve, realized_deferral_curve, auroc,
    pearson_correlation, expected_calibration_error, summarize_deferral)
from repro.core.calibration import (           # noqa: F401
    threshold_for_deferral_ratio, threshold_for_accuracy,
    expected_compute_cost, ladder_compute_cost, calibrate_edges)
