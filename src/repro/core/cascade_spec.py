"""Cascade ladder specification: an ordered chain of model tiers joined
by per-edge deferral gates.

The paper's deployment (Fig. 1) is the two-tier special case: M_S local,
M_L remote, one confidence gate g with one calibrated tau. A production
cascade wants a *ladder* — e.g. the 1.8B -> 32B -> 405B shape the
configs/ directory already describes — where each adjacent pair of tiers
has its own deferral signal and threshold, and traffic deferred at edge
i becomes arrival traffic for edge i+1.

`CascadeSpec` is the declarative description the serving engine (and the
offline calibration surface `core.calibration.calibrate_edges`) consume:

    spec = CascadeSpec(
        tiers=[CascadeTier("1.8b", runner=small, cost=0.2),
               CascadeTier("32b",  runner=mid,   cost=0.5),
               CascadeTier("405b", runner=large, cost=1.0)],
        edges=[DeferralEdge(signal="mean_confidence", tau=-2.1),
               DeferralEdge(signal="mean_confidence", tau=-1.7)])

Tier 0 is the slot-resident model the continuous engine decodes in
place; every later tier executes behind a `LargeBackend` (local sync /
thread, or the distributed socket / replica-pool backends — `backend`
takes the same name-or-factory the engine's M_L plumbing always took).
Every edge keeps the repo-wide convention ``deferred = conf < tau``.

`CascadeSpec.two_tier(...)` reproduces today's (small, large, tau)
engine exactly — the parity invariant tests pin it bit-exact.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

from repro.core import deferral as deferral_lib


@dataclasses.dataclass
class CascadeTier:
    """One rung of the ladder.

    `runner` is the tier's local `ModelRunner` (required for tier 0 and
    for any tier that calibrates offline or uses a sampling signal);
    `backend` overrides how tiers >= 1 execute — a `LargeBackend` name
    ("sync" | "thread" | "stub") or a callable factory (the socket /
    replica-pool path), defaulting to the engine config's ml.kind.
    `cost` is the tier's relative compute cost (paper Fig. 1 units:
    M_L = 1.0)."""
    name: str
    runner: Any = None
    backend: Any = None
    cost: float = 1.0


@dataclasses.dataclass
class DeferralEdge:
    """The gate between tier i and tier i+1.

    `signal` is a serving-signal name or instance
    (`core.deferral.SERVING_SIGNALS`); `tau` the acceptance threshold
    (``deferred = conf < tau``); `margin`/`min_tokens` shape in-flight
    early exit on edges whose signal supports a running form (evict once
    the running confidence drops below ``tau - margin`` after
    `min_tokens` generated tokens) — they are only meaningful on edge 0,
    the slot-resident tier's gate."""
    signal: Any = "mean_confidence"
    tau: float = -1.0
    margin: float = 0.0
    min_tokens: int = 2

    def __post_init__(self):
        self.signal = deferral_lib.resolve_signal(self.signal)
        self.min_tokens = max(1, int(self.min_tokens))


@dataclasses.dataclass
class CascadeSpec:
    """Ordered ladder of tiers + the deferral edges joining them.

    Invariant: ``len(edges) == len(tiers) - 1``; tier 0 must carry a
    local runner (it lives in the engine's decode slots)."""
    tiers: List[CascadeTier]
    edges: List[DeferralEdge]

    def __post_init__(self):
        if len(self.tiers) < 2:
            raise ValueError(f"a cascade needs at least 2 tiers, "
                             f"got {len(self.tiers)}")
        if len(self.edges) != len(self.tiers) - 1:
            raise ValueError(
                f"a {len(self.tiers)}-tier ladder needs exactly "
                f"{len(self.tiers) - 1} deferral edges, "
                f"got {len(self.edges)}")
        if self.tiers[0].runner is None:
            raise ValueError("tier 0 needs a local ModelRunner: it is "
                             "the slot-resident model the engine decodes")
        for i, t in enumerate(self.tiers[1:], start=1):
            if t.runner is None and t.backend is None:
                raise ValueError(
                    f"tier {i} ({t.name!r}) needs a runner or a backend "
                    f"factory — it has neither")
        for i, e in enumerate(self.edges[1:], start=1):
            if (not e.signal.supports_running
                    and self.tiers[i].runner is None):
                raise ValueError(
                    f"edge {i} uses the {e.signal.name!r} signal, which "
                    f"needs tier {i}'s local runner to draw samples, but "
                    f"tier {i} only has a remote backend")

    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    @property
    def taus(self) -> List[float]:
        return [e.tau for e in self.edges]

    @property
    def costs(self) -> List[float]:
        return [t.cost for t in self.tiers]

    @classmethod
    def two_tier(cls, small, large, tau: float = -1.0,
                 margin: float = 0.0, min_tokens: int = 2,
                 cost_small: float = 0.2, cost_large: float = 1.0,
                 signal: Any = "mean_confidence",
                 large_backend: Any = None,
                 names: Optional[List[str]] = None) -> "CascadeSpec":
        """The legacy (M_S, M_L, tau) engine shape as a spec — the
        bit-exact-parity construction the deprecation shim maps old
        constructor kwargs onto."""
        names = names or ["small", "large"]
        return cls(
            tiers=[CascadeTier(names[0], runner=small, cost=cost_small),
                   CascadeTier(names[1], runner=large, cost=cost_large,
                               backend=large_backend)],
            edges=[DeferralEdge(signal=signal, tau=tau, margin=margin,
                                min_tokens=min_tokens)])
