"""Online tau recalibration: hold a target deferral ratio under drift.

Offline calibration (`calibrate_edges`) picks each edge's tau as a
quantile of a *validation* confidence distribution. Live traffic drifts:
topics shift, prompts get harder, the quantile moves, and a fixed tau
quietly over- or under-defers — the deployment failure the ROADMAP's
"adaptive routing at scale" item names.

`TauController` closes the loop per edge from streaming confidence
telemetry, with two cooperating pieces:

* **EWMA quantile tracker** — stochastic (Robbins–Monro) quantile
  tracking: for each observed confidence c, step
  ``tau += step_scale * (target - 1[c < tau])``. The indicator's
  expectation is the current deferral probability, so tau converges to
  the target quantile of whatever the *current* traffic distribution
  is. The step is scaled by an EWMA of |c - tau| so the controller is
  invariant to the signal's units (neg-entropy nats vs agreement
  fractions).
* **Hysteresis gate** — the tracker only *moves* while the EWMA of the
  realized deferral indicator sits outside a deadband around the
  target, and keeps correcting until it re-enters a tighter re-arm
  band. On stationary traffic the gate stays closed and tau genuinely
  stays put (no random-walk wander); under drift the deadband breach
  opens it. This is the confidence-tuner / drift-detector split: the
  EWMA ratio is the drift detector, the quantile tracker the tuner.

The controller is model-free and signal-agnostic: it sees only the
scalar confidences the engine already computes at each edge's decision
point, so it costs nothing on the device hot path. `observe()` must be
called with the SAME tau the engine used for the decision — call it
right after deciding, before reading `tau` for the next request.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class RecalibConfig:
    """Knobs for one edge's tau controller.

    target_ratio  — deferral ratio to hold (None: use the edge's
                    offline-calibration target, set by the engine).
    step          — quantile-tracker step as a fraction of the tracked
                    confidence spread per observation.
    ewma_alpha    — smoothing of the deferral-indicator EWMA (the drift
                    detector) and the spread EWMA.
    deadband      — |ewma - target| must exceed this to OPEN the gate
                    (start moving tau).
    rearm         — gate CLOSES once |ewma - target| falls back inside
                    this (must be < deadband: that gap is the
                    hysteresis).
    warmup        — observations before the gate may open (the EWMA
                    needs to mean something first).
    tau_min/max   — hard clamps on tau (optional).
    """
    target_ratio: Optional[float] = None
    step: float = 0.08
    ewma_alpha: float = 0.01
    deadband: float = 0.1
    rearm: float = 0.02
    warmup: int = 32
    tau_min: float = -math.inf
    tau_max: float = math.inf

    def __post_init__(self):
        if not (0.0 <= self.rearm < self.deadband):
            raise ValueError(f"need 0 <= rearm < deadband, got "
                             f"rearm={self.rearm} deadband={self.deadband}")
        if self.target_ratio is not None \
                and not (0.0 <= self.target_ratio <= 1.0):
            raise ValueError(f"target_ratio must be in [0, 1], "
                             f"got {self.target_ratio}")


class TauController:
    """One edge's online tau tracker (see module docstring).

    `observe(conf)` ingests the confidence the edge just gated on and
    returns the (possibly nudged) tau to use for the NEXT decision.
    `trace` records (n_observed, tau) at every actual movement — the
    bench logs it so tau drift is a visible artifact, not a mystery."""

    def __init__(self, tau0: float, target_ratio: float,
                 cfg: Optional[RecalibConfig] = None):
        self.cfg = cfg or RecalibConfig()
        if not (0.0 <= target_ratio <= 1.0):
            raise ValueError(f"target_ratio must be in [0, 1], "
                             f"got {target_ratio}")
        self.target = target_ratio
        self.tau = float(tau0)
        self.n_observed = 0
        self.n_updates = 0
        self.correcting = False
        # start the ratio EWMA AT the target: a fresh controller has no
        # evidence of drift, so the gate must not open on boot noise
        self._ewma_ratio = target_ratio
        self._spread: Optional[float] = None
        self.trace: List[Tuple[int, float]] = [(0, self.tau)]

    def observe(self, conf: float) -> float:
        cfg = self.cfg
        d = 1.0 if conf < self.tau else 0.0
        a = cfg.ewma_alpha
        self._ewma_ratio += a * (d - self._ewma_ratio)
        dev = abs(float(conf) - self.tau)
        self._spread = dev if self._spread is None \
            else self._spread + a * (dev - self._spread)
        self.n_observed += 1
        if self.n_observed < cfg.warmup:
            return self.tau
        err = self._ewma_ratio - self.target
        if not self.correcting:
            if abs(err) > cfg.deadband:
                self.correcting = True
        elif abs(err) <= cfg.rearm:
            self.correcting = False
        if self.correcting:
            step = cfg.step * max(self._spread or 0.0, 1e-9)
            # move tau toward the target quantile of the live stream:
            # deferring too rarely (d=0 on average) raises tau, too
            # often lowers it
            new_tau = self.tau + step * (self.target - d)
            new_tau = min(max(new_tau, cfg.tau_min), cfg.tau_max)
            if new_tau != self.tau:
                self.tau = new_tau
                self.n_updates += 1
                self.trace.append((self.n_observed, self.tau))
        return self.tau

    @property
    def ewma_ratio(self) -> float:
        """Current EWMA of the realized deferral indicator (the drift
        detector's view of the live deferral ratio)."""
        return self._ewma_ratio


class EdgeRecalibrator:
    """Per-edge `TauController` bundle for a cascade ladder.

    Built by the engine when recalibration is on: one controller per
    edge, seeded from the edge's offline tau and the run's target
    deferral ratio(s). `tau(e)` is the live threshold for edge e;
    `observe(e, conf)` feeds the decision stream back."""

    def __init__(self, taus: List[float], target_ratio,
                 cfg: Optional[RecalibConfig] = None):
        cfg = cfg or RecalibConfig()
        targets = (list(target_ratio) if hasattr(target_ratio, "__len__")
                   else [float(target_ratio)] * len(taus))
        if len(targets) != len(taus):
            raise ValueError(f"{len(taus)} edges but {len(targets)} "
                             f"target ratios")
        self.controllers = [TauController(t, r, cfg)
                            for t, r in zip(taus, targets)]

    def tau(self, edge: int) -> float:
        return self.controllers[edge].tau

    def observe(self, edge: int, conf: float) -> float:
        return self.controllers[edge].observe(conf)

    def summary(self) -> Dict[str, object]:
        """Bench/stats payload: final taus, movement counts, and the
        (downsampled) per-edge tau traces."""
        out: Dict[str, object] = {
            "tau_final": [c.tau for c in self.controllers],
            "tau_updates": [c.n_updates for c in self.controllers],
            "ewma_ratio": [round(c.ewma_ratio, 4)
                           for c in self.controllers],
        }
        traces = []
        for c in self.controllers:
            tr = c.trace
            if len(tr) > 64:            # keep artifacts bounded
                stride = max(1, len(tr) // 64)
                tr = tr[::stride] + [tr[-1]]
            traces.append([(n, round(t, 6)) for n, t in tr])
        out["tau_trace"] = traces
        return out
