"""Evaluation metrics for cascade deferral (paper §4.1 + appendices).

  * s_o  — distributional overlap of correct/incorrect confidences (eq. 9),
           KDE-based min-overlap integral.
  * s_d  — deferral performance (eq. 10): realized area over random,
           normalized by ideal area over random.
  * ideal_deferral_curve — piecewise-linear oracle curve (App. A.2, eq. 11).
  * AUROC (App. B.3, eq. 12).
  * Pearson correlation for non-binary factuality scores (§4.3).

These are numpy/jnp-agnostic evaluation utilities (host-side, not jitted —
they run on experiment outputs, not in the training step).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Distributional overlap s_o (eq. 9)
# ---------------------------------------------------------------------------

def _gaussian_kde(samples: np.ndarray, grid: np.ndarray,
                  bandwidth: Optional[float] = None) -> np.ndarray:
    """Minimal Gaussian KDE (Scott's rule) evaluated on `grid`."""
    samples = np.asarray(samples, dtype=np.float64).ravel()
    n = samples.size
    if n == 0:
        return np.zeros_like(grid)
    if bandwidth is None:
        std = samples.std()
        if std <= 1e-12:
            std = 1e-3
        bandwidth = 1.06 * std * n ** (-1 / 5)
        bandwidth = max(bandwidth, 1e-4)
    z = (grid[:, None] - samples[None, :]) / bandwidth
    dens = np.exp(-0.5 * z * z).sum(axis=1)
    dens /= n * bandwidth * np.sqrt(2 * np.pi)
    return dens


def distributional_overlap(conf_correct: np.ndarray,
                           conf_incorrect: np.ndarray,
                           num_grid: int = 512,
                           bandwidth: Optional[float] = None) -> float:
    """s_o = integral of min(p_corr(c), p_incorr(c)) dc  (eq. 9).

    1.0 = indistinguishable, 0.0 = perfectly separable. Grid spans the union
    support of both samples (confidences need not live in [0,1] — negative
    entropy is unbounded below).
    """
    a = np.asarray(conf_correct, np.float64).ravel()
    b = np.asarray(conf_incorrect, np.float64).ravel()
    if a.size == 0 or b.size == 0:
        return float("nan")
    lo = min(a.min(), b.min())
    hi = max(a.max(), b.max())
    span = max(hi - lo, 1e-6)
    grid = np.linspace(lo - 0.05 * span, hi + 0.05 * span, num_grid)
    pa = _gaussian_kde(a, grid, bandwidth)
    pb = _gaussian_kde(b, grid, bandwidth)
    return float(np.trapezoid(np.minimum(pa, pb), grid))


# ---------------------------------------------------------------------------
# Deferral curves and s_d (eq. 10, App. A.2)
# ---------------------------------------------------------------------------

def ideal_deferral_curve(r: np.ndarray, p_s: float, p_l: float) -> np.ndarray:
    """acc_ideal(r), eq. (11): linear from p_s to p_l over [0, 1-p_s], then flat."""
    r = np.asarray(r, np.float64)
    knee = 1.0 - p_s
    if knee <= 1e-12:
        return np.full_like(r, p_l)
    rising = p_s + (p_l - p_s) / knee * r
    return np.where(r <= knee, rising, p_l)


def random_deferral_curve(r: np.ndarray, p_s: float, p_l: float) -> np.ndarray:
    """acc_rand(r) = (1-r) p_s + r p_l — linear interpolation."""
    r = np.asarray(r, np.float64)
    return (1.0 - r) * p_s + r * p_l


def realized_deferral_curve(confidence: np.ndarray,
                            small_correct: np.ndarray,
                            large_correct: np.ndarray,
                            ratios: Optional[np.ndarray] = None
                            ) -> Tuple[np.ndarray, np.ndarray]:
    """acc_real(r) under the learned deferral strategy g.

    For each deferral ratio r, defer the r-fraction of LEAST confident
    examples to M_L and measure joint accuracy.

    Args:
      confidence: [N] deferral signal g(x_i) (higher = keep on M_S).
      small_correct / large_correct: [N] {0,1} per-example correctness
        (or graded scores in [0,1] for the factuality variant of §4.3).
      ratios: deferral ratios to evaluate (default 0..1 in 1/N steps,
        capped at 201 points).

    Returns (ratios, joint_accuracy).
    """
    conf = np.asarray(confidence, np.float64).ravel()
    sc = np.asarray(small_correct, np.float64).ravel()
    lc = np.asarray(large_correct, np.float64).ravel()
    n = conf.size
    order = np.argsort(conf)          # ascending: least confident first
    sc_sorted = sc[order]
    lc_sorted = lc[order]
    # prefix[k] = sum of lc over the k least-confident (deferred),
    # suffix      = sum of sc over the rest (kept on M_S).
    prefix_lc = np.concatenate([[0.0], np.cumsum(lc_sorted)])
    prefix_sc = np.concatenate([[0.0], np.cumsum(sc_sorted)])
    total_sc = prefix_sc[-1]
    if ratios is None:
        m = min(n, 200)
        ratios = np.linspace(0.0, 1.0, m + 1)
    accs = np.empty_like(ratios)
    for i, r in enumerate(ratios):
        k = int(round(r * n))
        accs[i] = (prefix_lc[k] + (total_sc - prefix_sc[k])) / n
    return np.asarray(ratios), accs


def deferral_performance(confidence: np.ndarray,
                         small_correct: np.ndarray,
                         large_correct: np.ndarray,
                         num_ratios: int = 200) -> dict:
    """s_d of eq. (10) plus the underlying curves.

    s_d = ∫(acc_real - acc_rand) dr / ∫(acc_ideal - acc_rand) dr.
    1.0 = ideal deferral, 0.0 = no better than random.
    """
    sc = np.asarray(small_correct, np.float64).ravel()
    lc = np.asarray(large_correct, np.float64).ravel()
    p_s = float(sc.mean())
    p_l = float(lc.mean())
    ratios = np.linspace(0.0, 1.0, num_ratios + 1)
    _, acc_real = realized_deferral_curve(confidence, sc, lc, ratios)
    acc_rand = random_deferral_curve(ratios, p_s, p_l)
    acc_ideal = ideal_deferral_curve(ratios, p_s, p_l)
    num = np.trapezoid(acc_real - acc_rand, ratios)
    den = np.trapezoid(acc_ideal - acc_rand, ratios)
    s_d = float(num / den) if abs(den) > 1e-12 else float("nan")
    return {
        "s_d": s_d,
        "p_s": p_s,
        "p_l": p_l,
        "ratios": ratios,
        "acc_real": acc_real,
        "acc_rand": acc_rand,
        "acc_ideal": acc_ideal,
        "area_realized": float(num),
        "area_useful": float(den),
    }


# ---------------------------------------------------------------------------
# AUROC (App. B.3, eq. 12)
# ---------------------------------------------------------------------------

def auroc(conf_correct: np.ndarray, conf_incorrect: np.ndarray) -> float:
    """Area under the ROC of separating correct (positive) from incorrect
    (negative) by confidence. Computed exactly via the rank statistic
    (equivalent to eq. 12's threshold integral); ties get half credit."""
    pos = np.asarray(conf_correct, np.float64).ravel()
    neg = np.asarray(conf_incorrect, np.float64).ravel()
    if pos.size == 0 or neg.size == 0:
        return float("nan")
    all_scores = np.concatenate([pos, neg])
    order = np.argsort(all_scores, kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, all_scores.size + 1)
    # average ranks for ties
    sorted_scores = all_scores[order]
    i = 0
    while i < sorted_scores.size:
        j = i
        while j + 1 < sorted_scores.size and sorted_scores[j + 1] == sorted_scores[i]:
            j += 1
        if j > i:
            avg = (i + j + 2) / 2.0
            ranks[order[i:j + 1]] = avg
        i = j + 1
    r_pos = ranks[:pos.size].sum()
    u = r_pos - pos.size * (pos.size + 1) / 2.0
    return float(u / (pos.size * neg.size))


# ---------------------------------------------------------------------------
# Factuality-score variant (paper §4.3)
# ---------------------------------------------------------------------------

def pearson_correlation(x: np.ndarray, y: np.ndarray) -> float:
    """rho(g_NENT(x_i), s_Fac(y_hat_i, y_i)) — §4.3 replacement for s_o when
    correctness is graded rather than binary."""
    x = np.asarray(x, np.float64).ravel()
    y = np.asarray(y, np.float64).ravel()
    if x.size < 2:
        return float("nan")
    xs = x - x.mean()
    ys = y - y.mean()
    denom = np.sqrt((xs * xs).sum() * (ys * ys).sum())
    if denom < 1e-12:
        return float("nan")
    return float((xs * ys).sum() / denom)


def expected_calibration_error(confidence: np.ndarray, correct: np.ndarray,
                               num_bins: int = 15) -> float:
    """Beyond-paper: standard ECE, useful to report alongside s_o."""
    conf = np.asarray(confidence, np.float64).ravel()
    corr = np.asarray(correct, np.float64).ravel()
    bins = np.linspace(conf.min(), conf.max() + 1e-9, num_bins + 1)
    ece = 0.0
    for lo, hi in zip(bins[:-1], bins[1:]):
        m = (conf >= lo) & (conf < hi)
        if m.sum() == 0:
            continue
        ece += m.mean() * abs(conf[m].mean() - corr[m].mean())
    return float(ece)


def summarize_deferral(confidence: np.ndarray,
                       small_correct: np.ndarray,
                       large_correct: np.ndarray) -> dict:
    """One-call summary used by benchmarks: s_o, s_d, AUROC, acc(M_S)."""
    conf = np.asarray(confidence, np.float64).ravel()
    sc = np.asarray(small_correct, np.float64).ravel()
    res = deferral_performance(conf, sc, large_correct)
    c_corr = conf[sc > 0.5]
    c_inc = conf[sc <= 0.5]
    res["s_o"] = distributional_overlap(c_corr, c_inc)
    res["auroc"] = auroc(c_corr, c_inc)
    res["acc_small"] = res["p_s"]
    res["acc_large"] = res["p_l"]
    return res
