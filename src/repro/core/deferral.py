"""Deferral signals and selective prediction (paper §3.2 Stage 3, eqs. 6-8).

The deferral function g maps an input to a scalar confidence; the cascade
accepts M_S's answer when g(x) >= tau and defers to M_L otherwise (eq. 6).

Two layers live here:

* **Array-level signals** (`SIGNALS`): pure functions logits -> confidence
  used by the classifier `Cascade` and evaluation sweeps.
* **Serving-level signals** (`DeferralSignal` / `SERVING_SIGNALS`): objects
  the cascade *ladder* consults at its per-edge deferral decision points
  (`core.cascade_spec.DeferralEdge`). A serving signal sees a
  `SignalObservation` — the request's prompt, the tier's generated tokens,
  the device-accumulated eq.-8 mean confidence, and (for tiers running
  locally) the tier's `ModelRunner` — and returns one scalar compared
  against the edge's tau with the repo-wide ``deferred = conf < tau``
  convention. The built-ins:

  ``mean_confidence``
      The paper's eq.-8 path: mean negative predictive entropy of the
      tier's own decode, already accumulated on device. Supports running
      (in-flight) evaluation, so early exit works under it.
  ``semantic_agreement``
      k-sample semantic-agreement voting for open-ended generation
      (arXiv 2509.21837): draw k cheap stochastic samples from the tier's
      model and score the mean pairwise token-agreement in [0, 1] — high
      agreement means the model keeps telling the same story, low
      agreement means it is guessing. Needs the tier's runner locally and
      has no in-flight form (evaluated once, at the decision point).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def max_softmax(logits: jnp.ndarray) -> jnp.ndarray:
    """g_CL(x) = max_c p(y=c|x) (eq. 7). logits [..., C] -> [...]."""
    return jax.nn.softmax(logits.astype(jnp.float32), axis=-1).max(axis=-1)


def negative_entropy(logits: jnp.ndarray) -> jnp.ndarray:
    """-H(p) per position, stable from logits. Higher = more confident."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return (jnp.exp(logp) * logp).sum(axis=-1)


def sequence_negative_entropy(logits: jnp.ndarray,
                              valid_mask: Optional[jnp.ndarray] = None
                              ) -> jnp.ndarray:
    """g_NENT(x) = 1/T sum_t sum_c p log p (eq. 8).

    logits: [..., T, V]; valid_mask: [..., T] (1 = real token). Returns [...]
    — mean negative predictive entropy over valid positions.
    """
    nent = negative_entropy(logits)            # [..., T]
    if valid_mask is None:
        return nent.mean(axis=-1)
    m = valid_mask.astype(jnp.float32)
    return (nent * m).sum(axis=-1) / jnp.maximum(m.sum(axis=-1), 1.0)


def margin_confidence(logits: jnp.ndarray) -> jnp.ndarray:
    """Beyond-paper signal: top-1/top-2 probability margin."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top2 = jax.lax.top_k(p, 2)[0]
    return top2[..., 0] - top2[..., 1]


SIGNALS = {
    "max_softmax": max_softmax,
    "neg_entropy": negative_entropy,
    "seq_neg_entropy": sequence_negative_entropy,
    "margin": margin_confidence,
}


def defer_mask(confidence: jnp.ndarray, tau: float | jnp.ndarray) -> jnp.ndarray:
    """True where the cascade DEFERS to M_L (confidence < tau), eq. 6."""
    return confidence < tau


def selective_predict(small_preds: jnp.ndarray,
                      large_preds: jnp.ndarray,
                      confidence: jnp.ndarray,
                      tau: float | jnp.ndarray) -> jnp.ndarray:
    """(M_S, M_L, g)(x) of eq. 6, vectorized over a batch.

    small_preds/large_preds may be class ids [N] or token arrays [N, T];
    confidence is [N].
    """
    mask = defer_mask(confidence, tau)
    while mask.ndim < small_preds.ndim:
        mask = mask[..., None]
    return jnp.where(mask, large_preds, small_preds)


# ---------------------------------------------------------------------------
# Serving-level deferral signals (cascade-ladder per-edge decisions)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SignalObservation:
    """Everything a serving edge knows about one request at its deferral
    decision point. `mean_confidence` is the tier's device-accumulated
    eq.-8 mean negative entropy; `runner` is the tier's local
    `ModelRunner` (None when the tier executes behind a remote backend);
    `tokens` are the tokens the tier actually generated (may be a
    truncated record for in-flight evictions)."""
    prompt: np.ndarray
    mean_confidence: float
    tokens: Optional[np.ndarray] = None
    runner: Any = None
    max_new: int = 0
    rid: int = 0


class MeanConfidenceSignal:
    """Eq.-8 mean negative predictive entropy — the paper's signal and
    the ladder default. Zero extra compute: the confidence is already
    accumulated on device by the tier's decode loop, and a running mean
    exists every step, so in-flight early exit works under it."""

    name = "mean_confidence"
    supports_running = True

    def running(self, mean_confidence: float, n_gen: int) -> float:
        return mean_confidence

    def finalize(self, obs: SignalObservation) -> float:
        return float(obs.mean_confidence)


class SemanticAgreementSignal:
    """k-sample semantic-agreement voting (arXiv 2509.21837): sample k
    stochastic continuations of the prompt from the tier's own model and
    return the mean pairwise per-token agreement in [0, 1]. An
    open-ended generator that keeps producing the same continuation is
    confident even when its per-token entropy says otherwise (many valid
    surface forms); one that disagrees with itself is guessing.

    Costs k extra sampled generations per gated request, paid once at
    the decision point — there is no running form, so edges using this
    signal never early-exit. Sampling keys derive from the prompt bytes
    (crc32), so the score is deterministic per request and independent
    of batch composition or decision order."""

    name = "semantic_agreement"
    supports_running = False

    def __init__(self, k: int = 4, temperature: float = 0.8,
                 seed: int = 0):
        if k < 2:
            raise ValueError(f"semantic agreement needs k >= 2 samples, "
                             f"got {k}")
        self.k = k
        self.temperature = temperature
        self.seed = seed

    def running(self, mean_confidence: float, n_gen: int) -> None:
        return None

    def finalize(self, obs: SignalObservation) -> float:
        if obs.runner is None:
            raise ValueError(
                "semantic_agreement needs the tier's local ModelRunner "
                "to draw samples; this tier only has a remote backend")
        prompt = np.asarray(obs.prompt, np.int32)
        # deterministic per-request key: prompt-content hash, not rid,
        # so identical prompts score identically across runs
        seed = zlib.crc32(prompt.tobytes()) ^ self.seed
        max_new = obs.max_new or (len(obs.tokens)
                                  if obs.tokens is not None else 1)
        samples = obs.runner.sample(
            np.tile(prompt, (self.k, 1)), int(prompt.shape[0]),
            int(max_new), seed=seed, temperature=self.temperature)
        return float(pairwise_agreement(samples))


def pairwise_agreement(samples: np.ndarray) -> float:
    """Mean pairwise per-token agreement of a [k, T] sample matrix, in
    [0, 1]: 1.0 when all k samples are identical token-for-token."""
    s = np.asarray(samples)
    k = s.shape[0]
    if k < 2:
        return 1.0
    total, pairs = 0.0, 0
    for i in range(k):
        for j in range(i + 1, k):
            total += float((s[i] == s[j]).mean())
            pairs += 1
    return total / pairs


SERVING_SIGNALS = {
    "mean_confidence": MeanConfidenceSignal,
    "semantic_agreement": SemanticAgreementSignal,
}


def resolve_signal(signal: Any) -> Any:
    """Accept a serving-signal name or an instance; return the instance.
    Names construct with defaults — pass an instance for custom knobs."""
    if isinstance(signal, str):
        try:
            return SERVING_SIGNALS[signal]()
        except KeyError:
            raise ValueError(
                f"unknown deferral signal {signal!r}; known: "
                f"{sorted(SERVING_SIGNALS)}") from None
    if not hasattr(signal, "finalize") or not hasattr(signal, "running"):
        raise TypeError(f"deferral signal must implement "
                        f"running()/finalize(), got {type(signal).__name__}")
    return signal
