"""Deferral signals and selective prediction (paper §3.2 Stage 3, eqs. 6-8).

The deferral function g maps an input to a scalar confidence; the cascade
accepts M_S's answer when g(x) >= tau and defers to M_L otherwise (eq. 6).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def max_softmax(logits: jnp.ndarray) -> jnp.ndarray:
    """g_CL(x) = max_c p(y=c|x) (eq. 7). logits [..., C] -> [...]."""
    return jax.nn.softmax(logits.astype(jnp.float32), axis=-1).max(axis=-1)


def negative_entropy(logits: jnp.ndarray) -> jnp.ndarray:
    """-H(p) per position, stable from logits. Higher = more confident."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return (jnp.exp(logp) * logp).sum(axis=-1)


def sequence_negative_entropy(logits: jnp.ndarray,
                              valid_mask: Optional[jnp.ndarray] = None
                              ) -> jnp.ndarray:
    """g_NENT(x) = 1/T sum_t sum_c p log p (eq. 8).

    logits: [..., T, V]; valid_mask: [..., T] (1 = real token). Returns [...]
    — mean negative predictive entropy over valid positions.
    """
    nent = negative_entropy(logits)            # [..., T]
    if valid_mask is None:
        return nent.mean(axis=-1)
    m = valid_mask.astype(jnp.float32)
    return (nent * m).sum(axis=-1) / jnp.maximum(m.sum(axis=-1), 1.0)


def margin_confidence(logits: jnp.ndarray) -> jnp.ndarray:
    """Beyond-paper signal: top-1/top-2 probability margin."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top2 = jax.lax.top_k(p, 2)[0]
    return top2[..., 0] - top2[..., 1]


SIGNALS = {
    "max_softmax": max_softmax,
    "neg_entropy": negative_entropy,
    "seq_neg_entropy": sequence_negative_entropy,
    "margin": margin_confidence,
}


def defer_mask(confidence: jnp.ndarray, tau: float | jnp.ndarray) -> jnp.ndarray:
    """True where the cascade DEFERS to M_L (confidence < tau), eq. 6."""
    return confidence < tau


def selective_predict(small_preds: jnp.ndarray,
                      large_preds: jnp.ndarray,
                      confidence: jnp.ndarray,
                      tau: float | jnp.ndarray) -> jnp.ndarray:
    """(M_S, M_L, g)(x) of eq. 6, vectorized over a batch.

    small_preds/large_preds may be class ids [N] or token arrays [N, T];
    confidence is [N].
    """
    mask = defer_mask(confidence, tau)
    while mask.ndim < small_preds.ndim:
        mask = mask[..., None]
    return jnp.where(mask, large_preds, small_preds)
