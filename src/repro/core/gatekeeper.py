"""Gatekeeper loss (Rabanser et al. 2025) — the paper's core contribution.

Implements the correctness-aware hybrid loss of eqs. (1)-(3) for classifiers
and the token-level generalization of eqs. (4)-(5) for sequence models:

    L        = alpha * L_corr + (1 - alpha) * L_incorr
    L_corr   = mean over CORRECT  examples of CE(p, y)
    L_incorr = mean over INCORRECT examples of KL(p || Uniform)

Correct/incorrect is decided *dynamically* from the model's current argmax
(the paper's improvement over Rawat et al. 2021's static partition).

All functions are pure and jit/pjit friendly; they operate on logits, never
materializing full probability tensors beyond one softmax (and the fused
Pallas path in repro/kernels avoids even that on TPU).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GatekeeperConfig:
    """Configuration of the Gatekeeper fine-tuning loss.

    Attributes:
      alpha: trade-off in (0, 1). Low alpha emphasizes pushing incorrect
        predictions toward uniform (better deferral, lower raw accuracy);
        high alpha sharpens correct predictions (paper §3.2).
      soft_targets: if True, targets are a probability distribution (e.g.
        M_L's softened outputs) instead of integer labels (paper Stage 2:
        "rely on true labels or utilize the outputs of M_L with soft
        probabilities as targets").
      label_smoothing: optional smoothing applied to hard targets in L_corr.
      mask_pad: integer id treated as padding and excluded from token losses
        (-1 disables).
      stop_grad_partition: if True (default), the correct/incorrect indicator
        is computed under stop_gradient (the indicator is non-differentiable
        anyway; this documents intent and avoids argmax in the backward graph).
    """

    alpha: float = 0.5
    soft_targets: bool = False
    label_smoothing: float = 0.0
    mask_pad: int = -1
    stop_grad_partition: bool = True


def _log_softmax(logits: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  label_smoothing: float = 0.0) -> jnp.ndarray:
    """Per-example CE(p, y) = -log p_y, with optional label smoothing.

    logits: [..., C]; labels: integer [...] -> returns [...] fp32.
    """
    logp = _log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if label_smoothing > 0.0:
        smooth = -logp.mean(axis=-1)
        nll = (1.0 - label_smoothing) * nll + label_smoothing * smooth
    return nll


def soft_cross_entropy(logits: jnp.ndarray, target_probs: jnp.ndarray) -> jnp.ndarray:
    """CE against soft targets (e.g. M_L teacher probabilities)."""
    logp = _log_softmax(logits)
    return -(target_probs.astype(jnp.float32) * logp).sum(axis=-1)


def kl_to_uniform(logits: jnp.ndarray) -> jnp.ndarray:
    """Per-example KL(p || U) = log C - H(p), computed stably from logits.

    KL(p||U) = sum_c p_c log(p_c * C) = log C + sum_c p_c log p_c.
    """
    logp = _log_softmax(logits)
    p = jnp.exp(logp)
    ent = -(p * logp).sum(axis=-1)           # H(p) in nats
    return jnp.log(float(logits.shape[-1])) - ent


def predictive_entropy(logits: jnp.ndarray) -> jnp.ndarray:
    """H(p) per example, fp32, stable."""
    logp = _log_softmax(logits)
    return -(jnp.exp(logp) * logp).sum(axis=-1)


def _masked_mean(values: jnp.ndarray, mask: jnp.ndarray,
                 denom_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Sum of values*mask divided by denom_mask.sum() (defaults to mask).

    NOTE (paper fidelity): eqs. (2)-(3) normalize both terms by the full
    batch size N, not by the count of correct/incorrect samples — callers
    pass `denom_mask=valid` for the loss terms.
    """
    denom = mask if denom_mask is None else denom_mask
    return (values * mask).sum() / jnp.maximum(denom.sum(), 1.0)


@partial(jax.jit, static_argnames=("cfg",))
def gatekeeper_loss(logits: jnp.ndarray,
                    targets: jnp.ndarray,
                    cfg: GatekeeperConfig = GatekeeperConfig(),
                    valid_mask: Optional[jnp.ndarray] = None):
    """The Gatekeeper hybrid loss, eqs. (1)-(5).

    Works for both classifiers (logits [N, C], targets [N]) and token models
    (logits [N, T, V], targets [N, T]) — the correctness partition, CE and
    KL-to-uniform are all per-position, and both branches reduce with a
    masked mean over valid positions.

    Args:
      logits: [..., C] raw logits of M_S.
      targets: integer labels [...] (or [..., C] soft target probs when
        cfg.soft_targets).
      valid_mask: optional [...] {0,1} mask of positions to include.

    Returns:
      (loss, aux) where aux carries the partition statistics used by the
      training loop and by tests.
    """
    if cfg.soft_targets:
        hard_targets = jnp.argmax(targets, axis=-1)
    else:
        hard_targets = targets

    preds = jnp.argmax(logits, axis=-1)
    correct = (preds == hard_targets)
    if cfg.stop_grad_partition:
        correct = jax.lax.stop_gradient(correct)
    correct = correct.astype(jnp.float32)

    if valid_mask is None:
        valid = jnp.ones_like(correct)
    else:
        valid = valid_mask.astype(jnp.float32)
    if cfg.mask_pad >= 0 and not cfg.soft_targets:
        valid = valid * (targets != cfg.mask_pad).astype(jnp.float32)

    if cfg.soft_targets:
        ce = soft_cross_entropy(logits, targets)
    else:
        ce = cross_entropy(logits, hard_targets, cfg.label_smoothing)
    kl = kl_to_uniform(logits)

    l_corr = _masked_mean(ce, correct * valid, valid)          # eq. (2)/(4)
    l_incorr = _masked_mean(kl, (1.0 - correct) * valid, valid)  # eq. (3)/(5)
    loss = cfg.alpha * l_corr + (1.0 - cfg.alpha) * l_incorr  # eq. (1)

    aux = {
        "loss": loss,
        "l_corr": l_corr,
        "l_incorr": l_incorr,
        "frac_correct": _masked_mean(correct, valid),
        "mean_entropy": _masked_mean(predictive_entropy(logits), valid),
        "mean_entropy_correct": _masked_mean(predictive_entropy(logits),
                                             correct * valid),
        "mean_entropy_incorrect": _masked_mean(predictive_entropy(logits),
                                               (1.0 - correct) * valid),
    }
    return loss, aux


def gatekeeper_token_loss(logits: jnp.ndarray,
                          targets: jnp.ndarray,
                          cfg: GatekeeperConfig = GatekeeperConfig(),
                          valid_mask: Optional[jnp.ndarray] = None):
    """Token-level Gatekeeper (eqs. 4-5). Thin alias — the generic
    implementation already sums per token position; provided for API clarity
    at call sites (LM / VLM training paths)."""
    return gatekeeper_loss(logits, targets, cfg, valid_mask)


def standard_ce_loss(logits: jnp.ndarray, targets: jnp.ndarray,
                     valid_mask: Optional[jnp.ndarray] = None):
    """Stage-1 standard training objective (perplexity minimization)."""
    ce = cross_entropy(logits, targets)
    if valid_mask is None:
        valid = jnp.ones(ce.shape, jnp.float32)
    else:
        valid = valid_mask.astype(jnp.float32)
    loss = _masked_mean(ce, valid)
    preds = jnp.argmax(logits, axis=-1)
    acc = _masked_mean((preds == targets).astype(jnp.float32), valid)
    return loss, {"loss": loss, "accuracy": acc}
