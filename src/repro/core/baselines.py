"""Prior-work deferral baselines the paper compares against (or cites).

  * Untuned baseline — confidence from the Stage-1 model, no Gatekeeper
    fine-tune (the paper's main comparator).
  * Static partition (Rawat et al. 2021) — pre-partition train data into
    easy/hard ONCE (by a frozen reference model's confidence) and train an
    explicit easy/hard head. The paper improves on this by deciding the
    partition dynamically during training; we implement the static variant
    as a loss so benchmarks can compare.
  * Prompting baselines (App. B.2): "Reduce Confidence" and "Answer N" —
    realized here as instruction-token variants for our synthetic LM tasks
    (black-box analogues; the paper shows they don't help).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.gatekeeper import (
    cross_entropy, kl_to_uniform, _masked_mean)


def static_partition_loss(logits: jnp.ndarray,
                          targets: jnp.ndarray,
                          easy_mask: jnp.ndarray,
                          alpha: float = 0.5,
                          valid_mask: Optional[jnp.ndarray] = None):
    """Rawat'21-style loss: the easy/hard partition `easy_mask` is FIXED
    (computed once, before training, e.g. from M_L's confidence) instead of
    from M_S's live argmax. Same CE / KL-to-uniform branches as Gatekeeper.
    """
    easy = easy_mask.astype(jnp.float32)
    if valid_mask is None:
        valid = jnp.ones_like(easy)
    else:
        valid = valid_mask.astype(jnp.float32)
    ce = cross_entropy(logits, targets)
    kl = kl_to_uniform(logits)
    l_easy = _masked_mean(ce, easy * valid, valid)
    l_hard = _masked_mean(kl, (1.0 - easy) * valid, valid)
    loss = alpha * l_easy + (1.0 - alpha) * l_hard
    return loss, {"loss": loss, "l_easy": l_easy, "l_hard": l_hard}


def compute_static_partition(ref_logits: jnp.ndarray,
                             targets: jnp.ndarray) -> jnp.ndarray:
    """Easy = the frozen reference model (M_L or pre-finetune M_S) already
    answers correctly. Returns a {0,1} mask shaped like `targets`."""
    preds = jnp.argmax(ref_logits, axis=-1)
    return (preds == targets).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Prompting baselines (App. B.2) — black-box prompt modifications.
# For the synthetic LM tasks in this repo, "prompting" = prepending a
# reserved instruction token to the input sequence. The model was never
# trained to use it, mirroring how a deployed LLM receives a novel
# instruction string.
# ---------------------------------------------------------------------------

REDUCE_CONFIDENCE_TOKEN = 1   # reserved ids in our synthetic vocabularies
ANSWER_N_TOKEN = 2
UNCERTAIN_ANSWER_ID = 3       # the "N" answer token


@dataclasses.dataclass(frozen=True)
class PromptingBaseline:
    """Appends an uncertainty instruction token to each request (App. B.2)."""
    kind: str   # "reduce_confidence" | "answer_n"

    def modify_inputs(self, tokens: jnp.ndarray) -> jnp.ndarray:
        tok = {"reduce_confidence": REDUCE_CONFIDENCE_TOKEN,
               "answer_n": ANSWER_N_TOKEN}[self.kind]
        instr = jnp.full(tokens.shape[:-1] + (1,), tok, tokens.dtype)
        # prepend instruction, drop last position to keep static length
        return jnp.concatenate([instr, tokens[..., :-1]], axis=-1)

    def confidence_from_logits(self, logits: jnp.ndarray) -> jnp.ndarray:
        """answer_n: confidence = 1 - p("N"); reduce_confidence: max softmax."""
        p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        if self.kind == "answer_n":
            return 1.0 - p[..., UNCERTAIN_ANSWER_ID]
        return p.max(axis=-1)
