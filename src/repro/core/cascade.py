"""Cascade orchestration: (M_S, M_L, g) of eq. (6) as a framework object.

A `Cascade` wraps two predict functions (arbitrary pytree params + apply) and
a deferral signal. It runs the small model on every request, gates on the
confidence, and only evaluates the large model on the deferred subset.

Two execution modes:
  * `predict_dense`  — jit-friendly: evaluates both models on the full batch
    and selects (used inside pjit programs and for evaluation sweeps where
    M_L outputs are needed for metrics anyway).
  * `predict_sparse` — host-mediated: only the deferred subset is sent to
    M_L (the deployment path; M_L is typically remote — paper Fig. 1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import deferral as deferral_lib
from repro.core import calibration


PredictFn = Callable[[Any, jnp.ndarray], jnp.ndarray]   # (params, x) -> logits


@dataclasses.dataclass
class CascadeResult:
    predictions: np.ndarray        # joint predictions after gating
    confidence: np.ndarray         # g(x) per example
    deferred: np.ndarray           # bool per example
    small_predictions: np.ndarray
    large_predictions: Optional[np.ndarray]
    deferral_ratio: float
    compute_cost: float            # in units of M_L cost (paper Fig. 1)


@dataclasses.dataclass
class Cascade:
    """Two-model cascade with a confidence gate.

    Attributes:
      small_apply / large_apply: (params, inputs) -> logits.
      signal: name in deferral_lib.SIGNALS (default per paper: max_softmax
        for classifiers, seq_neg_entropy for token models).
      tau: acceptance threshold (eq. 6); calibrate via `calibrate_tau`.
      cost_small: relative cost of M_S (paper example: 0.2).
    """

    small_apply: PredictFn
    large_apply: PredictFn
    small_params: Any
    large_params: Any
    signal: str = "max_softmax"
    tau: float = 0.5
    cost_small: float = 0.2
    cost_large: float = 1.0

    def confidence(self, logits: jnp.ndarray,
                   valid_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        fn = deferral_lib.SIGNALS[self.signal]
        if self.signal == "seq_neg_entropy":
            return fn(logits, valid_mask)
        return fn(logits)

    # ------------------------------------------------------------------
    def predict_dense(self, inputs: jnp.ndarray,
                      valid_mask: Optional[jnp.ndarray] = None) -> CascadeResult:
        """Evaluate both models, gate, select (evaluation mode)."""
        s_logits = self.small_apply(self.small_params, inputs)
        conf = self.confidence(s_logits, valid_mask)
        l_logits = self.large_apply(self.large_params, inputs)
        s_pred = jnp.argmax(s_logits, axis=-1)
        l_pred = jnp.argmax(l_logits, axis=-1)
        joint = deferral_lib.selective_predict(s_pred, l_pred, conf, self.tau)
        deferred = np.asarray(deferral_lib.defer_mask(conf, self.tau))
        ratio = float(deferred.mean())
        return CascadeResult(
            predictions=np.asarray(joint),
            confidence=np.asarray(conf),
            deferred=deferred,
            small_predictions=np.asarray(s_pred),
            large_predictions=np.asarray(l_pred),
            deferral_ratio=ratio,
            compute_cost=calibration.expected_compute_cost(
                ratio, self.cost_small, self.cost_large),
        )

    # ------------------------------------------------------------------
    def predict_sparse(self, inputs: jnp.ndarray,
                       valid_mask: Optional[jnp.ndarray] = None) -> CascadeResult:
        """Deployment mode: M_L only sees the deferred subset (host gather)."""
        s_logits = self.small_apply(self.small_params, inputs)
        conf = np.asarray(self.confidence(s_logits, valid_mask))
        s_pred = np.asarray(jnp.argmax(s_logits, axis=-1))
        deferred = conf < self.tau
        joint = s_pred.copy()
        large_preds = None
        if deferred.any():
            idx = np.nonzero(deferred)[0]
            sub = jnp.asarray(np.asarray(inputs)[idx])
            l_logits = self.large_apply(self.large_params, sub)
            lp = np.asarray(jnp.argmax(l_logits, axis=-1))
            joint[idx] = lp
            large_preds = lp
        ratio = float(deferred.mean())
        return CascadeResult(
            predictions=joint,
            confidence=conf,
            deferred=deferred,
            small_predictions=s_pred,
            large_predictions=large_preds,
            deferral_ratio=ratio,
            compute_cost=calibration.expected_compute_cost(
                ratio, self.cost_small, self.cost_large),
        )

    # ------------------------------------------------------------------
    def calibrate_tau(self, val_inputs: jnp.ndarray, *,
                      deferral_ratio: Optional[float] = None,
                      target_accuracy: Optional[float] = None,
                      val_labels: Optional[np.ndarray] = None,
                      valid_mask: Optional[jnp.ndarray] = None) -> float:
        """Set tau from a validation batch for a target ratio or accuracy.

        The ratio path routes through the repo-wide calibration surface
        (`calibration.calibrate_edges`) — one quantile rule, one
        ``deferred = conf < tau`` sentinel convention, shared with the
        serving engines and N-tier ladders."""
        if deferral_ratio is not None:
            return calibration.calibrate_edges(
                self, val_inputs, deferral_ratio=deferral_ratio,
                valid_mask=valid_mask)[0]
        s_logits = self.small_apply(self.small_params, val_inputs)
        conf = np.asarray(self.confidence(s_logits, valid_mask))
        if target_accuracy is not None:
            assert val_labels is not None, "target_accuracy needs val_labels"
            s_pred = np.asarray(jnp.argmax(s_logits, axis=-1))
            l_logits = self.large_apply(self.large_params, val_inputs)
            l_pred = np.asarray(jnp.argmax(l_logits, axis=-1))
            sc = (s_pred == val_labels).astype(np.float64)
            lc = (l_pred == val_labels).astype(np.float64)
            if sc.ndim > 1:   # token models: sequence-level exact match
                sc = sc.all(axis=-1).astype(np.float64)
                lc = lc.all(axis=-1).astype(np.float64)
            tau = calibration.threshold_for_accuracy(conf, sc, lc, target_accuracy)
            if tau is None:
                tau = float(conf.max() + 1.0)   # full deferral
            self.tau = tau
            return self.tau
        raise ValueError("specify deferral_ratio or target_accuracy")
