"""ShapeDtypeStruct input stand-ins for every (arch × input-shape) combo —
weak-type-correct, sharded, zero allocation. The dry-run lowers against
these; the trainer/server build real arrays of the same shapes.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs import ModelConfig, InputShape
from repro.models import encdec as encdec_lib
from repro.models import transformer as tfm
from repro.sharding import AbstractParam, logical_to_spec
from repro.training import optim


def _sds(shape, dtype, logical_axes, mesh: Mesh, rules=None):
    spec = logical_to_spec(logical_axes, shape, mesh, rules)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def abstract_to_sds(tree: Any, mesh: Mesh, rules=None) -> Any:
    """AbstractParam tree -> ShapeDtypeStruct tree with shardings attached."""
    def conv(l: AbstractParam):
        return _sds(l.shape, l.dtype, l.logical_axes, mesh, rules)
    return jax.tree.map(conv, tree,
                        is_leaf=lambda x: isinstance(x, AbstractParam))


def adapt_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Per-assignment policy: long_500k requires sub-quadratic attention —
    SSM/hybrid run natively; full-attention archs run the implemented
    sliding-window variant (window 4096). Training/decode use the arch's
    native attention; PREFILL defaults to chunked online-softmax attention
    (adopted from the §Perf hillclimb: kills the S² score HBM wall, no
    backward pass to worry about)."""
    cfg = cfg.replace(param_dtype="bfloat16", compute_dtype="bfloat16")
    if shape.name == "long_500k" and cfg.family not in ("ssm_rwkv", "hybrid"):
        cfg = cfg.replace(sliding_window=4096)
    if shape.kind == "prefill" and cfg.family != "ssm_rwkv":
        cfg = cfg.replace(attn_chunk=1024)
    return cfg


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                rules=None) -> Dict:
    """Model-input ShapeDtypeStructs for a training/prefill batch."""
    B, S = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    if cfg.family == "vlm":
        P = cfg.vision.n_patches
        batch["tokens"] = _sds((B, S - P), jnp.int32, ("batch", None), mesh,
                               rules)
        batch["patches"] = _sds((B, P, cfg.d_model), jnp.bfloat16,
                                ("batch", None, "act_embed"), mesh, rules)
    elif cfg.family == "encdec":
        batch["tokens"] = _sds((B, S), jnp.int32, ("batch", None), mesh,
                               rules)
        batch["frames"] = _sds((B, cfg.encoder.n_frames, cfg.d_model),
                               jnp.bfloat16, ("batch", None, "act_embed"),
                               mesh, rules)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32, ("batch", None), mesh,
                               rules)
    if shape.kind == "train":
        batch["targets"] = _sds((B, S), jnp.int32, ("batch", None), mesh,
                                rules)
    return batch


def model_state_specs(cfg: ModelConfig, mesh: Mesh,
                      with_opt: bool, rules=None,
                      opt_rules=None) -> Tuple[Any, Any]:
    """(params, opt_state) as sharded SDS trees (abstract init, no alloc).

    opt_rules: separate rule table for AdamW mu/nu — ZeRO-1: shard the
    optimizer state over MORE axes than the params (e.g. the pod axis);
    GSPMD then reduce-scatters grads into the opt shard at the update and
    all-gathers fresh params after, with no per-layer scan resharding."""
    params_abs = tfm.init_params(cfg, None, abstract=True)
    params = abstract_to_sds(params_abs, mesh, rules)
    opt = None
    if with_opt:
        opt_abs = optim.adamw_init(params_abs)
        orl = opt_rules if opt_rules is not None else rules
        opt = optim.AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=abstract_to_sds(opt_abs.mu, mesh, orl),
            nu=abstract_to_sds(opt_abs.nu, mesh, orl))
    return params, opt


def cache_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                rules=None) -> Any:
    B, S = shape.global_batch, shape.seq_len
    init = (encdec_lib.init_cache if cfg.family == "encdec"
            else tfm.init_cache)
    cache_abs = init(cfg, B, S, abstract=True)
    return abstract_to_sds(cache_abs, mesh, rules)


def decode_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                 rules=None):
    """(token, position) stand-ins for serve_step."""
    B = shape.global_batch
    token = _sds((B,), jnp.int32, ("batch",), mesh, rules)
    position = jax.ShapeDtypeStruct((), jnp.int32)
    return token, position
