"""Production mesh construction (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state. The dry-run sets XLA_FLAGS host-device-count=512 before importing
jax; smoke tests and benches see the real (1-device) platform.
"""
from __future__ import annotations

from jax.sharding import Mesh

from repro.sharding import ParallelContext, make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_context(mesh: Mesh) -> ParallelContext:
    pod = "pod" if "pod" in mesh.axis_names else None
    return ParallelContext(mesh=mesh, data_axis="data", model_axis="model",
                           pod_axis=pod)


def make_host_mesh() -> Mesh:
    """1-device mesh for CPU smoke runs through the same code paths."""
    return _make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (~)
HBM_BYTES = 16 * 2**30          # 16 GiB
