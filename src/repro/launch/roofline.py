"""Roofline-term extraction from compiled dry-run artifacts (TPU v5e target).

    compute term    = HLO_FLOPs(per device) / peak_FLOP/s
    memory term     = HLO_bytes(per device) / HBM_bw
    collective term = collective_bytes(per device) / link_bw

`cost_analysis()` is per-partition post-SPMD (verified in-container), so its
flops/bytes are already per device. Collective bytes are parsed from the
per-partition optimized HLO: we sum result sizes of all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute ops with ring-algorithm
byte factors.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.configs import ModelConfig, InputShape
from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW, HBM_BYTES

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8}

_COLL_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))       # [n_groups, group_size]<=[N]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved over ICI, by collective type (ring factors)."""
    out: Dict[str, float] = {"all-reduce": 0.0, "all-gather": 0.0,
                             "reduce-scatter": 0.0, "all-to-all": 0.0,
                             "collective-permute": 0.0, "count": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        size = _shape_bytes(m.group("result"))
        g = _group_size(line)
        if op == "all-reduce":
            moved = 2.0 * size * (g - 1) / g
        elif op == "all-gather":
            moved = size * (g - 1) / g           # result = gathered
        elif op == "reduce-scatter":
            moved = size * (g - 1)               # result = scattered shard
        elif op == "all-to-all":
            moved = size * (g - 1) / g
        else:                                     # collective-permute
            moved = size
        out[op] += moved
        out["count"] += 1
    out["total"] = sum(v for k, v in out.items() if k not in ("count", "total"))
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_global: float
    useful_flops_ratio: float        # MODEL_FLOPS / (HLO_FLOPs * n_devices)
    peak_memory_bytes: Optional[float] = None
    fits_hbm: Optional[bool] = None
    collectives: Optional[Dict] = None

    def row(self) -> str:
        return (f"{self.arch},{self.shape},{self.mesh},"
                f"{self.compute_s:.6g},{self.memory_s:.6g},"
                f"{self.collective_s:.6g},{self.dominant},"
                f"{self.useful_flops_ratio:.4g}")


def make_report(arch: str, shape: str, mesh_name: str, n_devices: int,
                cost: Dict, hlo_text: str, model_flops: float,
                memory_stats=None) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = byts / HBM_BW
    collective_s = coll["total"] / ICI_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    ratio = model_flops / max(flops * n_devices, 1.0)
    peak = None
    fits = None
    if memory_stats is not None:
        peak = float(memory_stats.argument_size_in_bytes
                     + memory_stats.output_size_in_bytes
                     + memory_stats.temp_size_in_bytes
                     - memory_stats.alias_size_in_bytes)
        fits = peak <= HBM_BYTES
    return RooflineReport(arch, shape, mesh_name, flops, byts, coll["total"],
                          compute_s, memory_s, collective_s, dominant,
                          model_flops, ratio, peak, fits, coll)


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS: 6·N·D (train) / 2·N·D (inference), N = active
# matmul params (MoE counts top-k routed + shared experts; the embedding
# gather table is excluded, the unembed projection included). A causal
# attention term is added since 32k-prefill score FLOPs are material.
# ---------------------------------------------------------------------------

def active_matmul_params(cfg: ModelConfig) -> float:
    from repro.models import transformer as tfm
    from repro.sharding import param_count
    params = tfm.init_params(cfg, None, abstract=True)
    total = param_count(params)
    # exclude the gather-only embedding table (unembed tied: the same table
    # does participate in a matmul — count it once, which `total` already
    # does when untied; subtract the gather copy otherwise)
    if "unembed" in params:
        total -= cfg.vocab_size * cfg.d_model
    if cfg.moe is not None:
        n_moe = cfg.n_layers - cfg.moe.n_dense_layers
        per_expert = 3 * cfg.d_model * cfg.moe.d_ff_expert
        routed_total = n_moe * cfg.moe.n_experts * per_expert
        routed_active = n_moe * cfg.moe.top_k * per_expert
        total = total - routed_total + routed_active
    return float(total)


def analytic_model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    n = active_matmul_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    flops = mult * n * tokens
    # attention score+value FLOPs (causal): 2*2*S_kv/2 per token per layer*H*hd
    if cfg.family not in ("ssm_rwkv",):
        S_kv = shape.seq_len
        if cfg.sliding_window:
            S_kv = min(S_kv, cfg.sliding_window)
        hH = cfg.n_heads * cfg.head_dim
        if shape.kind == "decode":
            att = 4.0 * shape.global_batch * S_kv * cfg.n_layers * hH
        else:
            att = 2.0 * shape.global_batch * shape.seq_len * S_kv * \
                cfg.n_layers * hH
        flops += att * (3.0 if shape.kind == "train" else 1.0)
    return flops
