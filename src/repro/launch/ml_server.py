"""M_L server process: the out-of-process half of the distributed tier.

Owns the large `ModelRunner` and serves batched regeneration over the
socket RPC protocol in `repro.serving.remote`. The serving engine
connects with ``--large-backend socket --ml-address host:port`` (or
``--large-backend pool`` across several of these); greedy parity across
processes holds because `build_runners(arch, seed)` derives the large
model's parameters deterministically from ``--arch``/``--seed`` — run
the server and the engine with the same values.

    # one replica on a fixed port
    PYTHONPATH=src python -m repro.launch.ml_server --port 7070

    # the engine, in another shell
    PYTHONPATH=src python -m repro.launch.serve --engine continuous \
        --large-backend socket --ml-address 127.0.0.1:7070

Batching policy (--large-batch / --max-wait) lives server-side: the
server owns the `BatchPolicy`, so batch shapes — and therefore padding
behavior — are decided where the compute runs. Ctrl-C (or a client
``shutdown`` frame) stops the server after the current batch.
"""
from __future__ import annotations

import argparse
import time

from repro.launch.serve import build_runners
from repro.serving.remote import MLServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    help="architecture preset; must match the engine's "
                         "--arch for cross-process greedy parity")
    ap.add_argument("--seed", type=int, default=0,
                    help="parameter seed; must match the engine's --seed")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="listen port (0 = ephemeral, printed at startup)")
    ap.add_argument("--max-new", type=int, default=8,
                    help="regeneration length; must match the engine's "
                         "--max-new")
    ap.add_argument("--large-batch", type=int, default=0,
                    help="regeneration batch size (0 = one exact-size "
                         "batch at drain)")
    ap.add_argument("--max-wait", type=float, default=0.0,
                    help="seconds a partial batch may wait before "
                         "flushing padded (0 = wait for a full batch)")
    ap.add_argument("--latency", type=float, default=0.0,
                    help="injected per-batch response delay (benches)")
    args = ap.parse_args()

    _small, large, _cfg = build_runners(args.arch, args.seed)
    srv = MLServer(large, max_new=args.max_new,
                   large_batch=args.large_batch or None,
                   max_wait=args.max_wait or None,
                   host=args.host, port=args.port,
                   latency=args.latency).start()
    host, port = srv.address
    print(f"M_L server ({args.arch}, seed {args.seed}) listening on "
          f"{host}:{port} — connect with --large-backend socket "
          f"--ml-address {host}:{port}", flush=True)
    try:
        while srv.running:
            time.sleep(0.2)
        print("shutdown frame received, stopping")
    except KeyboardInterrupt:
        print("interrupted, stopping")
    finally:
        srv.stop()
    print(f"served {len(srv.batch_log)} batches")


if __name__ == "__main__":
    main()
