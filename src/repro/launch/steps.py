"""Step builders shared by the dry-run, the trainer and the server.

  * train_step  — Gatekeeper token-level fine-tune step (the paper's
    technique in the training path) with AdamW, for every architecture.
  * prefill_fn  — prompt processing, returns last-position logits +
    deferral confidence.
  * serve_step  — one-token decode returning (next_token, confidence);
    confidence is the paper's negative-predictive-entropy deferral signal,
    computed fused with the step (eq. 8).

The loss/entropy over huge vocabularies (kimi: 163,840) is computed with a
vocab-CHUNKED two-pass algorithm so [B, S, V] logits are never materialized
in fp32 — the XLA analogue of the fused Pallas kernel in repro/kernels.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.core.gatekeeper import GatekeeperConfig
from repro.models import encdec as encdec_lib
from repro.models import transformer as tfm
from repro.models.common import rms_norm
from repro.sharding import ParallelContext
from repro.training import optim


# ---------------------------------------------------------------------------
# Vocab-chunked fused Gatekeeper loss (two-pass logsumexp, no [B,S,V] fp32)
# ---------------------------------------------------------------------------

def chunked_gatekeeper_loss(x_final: jnp.ndarray, table: jnp.ndarray,
                            targets: jnp.ndarray, gk: GatekeeperConfig,
                            valid_mask: Optional[jnp.ndarray] = None,
                            n_chunks: int = 16):
    """Gatekeeper token loss fused with the unembedding.

    x_final: [B, S, d] final hidden states; table: [V, d]; targets [B, S].
    Pass 1: per-token max/logsumexp + argmax over vocab chunks.
    Pass 2: entropy sum + target logit over vocab chunks.
    Memory: O(B*S*V/n_chunks) transient instead of O(B*S*V) fp32.
    """
    B, S, d = x_final.shape
    V = table.shape[0]
    while V % n_chunks != 0:
        n_chunks //= 2
    Vc = V // n_chunks
    x2 = x_final.reshape(B * S, d)
    tgt = targets.reshape(B * S)
    tables = table.reshape(n_chunks, Vc, d)

    def pass1(carry, tb_idx):
        m, lse_acc, amax_val, amax_idx = carry
        tb, idx = tb_idx
        logits = jnp.einsum("td,vd->tv", x2, tb,
                            preferred_element_type=jnp.float32)
        cmax = logits.max(-1)
        cam = logits.argmax(-1)
        new_m = jnp.maximum(m, cmax)
        lse_acc = lse_acc * jnp.exp(m - new_m) + jnp.exp(
            jax.scipy.special.logsumexp(logits, axis=-1) - new_m)
        better = cmax > amax_val
        amax_val = jnp.where(better, cmax, amax_val)
        amax_idx = jnp.where(better, cam + idx * Vc, amax_idx)
        return (new_m, lse_acc, amax_val, amax_idx), None

    init = (jnp.full((B * S,), -jnp.inf, jnp.float32),
            jnp.zeros((B * S,), jnp.float32),
            jnp.full((B * S,), -jnp.inf, jnp.float32),
            jnp.zeros((B * S,), jnp.int32))
    (m, lse_acc, _amax, preds), _ = jax.lax.scan(
        pass1, init, (tables, jnp.arange(n_chunks)))
    lse = m + jnp.log(lse_acc)                     # [T]

    def pass2(carry, tb_idx):
        ent_acc, tgt_logit = carry
        tb, idx = tb_idx
        logits = jnp.einsum("td,vd->tv", x2, tb,
                            preferred_element_type=jnp.float32)
        logp = logits - lse[:, None]
        ent_acc = ent_acc - jnp.sum(jnp.exp(logp) * logp, axis=-1)
        loc = tgt - idx * Vc
        in_chunk = (loc >= 0) & (loc < Vc)
        got = jnp.take_along_axis(
            logits, jnp.clip(loc, 0, Vc - 1)[:, None], axis=-1)[:, 0]
        tgt_logit = jnp.where(in_chunk, got, tgt_logit)
        return (ent_acc, tgt_logit), None

    (entropy, tgt_logit), _ = jax.lax.scan(
        pass2, (jnp.zeros((B * S,), jnp.float32),
                jnp.zeros((B * S,), jnp.float32)),
        (tables, jnp.arange(n_chunks)))

    ce = lse - tgt_logit                           # -log p_target
    kl = jnp.log(float(V)) - entropy               # KL(p || U)
    correct = jax.lax.stop_gradient(preds == tgt).astype(jnp.float32)
    valid = (jnp.ones_like(correct) if valid_mask is None
             else valid_mask.reshape(B * S).astype(jnp.float32))
    denom = jnp.maximum(valid.sum(), 1.0)
    l_corr = (ce * correct * valid).sum() / denom
    l_incorr = (kl * (1 - correct) * valid).sum() / denom
    loss = gk.alpha * l_corr + (1 - gk.alpha) * l_incorr
    aux = {"l_corr": l_corr, "l_incorr": l_incorr,
           "frac_correct": (correct * valid).sum() / denom,
           "mean_entropy": (entropy * valid).sum() / denom}
    return loss, aux


def fused_confidence(x_final: jnp.ndarray, table: jnp.ndarray,
                     n_chunks: int = 8,
                     ctx: Optional["ParallelContext"] = None):
    """Deferral signal at decode: (neg_entropy [T], max_prob [T], argmax [T])
    from final hidden states, vocab-chunked (eq. 7/8 fused with unembed).

    With the "unembed_d" rule set, x_final's d dim is sharded so the
    table's FSDP (d) shard is contracted in place — partial [T, Vc] logits
    psum instead of a per-chunk table all-gather."""
    if ctx is not None:
        x_final = ctx.constrain(x_final, (None, "unembed_d"))
    T, d = x_final.shape
    V = table.shape[0]
    while V % n_chunks != 0:
        n_chunks //= 2
    Vc = V // n_chunks
    tables = table.reshape(n_chunks, Vc, d)

    def pass1(carry, tb_idx):
        m, lse_acc, amax_val, amax_idx = carry
        tb, idx = tb_idx
        logits = jnp.einsum("td,vd->tv", x_final, tb,
                            preferred_element_type=jnp.float32)
        cmax = logits.max(-1)
        new_m = jnp.maximum(m, cmax)
        lse_acc = lse_acc * jnp.exp(m - new_m) + jnp.exp(
            jax.scipy.special.logsumexp(logits, axis=-1) - new_m)
        better = cmax > amax_val
        amax_val = jnp.where(better, cmax, amax_val)
        amax_idx = jnp.where(better, logits.argmax(-1) + idx * Vc, amax_idx)
        return (new_m, lse_acc, amax_val, amax_idx), None

    init = (jnp.full((T,), -jnp.inf, jnp.float32),
            jnp.zeros((T,), jnp.float32),
            jnp.full((T,), -jnp.inf, jnp.float32),
            jnp.zeros((T,), jnp.int32))
    (m, lse_acc, amax_val, amax_idx), _ = jax.lax.scan(
        pass1, init, (tables, jnp.arange(n_chunks)))
    lse = m + jnp.log(lse_acc)

    def pass2(ent_acc, tb):
        logits = jnp.einsum("td,vd->tv", x_final, tb,
                            preferred_element_type=jnp.float32)
        logp = logits - lse[:, None]
        return ent_acc - jnp.sum(jnp.exp(logp) * logp, axis=-1), None

    entropy, _ = jax.lax.scan(pass2, jnp.zeros((T,), jnp.float32), tables)
    max_prob = jnp.exp(amax_val - lse)
    return -entropy, max_prob, amax_idx


# ---------------------------------------------------------------------------
# Forward wrappers returning final hidden states (pre-unembed)
# ---------------------------------------------------------------------------

def _final_hidden(params, cfg: ModelConfig, batch, ctx: ParallelContext):
    """Run the trunk and return (x_final [B,T,d], aux, valid_mask)."""
    if cfg.family == "encdec":
        enc_out = encdec_lib.encode(params, cfg, batch["frames"], ctx)
        kv = encdec_lib.cross_kv(params, cfg, enc_out)
        from repro.models.common import embed_tokens
        x = embed_tokens(params["embedding"], batch["tokens"]).astype(cfg.cdtype())
        positions = jnp.arange(x.shape[1])[None, :]
        x, _ = encdec_lib._decoder_trunk(params, cfg, x, positions, kv, ctx)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, jnp.zeros((), jnp.float32), batch.get("loss_mask")
    extra = batch.get("patches")
    x = tfm._embed_inputs(params, cfg, batch["tokens"], extra, ctx)
    positions = jnp.arange(x.shape[1])[None, :]
    x, _, aux = tfm._trunk(params, cfg, x, positions, ctx)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    mask = batch.get("loss_mask")
    if extra is not None:
        # loss only on text positions (patches prepended)
        P = extra.shape[1]
        m = jnp.concatenate([jnp.zeros((x.shape[0], P), jnp.float32),
                             jnp.ones((x.shape[0], x.shape[1] - P), jnp.float32)],
                            axis=1)
        mask = m if mask is None else mask * m
    return x, aux, mask


def _pad_targets(cfg: ModelConfig, batch, T: int):
    """targets aligned with the (possibly patch-extended) sequence."""
    tgt = batch["targets"]
    if tgt.shape[1] < T:
        pad = jnp.zeros((tgt.shape[0], T - tgt.shape[1]), tgt.dtype)
        tgt = jnp.concatenate([pad, tgt], axis=1)
    return tgt


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, ctx: ParallelContext,
                    gk: GatekeeperConfig = GatekeeperConfig(alpha=0.5),
                    opt_cfg: optim.AdamWConfig = optim.AdamWConfig(),
                    aux_weight: float = 0.01,
                    microbatches: int = 1):
    """Gatekeeper fine-tune step (paper Stage 2) usable for every arch.

    microbatches > 1 runs gradient accumulation: the global batch is
    split along dim 0 and scanned, so live activations scale with the
    microbatch — the memory-term/peak knob that composes with remat."""

    def loss_fn(params, batch):
        x, model_aux, mask = _final_hidden(params, cfg, batch, ctx)
        table = params.get("unembed", params["embedding"])
        tgt = _pad_targets(cfg, batch, x.shape[1])
        loss, aux = chunked_gatekeeper_loss(x, table, tgt, gk, mask)
        return loss + aux_weight * model_aux, aux

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            B = jax.tree.leaves(batch)[0].shape[0]
            assert B % microbatches == 0, (B, microbatches)
            mb = jax.tree.map(
                lambda a: a.reshape((microbatches, B // microbatches)
                                    + a.shape[1:]), batch)

            def acc_body(carry, microbatch):
                loss_a, aux_a, grads_a = carry
                (loss, aux), grads = grads_of(params, microbatch)
                grads_a = jax.tree.map(jnp.add, grads_a, grads)
                aux_a = jax.tree.map(jnp.add, aux_a, aux)
                return (loss_a + loss, aux_a, grads_a), None

            mb0 = jax.tree.map(lambda a: a[0], mb)
            (l_sh, a_sh), g_sh = jax.eval_shape(grads_of, params, mb0)
            zeros = lambda t: jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), t)
            (loss, aux, grads), _ = jax.lax.scan(
                acc_body, (zeros(l_sh), zeros(a_sh), zeros(g_sh)), mb)
            inv = 1.0 / microbatches
            loss = loss * inv
            aux = jax.tree.map(lambda a: a * inv, aux)
            grads = jax.tree.map(lambda g: g * inv, grads)
        else:
            (loss, aux), grads = grads_of(params, batch)
        params, opt_state, om = optim.adamw_update(opt_cfg, grads, opt_state,
                                                   params)
        return params, opt_state, {**aux, **om, "loss": loss}

    return train_step


def make_prefill(cfg: ModelConfig, ctx: ParallelContext):
    def prefill_fn(params, cache, batch):
        if cfg.family == "encdec":
            logits, cache = encdec_lib.prefill(
                params, cfg, batch["frames"], batch["tokens"], cache, ctx,
                last_only=True)
        else:
            logits, cache = tfm.prefill(params, cfg, batch["tokens"], cache,
                                        ctx, batch.get("patches"),
                                        last_only=True)
        last = logits[:, -1, :].astype(jnp.float32)
        logp = jax.nn.log_softmax(last, axis=-1)
        neg_ent = jnp.sum(jnp.exp(logp) * logp, axis=-1)
        return jnp.argmax(last, axis=-1), neg_ent, cache
    return prefill_fn


def make_serve_step(cfg: ModelConfig, ctx: ParallelContext,
                    tau: float = -1.0):
    """One-token decode with the fused deferral signal. Returns
    (next_token [B], confidence [B], defer [B] bool, cache)."""

    def serve_step(params, cache, token, position):
        if cfg.family == "encdec":
            x, cache = _decode_hidden_encdec(params, cfg, token, position,
                                             cache, ctx)
        else:
            x, cache = _decode_hidden(params, cfg, token, position, cache, ctx)
        table = params.get("unembed", params["embedding"])
        neg_ent, max_prob, nxt = fused_confidence(x, table, ctx=ctx)
        defer = neg_ent < tau          # eq. (6): route to M_L
        return nxt, neg_ent, defer, cache

    return serve_step


def _decode_hidden(params, cfg, token, position, cache, ctx):
    if token.ndim == 1:
        token = token[:, None]
    x = tfm._embed_inputs(params, cfg, token, None, ctx)
    x, new_cache, _ = tfm._trunk(params, cfg, x, None, ctx, cache=cache,
                                 decode=True, position=position)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x[:, 0, :], new_cache


def _decode_hidden_encdec(params, cfg, token, position, cache, ctx):
    if token.ndim == 1:
        token = token[:, None]
    from repro.models.common import embed_tokens
    x = embed_tokens(params["embedding"], token).astype(cfg.cdtype())
    kv = jax.tree.map(lambda a: a.astype(cfg.cdtype()), cache["cross_kv"])
    x, new_self = encdec_lib._decoder_trunk(params, cfg, x, None, kv, ctx,
                                            cache=cache["dense"], decode=True,
                                            position=position)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x[:, 0, :], {"dense": new_self, "cross_kv": cache["cross_kv"]}
