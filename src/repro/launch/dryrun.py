import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import (device count locks on first init).

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the single-pod (16,16) and multi-pod (2,16,16) production meshes, print
memory/cost analyses, and emit roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # single-pod, 40 combos
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
Results are appended as JSON lines to benchmarks/results/dryrun.jsonl.
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs import SHAPES, get_config, list_configs
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh, make_context
from repro.launch.specs import (adapt_for_shape, batch_specs, cache_specs,
                                decode_specs, model_state_specs)
from repro.launch.steps import make_prefill, make_serve_step, make_train_step


def _cost_dict(compiled):
    """Normalized `cost_analysis()`: jax 0.4.x returns a one-element list
    of dicts, jax >= 0.5 returns the dict directly."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _lower_for(cfg, shape, mesh, ctx, rules=None, opt_rules=None):
    """Build + lower the appropriate step for `shape.kind`."""
    with jax.default_device(jax.devices("cpu")[0]):
        if shape.kind == "train":
            params, opt = model_state_specs(cfg, mesh, with_opt=True,
                                            rules=rules,
                                            opt_rules=opt_rules)
            batch = batch_specs(cfg, shape, mesh, rules=rules)
            step = make_train_step(cfg, ctx,
                                   microbatches=cfg.microbatches)
            return jax.jit(step, donate_argnums=(0, 1)).lower(
                params, opt, batch)
        if shape.kind == "prefill":
            params, _ = model_state_specs(cfg, mesh, with_opt=False,
                                          rules=rules)
            cache = cache_specs(cfg, shape, mesh, rules=rules)
            batch = batch_specs(cfg, shape, mesh, rules=rules)
            fn = make_prefill(cfg, ctx)
            return jax.jit(fn, donate_argnums=(1,)).lower(
                params, cache, batch)
        params, _ = model_state_specs(cfg, mesh, with_opt=False, rules=rules)
        cache = cache_specs(cfg, shape, mesh, rules=rules)
        token, position = decode_specs(cfg, shape, mesh, rules=rules)
        fn = make_serve_step(cfg, ctx)
        return jax.jit(fn, donate_argnums=(1,)).lower(
            params, cache, token, position)


def _depth_points(cfg):
    """Two shallow depths whose UNROLLED costs extrapolate linearly to L.
    (XLA's HloCostAnalysis counts a scan body once, so full-depth
    cost_analysis under-reports by ~L; we unroll shallow variants and use
    f(L) ≈ f(d1) + (L-d1)/(d2-d1) * (f(d2)-f(d1)).)"""
    if cfg.family == "moe":
        return 2, 3            # 1 dense + 1/2 moe layers
    if cfg.family == "hybrid":
        return cfg.shared_attn_every, 2 * cfg.shared_attn_every
    return 1, 2


def _shallow_cfg(cfg, d):
    kw = dict(n_layers=d, scan_layers=False)
    if cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(cfg.encoder, n_layers=d)
    return cfg.replace(**kw)


def _measured_costs(cfg, shape, mesh, ctx, rules=None, opt_rules=None):
    """(flops, bytes, coll_breakdown) per device from one compile."""
    lowered = _lower_for(cfg, shape, mesh, ctx, rules=rules,
                         opt_rules=opt_rules)
    compiled = lowered.compile()
    cost = _cost_dict(compiled)
    coll = rf.collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def extrapolated_costs(cfg, shape, mesh, ctx, rules=None, opt_rules=None):
    """Depth-extrapolated per-device (flops, bytes, coll_breakdown)."""
    d1, d2 = _depth_points(cfg)
    f1 = _measured_costs(_shallow_cfg(cfg, d1), shape, mesh, ctx, rules,
                         opt_rules)
    f2 = _measured_costs(_shallow_cfg(cfg, d2), shape, mesh, ctx, rules,
                         opt_rules)
    L = cfg.n_layers
    k = (L - d1) / (d2 - d1)
    flops = f1[0] + k * (f2[0] - f1[0])
    byts = f1[1] + k * (f2[1] - f1[1])
    coll = {key: f1[2][key] + k * (f2[2][key] - f1[2][key]) for key in f1[2]}
    return flops, byts, coll


def lower_combo(arch: str, shape_name: str, multi_pod: bool,
                remat: str = "none", verbose: bool = True,
                skip_extrapolation: bool = False,
                rule_overrides: Optional[dict] = None,
                label: Optional[str] = None,
                cfg_overrides: Optional[dict] = None,
                opt_rule_overrides: Optional[dict] = None):
    """Lower + compile one (arch, shape, mesh). Returns result dict.

    rule_overrides: logical-axis -> mesh-axes overrides (hillclimb knob).
    cfg_overrides:  ModelConfig.replace(**...) applied after shape adapt.
    """
    from repro.sharding import rules_dict
    shape = SHAPES[shape_name]
    cfg = adapt_for_shape(get_config(arch), shape)
    if remat != "none":
        cfg = cfg.replace(remat=remat)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    rules = rules_dict(rule_overrides) if rule_overrides else None
    opt_rules = (rules_dict({**(rule_overrides or {}), **opt_rule_overrides})
                 if opt_rule_overrides else None)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_context(mesh)
    if rules is not None:
        ctx = dataclasses.replace(ctx, rules=rules)
    n_dev = mesh.devices.size
    mesh_name = "2x16x16" if multi_pod else "16x16"

    t0 = time.time()
    lowered = _lower_for(cfg, shape, mesh, ctx, rules=rules,
                         opt_rules=opt_rules)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = _cost_dict(compiled)
    memstats = compiled.memory_analysis()
    hlo = compiled.as_text()
    if skip_extrapolation:
        flops, byts = (float(cost.get("flops", 0.0)),
                       float(cost.get("bytes accessed", 0.0)))
        coll_bd = rf.collective_bytes(hlo)
    else:
        flops, byts, coll_bd = extrapolated_costs(cfg, shape, mesh, ctx,
                                                  rules, opt_rules)
    coll = coll_bd["total"]
    model_flops = rf.analytic_model_flops(cfg, shape)
    report = rf.make_report(
        arch, shape_name, mesh_name, n_dev,
        {"flops": flops, "bytes accessed": byts}, "", model_flops, memstats)
    report.collective_bytes_per_device = coll
    report.collective_s = coll / rf.ICI_BW
    report.collectives = coll_bd
    report.dominant = max(
        (("compute", report.compute_s), ("memory", report.memory_s),
         ("collective", report.collective_s)), key=lambda kv: kv[1])[0]
    result = dataclasses.asdict(report)
    result.update({
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "remat": remat, "label": label,
        "rule_overrides": rule_overrides, "cfg_overrides": cfg_overrides,
        "scan_body_flops": float(cost.get("flops", 0.0)),
        "arg_bytes": int(memstats.argument_size_in_bytes),
        "temp_bytes": int(memstats.temp_size_in_bytes),
        "out_bytes": int(memstats.output_size_in_bytes),
    })
    if verbose:
        print(f"== {arch} × {shape_name} × {mesh_name} ==")
        print("memory_analysis:", memstats)
        print("cost_analysis flops/device:", cost.get("flops"),
              "bytes/device:", cost.get("bytes accessed"))
        print(f"roofline: compute={report.compute_s:.4g}s "
              f"memory={report.memory_s:.4g}s "
              f"collective={report.collective_s:.4g}s "
              f"-> dominant={report.dominant}; "
              f"useful-flops ratio={report.useful_flops_ratio:.3g}; "
              f"fits_hbm={report.fits_hbm}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--skip-extrapolation", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun.jsonl")
    args = ap.parse_args()

    combos = []
    archs = [get_config(a).name for a in list_configs()] \
        if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    for a in archs:
        for s in shapes:
            combos.append((a, s))

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    failures = []
    with open(args.out, "a") as f:
        for arch, shape in combos:
            try:
                res = lower_combo(arch, shape, args.multi_pod,
                                  remat=args.remat,
                                  skip_extrapolation=args.skip_extrapolation)
                f.write(json.dumps(res) + "\n")
                f.flush()
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape, repr(e)[:200]))
    if failures:
        print("FAILURES:")
        for fa in failures:
            print(" ", fa)
        raise SystemExit(1)
    print(f"all {len(combos)} combos lowered+compiled OK "
          f"({'multi-pod 2x16x16' if args.multi_pod else 'single-pod 16x16'})")


if __name__ == "__main__":
    main()
