"""Cascade serving driver: small + large model, batched requests, Gatekeeper
deferral (CPU-scale demonstration of the deployment path).

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --requests 32 --max-new 8 --deferral-ratio 0.3
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.data.synthetic import make_lm_stream
from repro.models import transformer as tfm
from repro.serving.engine import CascadeEngine, ModelRunner
from repro.sharding import ParallelContext


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--deferral-ratio", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    key = jax.random.PRNGKey(args.seed)
    small_cfg = reduced(get_config(args.arch))
    large_cfg = small_cfg.replace(name=small_cfg.name + "-large",
                                  n_layers=4, d_model=small_cfg.d_model * 2,
                                  n_heads=8, d_ff=small_cfg.d_ff * 2)
    small = ModelRunner(small_cfg, tfm.init_params(small_cfg, key))
    large = ModelRunner(large_cfg,
                        tfm.init_params(large_cfg, jax.random.fold_in(key, 1)))

    prompts = make_lm_stream(jax.random.fold_in(key, 2),
                             args.requests * 2, args.prompt_len,
                             small_cfg.vocab_size)
    cal, live = prompts[:args.requests], prompts[args.requests:]

    engine = CascadeEngine(small, large)
    tau = engine.calibrate(cal, args.prompt_len, args.max_new,
                           args.deferral_ratio)
    print(f"calibrated tau={tau:.4f} for target deferral "
          f"{args.deferral_ratio}")
    res = engine.serve(live, args.prompt_len, args.max_new)
    print(f"served {len(live)} requests: deferral_ratio="
          f"{res.deferral_ratio:.3f}, compute_cost={res.compute_cost:.3f}x, "
          f"mean_confidence={res.confidence.mean():.4f}")
    print("first tokens:", res.tokens[:4].tolist())


if __name__ == "__main__":
    main()
