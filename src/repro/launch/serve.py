"""Cascade serving driver: small + large model, Gatekeeper deferral
(CPU-scale demonstration of the deployment path).

Two engines (see repro.serving):
  * static      — lock-step batches, full max_new decode before deferral
  * continuous  — continuous batching with in-flight deferral once the
                  running mean confidence drops below tau - margin
                  (saves the remaining M_S steps), over one of two KV
                  backends: --backend slot (dense worst-case rows) or
                  --backend paged (block-paged cache, ragged prompts,
                  chunked prefill batched across same-offset requests;
                  size the budget with --blocks, pick the Pallas paged
                  flash-decode kernel with --paged-kernel)

Deferred requests regenerate on a pluggable M_L backend
(--large-backend): sync runs M_L inline on the decode loop (reference);
thread runs it on a worker thread so M_S decode never stalls on large
batches; stub adds a serialized request/response pipe with injectable
latency (--stub-latency), the shape of a real RPC. --large-batch sets
the regeneration batch size and --large-max-wait bounds how long a
partial batch may wait before flushing.

The distributed tier (docs/serving.md): --large-backend socket talks to
one M_L server process (start it with `python -m repro.launch.ml_server`,
point at it with --ml-address host:port), --large-backend pool
load-balances across several (--ml-address host:a,host:b,...) with
health checks, dead-replica ejection, and in-flight re-dispatch.
--ml-spawn N starts N in-process demo servers instead of requiring
separate processes; --ml-request-timeout/--ml-connect-timeout/
--ml-retries/--ml-health-interval tune the failure handling. With
socket/pool the batching policy (--large-batch/--large-max-wait) is
applied server-side — spawned servers inherit this process's flags,
external servers use their own.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --requests 32 --max-new 8 --deferral-ratio 0.3 \
        --engine continuous --slots 8 --arrival-rate 50 \
        --audit-log /tmp/serve_audit.jsonl

    # ragged prompts over the paged backend
    PYTHONPATH=src python -m repro.launch.serve --engine continuous \
        --backend paged --ragged-min 8 --ragged-max 32 --block-size 8 \
        --prefill-chunk 8

The cascade ladder (docs/serving.md): --tiers N serves an N-tier
cascade (intermediate demo models interpolated between M_S and M_L),
each adjacent pair gated by its own calibrated deferral edge; --signal
picks the per-edge deferral signal (eq.-8 mean confidence, or k-sample
semantic-agreement voting with --signal-k/--signal-temperature);
--recalibrate turns on the online tau controller (EWMA deferral-ratio
tracker with hysteresis) that nudges every edge's tau toward
--recalib-target under arrival drift. Contradictory flag combinations
(e.g. --ml-address with --large-backend sync, paged knobs with
--backend slot) are rejected at argparse time instead of silently
ignored.

Pressure & overload (docs/serving.md): --oversubscribe F admits paged
requests against a virtual budget of round(blocks * F); when the
physical pool runs dry mid-flight, --pressure-policy picks the victim
handling — preempt (save state, requeue age-first, bit-exact resume;
bounded by --max-preemptions before escalating to defer), defer
(straight up the cascade ladder, deferred_reason="oom"), or shed
(REJECTED). --swap-blocks N spills cold registered prefix blocks to a
host-RAM LRU tier instead of dropping them. Admission overload control:
--max-queue bounds the ready queue (overflow shed newest-first as
REJECTED) and --deadline-ms sheds requests still queued past their
deadline as EXPIRED.

Observability (continuous engine; see docs/observability.md):
--trace-out dumps a Perfetto-loadable Chrome trace of the run,
--metrics-out / --metrics-port export the Prometheus metrics registry
(file dump / live scrape endpoint), --device-timing splits host vs
device wall time per phase, and --profile-dir captures a jax.profiler
window of the first --profile-iters engine iterations.
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config, reduced
from repro.core.deferral import SemanticAgreementSignal
from repro.data.synthetic import make_lm_stream, make_ragged_lm_stream
from repro.models import transformer as tfm
from repro.serving import (CascadeEngine, CascadeSpec, CascadeTier,
                           ContinuousCascadeEngine, DeferralEdge,
                           EngineConfig, MLBackendConfig, ModelRunner,
                           PagedConfig, PressureConfig, RecalibConfig,
                           make_requests, poisson_arrivals)
from repro.serving.obs import (Observability, add_obs_args,
                               obs_config_from_args)


def build_runners(arch: str, seed: int):
    small, large = build_ladder(arch, seed, 2)
    return small, large, small.cfg


def build_ladder(arch: str, seed: int, n_tiers: int):
    """One ModelRunner per tier, capacity interpolated from the reduced
    `arch` (tier 0) up to the demo "large" config (last tier):
    intermediate tiers grow depth/FFN only, keeping d_model/head count —
    cheap enough that a CPU demo of a 3- or 4-tier ladder stays fast."""
    key = jax.random.PRNGKey(seed)
    small_cfg = reduced(get_config(arch))
    large_cfg = small_cfg.replace(name=small_cfg.name + "-large",
                                  n_layers=4, d_model=small_cfg.d_model * 2,
                                  n_heads=8, d_ff=small_cfg.d_ff * 2)
    cfgs = [small_cfg]
    for i in range(1, n_tiers - 1):
        f = i / (n_tiers - 1)
        cfgs.append(small_cfg.replace(
            name=f"{small_cfg.name}-mid{i}",
            n_layers=round(small_cfg.n_layers
                           + f * (large_cfg.n_layers - small_cfg.n_layers)),
            d_ff=round(small_cfg.d_ff * (1 + f))))
    cfgs.append(large_cfg)
    # tier 0 keeps the base key (the historical two-runner init), so a
    # 2-tier ladder is weight-identical to every earlier bench run
    return [ModelRunner(c, tfm.init_params(
                c, key if i == 0 else jax.random.fold_in(key, i)))
            for i, c in enumerate(cfgs)]


def make_remote_factory(kind: str, addresses, *, connect_timeout: float,
                        request_timeout: float, retries: int,
                        health_interval: float):
    """Callable `large_backend` for the engine: builds the socket/pool
    backend with the engine-supplied context (max_new, metrics registry)
    plus the addresses/timeouts closed over here. The runner argument is
    ignored — with a remote tier, M_L compute lives in the server."""
    from repro.serving.remote import ReplicaPool, SocketBackend

    def factory(runner=None, max_new=0, large_batch=None, max_wait=None,
                stub_latency=0.0, registry=None):
        if kind == "socket":
            return SocketBackend(addresses[0],
                                 connect_timeout=connect_timeout,
                                 request_timeout=request_timeout,
                                 retries=retries, registry=registry)
        return ReplicaPool(addresses,
                           connect_timeout=connect_timeout,
                           request_timeout=request_timeout,
                           retries=retries,
                           health_interval=health_interval,
                           max_new=max_new, large_batch=large_batch,
                           registry=registry)

    return factory


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--engine", choices=("static", "continuous"),
                    default="continuous")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--deferral-ratio", type=float, default=0.3)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--min-tokens", type=int, default=2)
    ap.add_argument("--margin", type=float, default=0.0)
    ap.add_argument("--no-early-exit", action="store_true")
    ap.add_argument("--tiers", type=int, default=2,
                    help="cascade ladder depth (continuous engine): 2 = "
                         "the paper's M_S/M_L pair; >2 inserts "
                         "intermediate tiers, each with its own "
                         "calibrated deferral edge")
    ap.add_argument("--signal",
                    choices=("mean_confidence", "semantic_agreement"),
                    default="mean_confidence",
                    help="per-edge deferral signal: eq.-8 mean negative "
                         "entropy (running form, supports in-flight "
                         "early exit) or k-sample semantic-agreement "
                         "voting (finalize-only)")
    ap.add_argument("--signal-k", type=int, default=4,
                    help="semantic_agreement: samples per vote")
    ap.add_argument("--signal-temperature", type=float, default=0.8,
                    help="semantic_agreement: sampling temperature")
    ap.add_argument("--recalibrate", action="store_true",
                    help="continuous engine: recalibrate each edge's tau "
                         "online (EWMA quantile tracker with hysteresis) "
                         "toward --recalib-target under arrival drift")
    ap.add_argument("--recalib-target", type=float, default=-1.0,
                    help="target deferral ratio the online controller "
                         "holds per edge (default: --deferral-ratio)")
    ap.add_argument("--recalib-step", type=float, default=0.08,
                    help="recalibration: Robbins-Monro step scale")
    ap.add_argument("--recalib-deadband", type=float, default=0.1,
                    help="recalibration: hysteresis deadband — the "
                         "controller stays idle until |ewma - target| "
                         "exceeds this")
    ap.add_argument("--recalib-warmup", type=int, default=32,
                    help="recalibration: observations before the "
                         "controller may move tau")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrivals/s; 0 = all at t=0")
    ap.add_argument("--large-backend",
                    choices=("sync", "thread", "stub", "socket", "pool"),
                    default="sync",
                    help="M_L regeneration backend: inline (sync), "
                         "worker thread overlapped with M_S decode "
                         "(thread), serialized RPC stub (stub), one "
                         "remote M_L server (socket), or N replicas with "
                         "health checks + re-dispatch (pool)")
    ap.add_argument("--ml-address", default="",
                    help="socket/pool: comma-separated host:port of "
                         "running M_L servers (repro.launch.ml_server); "
                         "empty with --ml-spawn starts in-process demo "
                         "servers")
    ap.add_argument("--ml-spawn", type=int, default=0,
                    help="socket/pool: start this many in-process M_L "
                         "servers on ephemeral ports instead of "
                         "connecting to --ml-address")
    ap.add_argument("--ml-connect-timeout", type=float, default=2.0)
    ap.add_argument("--ml-request-timeout", type=float, default=30.0)
    ap.add_argument("--ml-retries", type=int, default=3,
                    help="socket/pool: RPC retry attempts "
                         "(exponential backoff) before giving up")
    ap.add_argument("--ml-health-interval", type=float, default=2.0,
                    help="pool: seconds between replica health probes")
    ap.add_argument("--large-batch", type=int, default=0,
                    help="M_L regeneration batch size (0 = one "
                         "exact-size batch at end of run)")
    ap.add_argument("--large-max-wait", type=float, default=0.0,
                    help="seconds a partial M_L batch may wait before "
                         "flushing padded (0 = wait for a full batch)")
    ap.add_argument("--stub-latency", type=float, default=0.0,
                    help="injected per-batch RPC latency for "
                         "--large-backend stub")
    ap.add_argument("--audit-log", default=None,
                    help="JSONL audit log path (continuous engine)")
    ap.add_argument("--backend", choices=("slot", "paged"), default="slot",
                    help="continuous engine KV-cache backend")
    ap.add_argument("--block-size", type=int, default=8,
                    help="paged backend: tokens per cache block")
    ap.add_argument("--blocks", type=int, default=0,
                    help="paged backend: physical block budget "
                         "(0 = worst case, always fits)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="paged backend: prefill chunk tokens "
                         "(0 = whole prompt in one chunk)")
    ap.add_argument("--paged-kernel", choices=("auto", "on", "off"),
                    default="auto",
                    help="paged backend: route decode through the Pallas "
                         "paged flash-decode kernels (auto = on for TPU, "
                         "XLA gather fallback on CPU; env "
                         "REPRO_PAGED_KERNEL overrides auto)")
    ap.add_argument("--serial-prefill", action="store_true",
                    help="paged backend: disable batched same-offset "
                         "prefill chunk dispatch (debug/parity)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="paged backend: disable copy-on-write prefix "
                         "sharing (every request prefills and maps its "
                         "whole prompt even when the blocks are already "
                         "resident)")
    ap.add_argument("--oversubscribe", type=float, default=1.0,
                    help="paged backend: admit against a virtual budget "
                         "of round(blocks * factor); > 1.0 allows block "
                         "pressure, handled by --pressure-policy "
                         "(1.0 = classic reservation invariant)")
    ap.add_argument("--pressure-policy",
                    choices=("preempt", "defer", "shed"),
                    default="preempt",
                    help="paged backend under --oversubscribe > 1: evict "
                         "the youngest running request by preempt-and-"
                         "requeue (bit-exact resume), defer-on-OOM up "
                         "the cascade ladder, or shed (REJECTED)")
    ap.add_argument("--max-preemptions", type=int, default=2,
                    help="preempt policy: preemption bound per request "
                         "before escalating to defer-on-OOM")
    ap.add_argument("--swap-blocks", type=int, default=0,
                    help="paged backend: host-RAM swap-tier capacity in "
                         "blocks for cold registered prefix blocks "
                         "(0 = evicted cold blocks are dropped)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="admission overload control: bound on the ready "
                         "arrival queue; overflow is shed newest-first "
                         "as REJECTED (0 = unbounded)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request queueing deadline in ms from "
                         "arrival; requests still queued past it are "
                         "shed as EXPIRED (0 = no deadlines)")
    ap.add_argument("--ragged-min", type=int, default=0,
                    help=">0: ragged prompt lengths uniform in "
                         "[ragged-min, ragged-max] (continuous engine)")
    ap.add_argument("--ragged-max", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    add_obs_args(ap)
    args = ap.parse_args(argv)

    def given(dest: str) -> bool:
        return getattr(args, dest) != ap.get_default(dest)

    if args.ragged_min > 0 and args.engine == "static":
        ap.error("--ragged-min/--ragged-max need --engine continuous "
                 "(the static engine serves lock-step uniform batches)")
    obs_cfg = obs_config_from_args(args)
    if args.engine == "static" and obs_cfg.any_enabled:
        ap.error("observability flags (--trace-out/--metrics-*/"
                 "--device-timing/--profile-dir) need --engine continuous")

    # reject contradictory flag combinations up front: a tuning flag that
    # the selected backend/engine would silently ignore is a user error,
    # not a no-op
    remote = args.large_backend in ("socket", "pool")
    if not remote:
        for dest in ("ml_address", "ml_spawn", "ml_connect_timeout",
                     "ml_request_timeout", "ml_retries",
                     "ml_health_interval"):
            if given(dest):
                ap.error(f"--{dest.replace('_', '-')} needs "
                         f"--large-backend socket|pool (got "
                         f"--large-backend {args.large_backend}, which "
                         f"would silently ignore it)")
    if args.large_backend != "stub" and given("stub_latency"):
        ap.error(f"--stub-latency needs --large-backend stub (got "
                 f"--large-backend {args.large_backend}, which would "
                 f"silently ignore it)")
    if args.backend != "paged":
        for dest in ("block_size", "blocks", "prefill_chunk",
                     "paged_kernel", "serial_prefill",
                     "no_prefix_sharing", "oversubscribe",
                     "pressure_policy", "max_preemptions", "swap_blocks"):
            if given(dest):
                ap.error(f"--{dest.replace('_', '-')} needs --backend "
                         f"paged (got --backend {args.backend}, which "
                         f"would silently ignore it)")
    if args.oversubscribe < 1.0:
        ap.error(f"--oversubscribe must be >= 1.0 (1.0 = reservation-"
                 f"only), got {args.oversubscribe}")
    if args.oversubscribe == 1.0:
        # pressure can only fire past the reservation invariant: tuning
        # its handling without enabling it is a silent no-op
        for dest in ("pressure_policy", "max_preemptions"):
            if given(dest):
                ap.error(f"--{dest.replace('_', '-')} needs "
                         f"--oversubscribe > 1.0 (reservation-only "
                         f"admission never hits block pressure)")
    if args.pressure_policy != "preempt" and given("max_preemptions"):
        ap.error(f"--max-preemptions needs --pressure-policy preempt "
                 f"(got --pressure-policy {args.pressure_policy}, which "
                 f"never preempts)")
    if args.oversubscribe > 1.0 and not given("blocks"):
        ap.error("--oversubscribe > 1.0 needs an explicit --blocks "
                 "budget (the worst-case default never runs out)")
    if args.max_queue < 0:
        ap.error(f"--max-queue must be >= 0, got {args.max_queue}")
    if args.deadline_ms < 0:
        ap.error(f"--deadline-ms must be >= 0, got {args.deadline_ms}")
    if not args.recalibrate:
        for dest in ("recalib_target", "recalib_step",
                     "recalib_deadband", "recalib_warmup"):
            if given(dest):
                ap.error(f"--{dest.replace('_', '-')} needs "
                         f"--recalibrate")
    if args.tiers < 2:
        ap.error(f"--tiers must be >= 2, got {args.tiers}")
    if args.engine == "static":
        for dest, flag in (("tiers", "--tiers"), ("signal", "--signal"),
                           ("recalibrate", "--recalibrate"),
                           ("max_queue", "--max-queue"),
                           ("deadline_ms", "--deadline-ms")):
            if given(dest):
                ap.error(f"{flag} needs --engine continuous")
    if args.signal != "semantic_agreement":
        for dest in ("signal_k", "signal_temperature"):
            if given(dest):
                ap.error(f"--{dest.replace('_', '-')} needs "
                         f"--signal semantic_agreement")
    if remote:
        if args.tiers != 2:
            ap.error("--large-backend socket|pool drives the final "
                     "(remote) tier of a 2-tier cascade; --tiers > 2 "
                     "needs a local backend per intermediate tier")
        if args.ml_spawn <= 0 and not args.ml_address:
            ap.error("--large-backend socket/pool needs --ml-address "
                     "host:port[,host:port...] or --ml-spawn N")
        if (args.large_backend == "socket" and args.ml_address
                and len(args.ml_address.split(",")) != 1):
            ap.error("--large-backend socket takes exactly one "
                     "--ml-address; use --large-backend pool for several")

    key = jax.random.PRNGKey(args.seed)
    runners = build_ladder(args.arch, args.seed, args.tiers)
    small, large = runners[0], runners[-1]
    small_cfg = small.cfg

    ragged = args.ragged_min > 0
    cal_len = ((args.ragged_min + max(args.ragged_max, args.ragged_min))
               // 2 if ragged else args.prompt_len)
    cal = make_lm_stream(jax.random.fold_in(key, 1), args.requests,
                         cal_len, small_cfg.vocab_size)
    if ragged:
        live = make_ragged_lm_stream(
            jax.random.fold_in(key, 2), args.requests, args.ragged_min,
            max(args.ragged_max, args.ragged_min), small_cfg.vocab_size)
    else:
        live = make_lm_stream(jax.random.fold_in(key, 2), args.requests,
                              args.prompt_len, small_cfg.vocab_size)

    if args.engine == "static":
        engine = CascadeEngine(small, large)
        tau = engine.calibrate(cal, args.prompt_len, args.max_new,
                               args.deferral_ratio)
        print(f"calibrated tau={tau:.4f} for target deferral "
              f"{args.deferral_ratio}")
        res = engine.serve(live, args.prompt_len, args.max_new)
        print(f"served {len(live)} requests: deferral_ratio="
              f"{res.deferral_ratio:.3f}, compute_cost={res.compute_cost:.3f}x,"
              f" mean_confidence={res.confidence.mean():.4f}")
        print("first tokens:", res.tokens[:4].tolist())
        return

    ml_servers = []
    large_backend = args.large_backend
    if args.large_backend in ("socket", "pool"):
        if args.ml_spawn > 0:
            from repro.serving.remote import MLServer
            n = max(args.ml_spawn,
                    2 if args.large_backend == "pool" else 1)
            for _ in range(n):
                ml_servers.append(MLServer(
                    large, max_new=args.max_new,
                    large_batch=args.large_batch or None,
                    max_wait=args.large_max_wait or None).start())
            addresses = [s.address for s in ml_servers]
            print(f"spawned {n} in-process M_L server(s): "
                  + ", ".join(f"{h}:{p}" for h, p in addresses))
        elif args.ml_address:
            addresses = args.ml_address.split(",")
        else:
            ap.error("--large-backend socket/pool needs --ml-address "
                     "host:port[,host:port...] or --ml-spawn N")
        if args.large_backend == "socket" and len(addresses) != 1:
            ap.error("--large-backend socket takes exactly one "
                     "--ml-address; use --large-backend pool for several")
        large_backend = make_remote_factory(
            args.large_backend, addresses,
            connect_timeout=args.ml_connect_timeout,
            request_timeout=args.ml_request_timeout,
            retries=args.ml_retries,
            health_interval=args.ml_health_interval)

    # declarative ladder: one tier per runner, cost interpolated
    # geometrically from the paper's M_S (0.2) to M_L (1.0) units; one
    # deferral edge per adjacent pair, all carrying the same signal
    n = len(runners)
    costs = [0.2 * (1.0 / 0.2) ** (i / (n - 1)) for i in range(n)]
    tiers = [CascadeTier(r.cfg.name, runner=r, cost=costs[i])
             for i, r in enumerate(runners)]
    if callable(large_backend):          # socket/pool factory (2-tier)
        tiers[-1] = CascadeTier(tiers[-1].name, runner=large,
                                cost=costs[-1], backend=large_backend)

    def make_signal():
        if args.signal == "semantic_agreement":
            return SemanticAgreementSignal(k=args.signal_k,
                                           temperature=args.signal_temperature,
                                           seed=args.seed)
        return "mean_confidence"

    spec = CascadeSpec(
        tiers=tiers,
        edges=[DeferralEdge(signal=make_signal(), margin=args.margin,
                            min_tokens=args.min_tokens)
               for _ in range(n - 1)])
    recalib_target = (args.recalib_target if args.recalib_target >= 0
                      else args.deferral_ratio)
    pressure = (PressureConfig(oversubscribe=args.oversubscribe,
                               policy=args.pressure_policy,
                               max_preemptions=args.max_preemptions,
                               swap_blocks=args.swap_blocks)
                if args.oversubscribe > 1.0 or args.swap_blocks > 0
                else None)
    config = EngineConfig(
        n_slots=args.slots, early_exit=not args.no_early_exit,
        backend=args.backend,
        max_queue=args.max_queue or None,
        deadline_s=args.deadline_ms / 1e3 if args.deadline_ms else None,
        paged=PagedConfig(
            block_size=args.block_size,
            n_blocks=args.blocks or None,
            prefill_chunk=args.prefill_chunk or None,
            paged_kernel={"auto": None, "on": True,
                          "off": False}[args.paged_kernel],
            batch_prefill=not args.serial_prefill,
            prefix_sharing=not args.no_prefix_sharing,
            pressure=pressure),
        ml=MLBackendConfig(
            kind=args.large_backend if not callable(large_backend)
            else "sync",
            large_batch=args.large_batch or None,
            max_wait=args.large_max_wait or None,
            stub_latency=args.stub_latency),
        recalibration=(RecalibConfig(step=args.recalib_step,
                                     deadband=args.recalib_deadband,
                                     warmup=args.recalib_warmup)
                       if args.recalibrate else None),
        recalib_target=recalib_target)
    engine = ContinuousCascadeEngine(spec, config)
    taus = engine.calibrate(cal, cal_len, args.max_new,
                            args.deferral_ratio)
    taus = taus if isinstance(taus, list) else [taus]
    print(f"calibrated tau(s) "
          f"{', '.join(f'{t:.4f}' for t in taus)} for target deferral "
          f"{args.deferral_ratio} per edge"
          + (" (online recalibration on)" if args.recalibrate else ""))
    arrivals = (poisson_arrivals(len(live), args.arrival_rate, args.seed)
                if args.arrival_rate > 0 else None)
    reqs = make_requests(live, args.max_new, arrivals)
    # caller-owned observability runtime: the /metrics endpoint stays up
    # (and announced) before the run starts and until after the final
    # scrape is dumped
    obs = Observability(obs_cfg)
    server = obs.start_server()
    if server is not None:
        print(f"metrics endpoint: {server.url}")
    try:
        res = engine.run(reqs, args.max_new, audit_path=args.audit_log,
                         obs=obs)
    finally:
        obs.finish()
        for srv in ml_servers:
            srv.stop()
    print(f"served {len(live)} requests on {args.slots} slots "
          f"({args.backend} backend, {args.tiers}-tier ladder, upper "
          f"tiers via {args.large_backend}) in "
          f"{res.steps} M_S steps: deferral_ratio={res.deferral_ratio:.3f}, "
          f"early_exits={int(res.early_exited.sum())}, "
          f"saved_M_S_steps={res.saved_steps}")
    if args.tiers > 2:
        print(f"tier_served={res.stats['tier_served']} over tiers "
              f"{res.stats['tier_names']}, per-edge deferrals "
              f"{res.stats['edge_deferrals']}")
    if pressure is not None or args.max_queue or args.deadline_ms:
        st = res.stats
        print(f"pressure/overload: preemptions={st['n_preemptions']}, "
              f"oom_deferrals={st['oom_deferrals']}, "
              f"rejected={st['n_rejected']}, expired={st['n_expired']}, "
              f"swap_outs={st.get('swap_outs', 0)}, "
              f"swap_ins={st.get('swap_ins', 0)}")
    if args.recalibrate:
        rc = res.stats["recalibration"]
        drift = [f"{a:.4f}->{b:.4f}"
                 for a, b in zip(taus, rc["tau_final"])]
        print(f"online recalibration: tau drift {', '.join(drift)} "
              f"({rc['tau_updates']} updates, ewma deferral "
              f"{rc['ewma_ratio']})")
    print(json.dumps({k: (round(v, 4) if isinstance(v, float) else v)
                      for k, v in res.stats.items()}, indent=1))
    if args.audit_log:
        print(f"audit log written to {args.audit_log}")
    if args.trace_out:
        print(f"trace written to {args.trace_out} "
              f"(load in https://ui.perfetto.dev)")
    if args.metrics_out:
        print(f"metrics scrape written to {args.metrics_out}")
    if args.profile_dir:
        print(f"jax.profiler trace in {args.profile_dir}")
    print("first tokens:", res.tokens[:4].tolist())


if __name__ == "__main__":
    main()
