"""End-to-end training driver.

Two-stage recipe per the paper (§3.2): Stage-1 standard CE training, then
Stage-2 Gatekeeper confidence tuning, with checkpoints after each stage.

CPU-scale examples:
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --stage1-steps 200 --stage2-steps 100 --alpha 0.3
    PYTHONPATH=src python -m repro.launch.train --preset 100m \
        --stage1-steps 300           # ~100M-param decoder on lm_stream

On a real cluster the same entry point runs full configs under
make_production_mesh() (the dry-run proves those lower; this container is
CPU-only so full-scale execution is out of scope).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, get_config, reduced
from repro.core.gatekeeper import GatekeeperConfig
from repro.data.pipeline import BatchIterator
from repro.data.synthetic import make_lm_stream, make_qa
from repro.launch.steps import make_train_step
from repro.models import transformer as tfm
from repro.sharding import ParallelContext
from repro.training import checkpoint, optim


PRESET_100M = ModelConfig(
    name="repro-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072, vocab_size=8192,
    qkv_bias=False, rope_theta=10000.0, tie_embeddings=True,
    source="paper-scale driver (~100M params)")


def build_cfg(args) -> ModelConfig:
    if args.preset == "100m":
        return PRESET_100M
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    return cfg


def make_data(cfg: ModelConfig, args, key):
    if args.task == "qa":
        qa = make_qa(key, args.n_train, n_symbols=min(cfg.vocab_size - 16, 16))
        return {"tokens": qa.inputs, "targets": qa.targets,
                "loss_mask": qa.loss_mask}
    stream = make_lm_stream(key, args.n_train, args.seq_len + 1,
                            cfg.vocab_size)
    return {"tokens": stream[:, :-1], "targets": stream[:, 1:]}


def run(args):
    key = jax.random.PRNGKey(args.seed)
    cfg = build_cfg(args)
    ctx = ParallelContext()
    print(f"config: {cfg.name} ({cfg.family}), vocab={cfg.vocab_size}, "
          f"d_model={cfg.d_model}, layers={cfg.n_layers}")
    params = tfm.init_params(cfg, key)
    from repro.sharding import param_count
    print(f"params: {param_count(params)/1e6:.1f}M")

    data = make_data(cfg, args, jax.random.fold_in(key, 1))
    it = BatchIterator(data, args.batch, key=jax.random.fold_in(key, 2))

    for stage, steps, gk_alpha in (
            (1, args.stage1_steps, None),
            (2, args.stage2_steps, args.alpha)):
        if steps <= 0:
            continue
        opt_cfg = optim.AdamWConfig(lr=args.lr if stage == 1 else args.lr * 0.3,
                                    warmup_steps=min(50, steps // 5),
                                    total_steps=steps)
        gk = GatekeeperConfig(alpha=gk_alpha) if gk_alpha is not None else \
            GatekeeperConfig(alpha=1.0)   # alpha=1 + all-correct ≈ CE? no:
        # Stage 1 uses plain CE via alpha=1.0 would still skip incorrect
        # tokens; instead use the dedicated CE loss:
        step_fn = make_train_step(cfg, ctx, gk=gk, opt_cfg=opt_cfg)
        if stage == 1:
            from repro.training.loop import make_train_step as mk
            def apply_fn(params, batch):
                return tfm.forward(params, cfg, batch["inputs"], ctx,
                                   batch.get("patches"), return_aux=True)
            step_fn = mk(apply_fn, opt_cfg, loss_kind="ce", aux_weight=0.01)
        opt_state = optim.adamw_init(params)
        t0 = time.time()
        it_forever = it.forever()
        for i in range(steps):
            b = next(it_forever)
            batch = {"tokens": jnp.asarray(b["tokens"]),
                     "targets": jnp.asarray(b["targets"])}
            if "loss_mask" in b:
                batch["loss_mask"] = jnp.asarray(b["loss_mask"])
            if stage == 1:
                batch["inputs"] = batch["tokens"]
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if (i + 1) % args.log_every == 0 or i == 0:
                m = {k: round(float(v), 4) for k, v in metrics.items()
                     if jnp.ndim(v) == 0}
                print(f"stage{stage} step {i+1}/{steps} "
                      f"({(time.time()-t0)/(i+1):.2f}s/step): {m}")
        if args.ckpt:
            path = f"{args.ckpt}/stage{stage}"
            checkpoint.save_checkpoint(path, params, step=steps)
            print(f"checkpoint -> {path}")
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--preset", default=None, choices=[None, "100m"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--task", default="stream", choices=["stream", "qa"])
    ap.add_argument("--n-train", type=int, default=2048)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--stage1-steps", type=int, default=100)
    ap.add_argument("--stage2-steps", type=int, default=50)
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--ckpt", default=None)
    run(ap.parse_args())


if __name__ == "__main__":
    main()
