"""Shared helpers for the benchmark suite (one module per paper table/fig)."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def save_result(name: str, payload: Dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=_np_safe)
    return path


def _np_safe(o):
    if isinstance(o, (np.floating, np.integer)):
        return o.item()
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def emit_csv_row(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
