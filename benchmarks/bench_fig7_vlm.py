"""Paper Fig. 7: VLM (enc-dec style) cascade — closed-form classification AND
open-form captioning with a graded factuality score; the paper's Gemini
judge is replaced by the programmatic `caption_factuality` (App. B.4
analogue) and the Pearson-correlation metric of §4.3.

Instantiation: stub patch embeddings -> tiny decoder ("PaliGemma-1B" role)
vs a larger decoder ("7B" role); captions = [class_tok, attr_tok, SEP].
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig, VisionSpec
from repro.core.deferral import sequence_negative_entropy
from repro.core.gatekeeper import GatekeeperConfig
from repro.core.metrics import (deferral_performance, pearson_correlation,
                                summarize_deferral)
from repro.data.pipeline import BatchIterator
from repro.data.synthetic import SYMBOL_BASE, caption_factuality, make_captions
from repro.models import transformer as tfm
from repro.sharding import ParallelContext
from repro.training import optim
from repro.training.loop import make_train_step, train

from benchmarks.common import emit_csv_row, save_result

ALPHAS = (0.05, 0.2, 0.5)
CTX = ParallelContext()


def _mk_cfg(name, layers, d, vocab, patches):
    return ModelConfig(name=name, family="vlm", n_layers=layers, d_model=d,
                       n_heads=4, n_kv_heads=4, head_dim=d // 4, d_ff=d * 4,
                       vocab_size=vocab, tie_embeddings=True,
                       vision=VisionSpec(n_patches=patches))


def _project(patches, d_model, key):
    """Stub frontend projector: fixed random projection to d_model."""
    w = jax.random.normal(key, (patches.shape[-1], d_model)) / \
        np.sqrt(patches.shape[-1])
    return jnp.asarray(patches) @ w


def _train_vlm(cfg, proj, data, seed, steps, loss_kind="ce", gk=None,
               init=None, lr=3e-3):
    params = init if init is not None else tfm.init_params(
        cfg, jax.random.PRNGKey(seed))
    P = data.patches.shape[1]
    targets = np.concatenate(
        [np.zeros((len(data.tokens), P), np.int32), data.targets], axis=1)
    mask = np.concatenate(
        [np.zeros((len(data.tokens), P), np.float32),
         np.ones_like(data.targets, np.float32)], axis=1)
    apply_fn = lambda p, b: tfm.forward(p, cfg, b["inputs"], CTX,
                                        extra_embeds=b["patches"])
    it = BatchIterator({"inputs": data.inputs, "patches": np.asarray(proj),
                        "targets": targets, "loss_mask": mask}, 256,
                       key=jax.random.PRNGKey(seed))
    step = make_train_step(apply_fn, optim.AdamWConfig(lr=lr,
                                                       total_steps=steps),
                           loss_kind=loss_kind, gk_cfg=gk)
    return train(params, step, it.forever(), steps, log_every=10**9).params


def _generate_caption(cfg, params, proj, data):
    """Teacher-free 2-token greedy decode (class_tok, attr_tok) after BOS."""
    logits = tfm.forward(params, cfg, jnp.asarray(data.inputs), CTX,
                         extra_embeds=jnp.asarray(proj))
    P = proj.shape[1]
    text_logits = logits[:, P:, :]            # positions predicting tokens
    pred_cls = np.asarray(jnp.argmax(text_logits[:, 0, :], -1))
    pred_attr = np.asarray(jnp.argmax(text_logits[:, 1, :], -1))
    preds = np.stack([pred_cls, pred_attr], axis=1)
    mask = jnp.ones((len(preds), text_logits.shape[1]))
    conf = np.asarray(sequence_negative_entropy(text_logits, mask))
    return preds, conf


def run(n_train=1500, n_large=12000, n_cal=3000, n_test=2500,
        steps=800, gk_steps=600, seed=0):
    key = jax.random.PRNGKey(seed)
    d_raw = 32
    tr = make_captions(key, n_train, n_patches=8, d_model=d_raw)
    tr_l = make_captions(jax.random.fold_in(key, 5), n_large, n_patches=8,
                         d_model=d_raw)
    cal = make_captions(jax.random.fold_in(key, 7), n_cal, n_patches=8,
                        d_model=d_raw)
    te = make_captions(jax.random.fold_in(key, 1), n_test, n_patches=8,
                       d_model=d_raw)
    s_cfg = _mk_cfg("vlm-small", 2, 64, tr.vocab, 8)
    l_cfg = _mk_cfg("vlm-large", 4, 160, tr.vocab, 8)
    kp = jax.random.fold_in(key, 9)
    tr_s, te_s = _project(tr.patches, 64, kp), _project(te.patches, 64, kp)
    cal_s = _project(cal.patches, 64, kp)
    trl_l = _project(tr_l.patches, 160, kp)
    te_l = _project(te.patches, 160, kp)

    t0 = time.perf_counter()
    small = _train_vlm(s_cfg, tr_s, tr, 1, steps + 700)   # to interpolation
    large = _train_vlm(l_cfg, trl_l, tr_l, 2, steps + 400)
    l_preds, _ = _generate_caption(l_cfg, large, te_l, te)
    l_fact = caption_factuality(l_preds, te)

    rows = {}

    def eval_model(params):
        preds, conf = _generate_caption(s_cfg, params, te_s, te)
        fact = caption_factuality(preds, te)
        cls_correct = (preds[:, 0] == SYMBOL_BASE + te.classes).astype(float)
        l_cls = (l_preds[:, 0] == SYMBOL_BASE + te.classes).astype(float)
        out = summarize_deferral(conf, cls_correct, l_cls)   # closed-form
        out["pearson_fact"] = pearson_correlation(conf, fact)  # open-form
        out["s_d_fact"] = deferral_performance(conf, fact, l_fact)["s_d"]
        return out

    rows["baseline"] = eval_model(small)
    for a in ALPHAS:
        tuned = _train_vlm(s_cfg, cal_s, cal, 3, gk_steps,
                           loss_kind="gatekeeper",
                           gk=GatekeeperConfig(alpha=a), init=small, lr=3e-3)
        rows[f"alpha={a}"] = eval_model(tuned)
    elapsed = time.perf_counter() - t0

    payload = {k: {m: v[m] for m in ("s_d", "s_o", "auroc", "acc_small",
                                     "pearson_fact", "s_d_fact")}
               for k, v in rows.items()}
    save_result("fig7_vlm", payload)
    for k, v in payload.items():
        emit_csv_row(f"fig7/{k}", elapsed / len(rows) * 1e6,
                     f"s_d={v['s_d']:.3f};pearson={v['pearson_fact']:.3f};"
                     f"s_d_fact={v['s_d_fact']:.3f};acc={v['acc_small']:.3f}")
    return payload


if __name__ == "__main__":
    run()
